//! The paper's benchmark data structures (§4.1), generic over the
//! reclamation scheme:
//!
//! * [`queue::Queue`] — Michael & Scott's lock-free FIFO queue.
//! * [`list::List`] — Michael's improved version of Harris' list-based set
//!   (optionally carrying values).
//! * [`hashmap::HashMap`] — the hash-map built from per-bucket lists, and
//!   [`hashmap::FifoCache`] — the bounded FIFO-evicting variant the
//!   HashMap benchmark uses.
pub mod hashmap;
pub mod list;
pub mod queue;

//! The paper's benchmark data structures (§4.1), generic over the
//! reclamation scheme:
//!
//! * [`queue::Queue`] — Michael & Scott's lock-free FIFO queue.
//! * [`list::List`] — Michael's improved version of Harris' list-based set
//!   (optionally carrying values).
//! * [`hashmap::HashMap`] — the hash-map built from per-bucket lists, and
//!   [`hashmap::FifoCache`] — the bounded FIFO-evicting variant the
//!   HashMap benchmark uses.
//!
//! Every structure is bound to a reclamation
//! [`DomainRef`](crate::reclaim::DomainRef): `new()` uses the process-wide
//! global domain, `new_in(domain)` isolates the structure in its own
//! reclamation universe (one per shard, test or benchmark trial). Each
//! operation takes one `impl `[`HandleSource`](crate::reclaim::HandleSource)
//! argument selecting the plumbing: [`Cached`](crate::reclaim::Cached)
//! resolves the calling thread's cached handle (one TLS lookup per call),
//! a registered [`&LocalHandle`](crate::reclaim::LocalHandle) is the
//! TLS-free hot path.
//!
//! The structures are written entirely on the safe SMR facade
//! ([`crate::reclaim::facade`]); `unsafe` appears only at
//! unlink-then-retire sites and in exclusive-access `Drop` teardowns, each
//! with its one-line safety argument.
pub mod hashmap;
pub mod list;
pub mod queue;

//! Michael-style lock-free hash-map — an array of Harris–Michael lists
//! (paper §4.1) — plus [`FifoCache`], the bounded, FIFO-evicting wrapper
//! the paper's HashMap benchmark is built around: "the number of entries in
//! the hash-map is kept below some threshold by evicting old entries using
//! a simple FIFO policy".
//!
//! Paper benchmark parameters (defaults in [`crate::bench_fw`]): 2048
//! buckets, ≤ 10 000 entries, 30 000 possible keys, 1024-byte payloads.
//!
//! All buckets (and the FIFO order queue) share the map's reclamation
//! [`DomainRef`]; `new` uses the global domain, `new_in` pins the map to an
//! owned one. Every operation takes an `impl HandleSource<R>`
//! ([`Cached`](crate::reclaim::Cached) or a registered
//! [`&LocalHandle`](crate::reclaim::LocalHandle)); composite operations
//! resolve the handle **once** at the entry point and pass it through to
//! the buckets and the order queue. This file is entirely safe code — the
//! list and queue carry the retire sites.

use super::list::List;
use super::queue::Queue;
use crate::reclaim::{DomainRef, HandleSource, Reclaimer};
use crate::util::rng::mix64;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Lock-free hash-map under reclamation scheme `R`.
pub struct HashMap<K, V, R>
where
    K: Ord + std::hash::Hash + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    domain: DomainRef<R>,
    buckets: Box<[List<K, V, R>]>,
    len: AtomicUsize,
}

/// Cheap stateless hash (SplitMix64 finalizer over `Hash`-fed u64).
fn bucket_of<K: std::hash::Hash>(key: &K, n: usize) -> usize {
    use std::hash::Hasher;
    // FxHash-style accumulation into a u64, finalized by mix64.
    struct H(u64);
    impl Hasher for H {
        fn finish(&self) -> u64 {
            mix64(self.0)
        }
        fn write(&mut self, bytes: &[u8]) {
            for &b in bytes {
                self.0 = (self.0 ^ b as u64).wrapping_mul(0x0100_0000_01B3);
            }
        }
        fn write_u64(&mut self, v: u64) {
            self.0 = (self.0 ^ v).wrapping_mul(0x0100_0000_01B3);
        }
        fn write_u32(&mut self, v: u32) {
            self.write_u64(v as u64);
        }
        fn write_usize(&mut self, v: usize) {
            self.write_u64(v as u64);
        }
    }
    let mut h = H(0xCBF2_9CE4_8422_2325);
    key.hash(&mut h);
    (h.finish() % n as u64) as usize
}

impl<K, V, R> HashMap<K, V, R>
where
    K: Ord + std::hash::Hash + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    /// A map with `buckets` buckets (paper: 2048) on the global domain.
    pub fn new(buckets: usize) -> Self {
        Self::new_in(DomainRef::global(), buckets)
    }

    /// A map whose nodes are retired into `domain`.
    pub fn new_in(domain: DomainRef<R>, buckets: usize) -> Self {
        assert!(buckets > 0);
        Self {
            buckets: (0..buckets).map(|_| List::new_in(domain.clone())).collect(),
            domain,
            len: AtomicUsize::new(0),
        }
    }

    /// The map's reclamation domain.
    pub fn domain(&self) -> &DomainRef<R> {
        &self.domain
    }

    #[inline]
    fn bucket(&self, key: &K) -> &List<K, V, R> {
        &self.buckets[bucket_of(key, self.buckets.len())]
    }

    /// Is `key` present?
    pub fn contains(&self, h: impl HandleSource<R>, key: &K) -> bool {
        h.with_source(&self.domain, |h| self.bucket(key).contains(h, key))
    }

    /// Guarded read of the value under `key` (no clone of the payload —
    /// the benchmark's 1 KiB results are consumed in place).
    pub fn get<U>(&self, h: impl HandleSource<R>, key: &K, f: impl FnOnce(&V) -> U) -> Option<U> {
        h.with_source(&self.domain, |h| self.bucket(key).get(h, key, f))
    }

    /// Insert if absent; returns whether this call inserted.
    pub fn insert(&self, h: impl HandleSource<R>, key: K, value: V) -> bool {
        h.with_source(&self.domain, |h| {
            let inserted = self.bucket(&key).insert(h, key, value);
            if inserted {
                self.len.fetch_add(1, Ordering::Relaxed);
            }
            inserted
        })
    }

    /// Remove `key`; returns whether this call removed it.
    pub fn remove(&self, h: impl HandleSource<R>, key: &K) -> bool {
        h.with_source(&self.domain, |h| {
            let removed = self.bucket(key).remove(h, key);
            if removed {
                self.len.fetch_sub(1, Ordering::Relaxed);
            }
            removed
        })
    }

    /// Entry count (maintained with relaxed counters; exact when quiescent).
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of buckets.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }
}

/// The paper's HashMap-benchmark container: a bounded hash-map with FIFO
/// eviction. Insertion order is tracked in a Michael–Scott queue **built on
/// the same reclamation scheme and domain** — the benchmark therefore
/// stresses two node types (map nodes carrying large payloads, queue nodes)
/// at once, just like the paper's implementation.
pub struct FifoCache<K, V, R>
where
    K: Ord + std::hash::Hash + Clone + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    map: HashMap<K, V, R>,
    order: Queue<K, R>,
    capacity: usize,
}

impl<K, V, R> FifoCache<K, V, R>
where
    K: Ord + std::hash::Hash + Clone + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    /// A cache holding at most `capacity` entries across `buckets` buckets,
    /// on the global domain.
    pub fn new(buckets: usize, capacity: usize) -> Self {
        Self::new_in(DomainRef::global(), buckets, capacity)
    }

    /// A cache whose nodes are retired into `domain`.
    pub fn new_in(domain: DomainRef<R>, buckets: usize, capacity: usize) -> Self {
        Self {
            map: HashMap::new_in(domain.clone(), buckets),
            order: Queue::new_in(domain),
            capacity,
        }
    }

    /// The cache's reclamation domain.
    pub fn domain(&self) -> &DomainRef<R> {
        self.map.domain()
    }

    /// Guarded read (a cache hit — the benchmark's "reuse" path).
    pub fn get<U>(&self, h: impl HandleSource<R>, key: &K, f: impl FnOnce(&V) -> U) -> Option<U> {
        h.with_source(self.domain(), |h| self.map.get(h, key, f))
    }

    /// Is `key` cached?
    pub fn contains(&self, h: impl HandleSource<R>, key: &K) -> bool {
        h.with_source(self.domain(), |h| self.map.contains(h, key))
    }

    /// Insert a computed result; evicts FIFO-oldest entries beyond
    /// capacity. Returns whether this call inserted (false = already
    /// present, `value` dropped). The handle is resolved once for the
    /// whole insert-enqueue-evict sequence.
    pub fn insert(&self, h: impl HandleSource<R>, key: K, value: V) -> bool {
        h.with_source(self.domain(), |h| {
            if !self.map.insert(h, key.clone(), value) {
                return false;
            }
            self.order.enqueue(h, key);
            // Evict until back under capacity. An evicted key may already
            // have been removed (rare double-insert races) — the queue is
            // the single source of eviction order, the map the source of
            // truth.
            while self.map.len() > self.capacity {
                match self.order.dequeue(h) {
                    Some(old) => {
                        self.map.remove(h, &old);
                    }
                    None => break,
                }
            }
            true
        })
    }

    /// Current entry count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::debra::Debra;
    use crate::reclaim::leaky::Leaky;
    use crate::reclaim::lfrc::Lfrc;
    use crate::reclaim::stamp::StampIt;
    use crate::reclaim::Cached;

    #[test]
    fn map_semantics() {
        let m: HashMap<u64, u64, Leaky> = HashMap::new(16);
        assert!(m.is_empty());
        for i in 0..100 {
            assert!(m.insert(Cached, i, i * 10));
        }
        assert!(!m.insert(Cached, 5, 0), "duplicate insert must fail");
        assert_eq!(m.len(), 100);
        for i in 0..100 {
            assert_eq!(m.get(Cached, &i, |v| *v), Some(i * 10));
        }
        assert!(m.remove(Cached, &50));
        assert!(!m.remove(Cached, &50));
        assert!(!m.contains(Cached, &50));
        assert_eq!(m.len(), 99);
    }

    #[test]
    fn bucket_distribution_is_reasonable() {
        let n = 64;
        let mut counts = vec![0usize; n];
        for k in 0u64..6400 {
            counts[bucket_of(&k, n)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(min > 0, "empty bucket: {counts:?}");
        assert!(max < 300, "overloaded bucket: max={max}");
    }

    #[test]
    fn fifo_cache_evicts_oldest() {
        let c: FifoCache<u64, u64, Leaky> = FifoCache::new(16, 10);
        for i in 0..25 {
            assert!(c.insert(Cached, i, i));
        }
        assert!(c.len() <= 10, "capacity must bound entries: {}", c.len());
        // The oldest entries are gone, the newest survive.
        assert!(!c.contains(Cached, &0));
        assert!(!c.contains(Cached, &5));
        assert!(c.contains(Cached, &24));
    }

    fn concurrent_cache_exercise<R: Reclaimer>() {
        use crate::reclaim::DomainRef;
        use crate::util::rng::Xoshiro256;
        use std::sync::Arc;
        // Shrunk HashMap-benchmark shape: large-ish payloads, bounded map,
        // concurrent compute-or-reuse — on an isolated domain.
        let cache: Arc<FifoCache<u64, [u8; 256], R>> =
            Arc::new(FifoCache::new_in(DomainRef::new_owned(), 64, 100));
        let threads = 4;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let cache = cache.clone();
                std::thread::spawn(move || {
                    let h = cache.domain().register();
                    let mut rng = Xoshiro256::new(0xCAFE + t as u64);
                    let mut hits = 0usize;
                    for i in 0..2000 {
                        let key = rng.below(300);
                        let found = cache.get(&h, &key, |v| {
                            // Payload integrity: first byte encodes the key.
                            assert_eq!(v[0], (key % 251) as u8);
                        });
                        match found {
                            Some(()) => hits += 1,
                            None => {
                                let mut payload = [0u8; 256];
                                payload[0] = (key % 251) as u8;
                                cache.insert(&h, key, payload);
                            }
                        }
                        if i % 128 == 0 {
                            std::thread::yield_now();
                        }
                    }
                    hits
                })
            })
            .collect();
        let total_hits: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(cache.len() <= 100 + threads, "capacity roughly respected: {}", cache.len());
        assert!(total_hits > 0, "a cache that never hits is broken");
    }

    #[test]
    fn concurrent_cache_under_debra() {
        concurrent_cache_exercise::<Debra>();
    }

    #[test]
    fn concurrent_cache_under_lfrc() {
        concurrent_cache_exercise::<Lfrc>();
    }

    #[test]
    fn concurrent_cache_under_stamp_it() {
        concurrent_cache_exercise::<StampIt>();
    }
}

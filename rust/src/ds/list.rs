//! Harris–Michael lock-free ordered list-based set (paper §2 and §4.1:
//! "the linked-list and hash-map [are based] on Michael's improved version
//! [18] of Harris' list-based set [14]").
//!
//! `find` follows the paper's Listing 1: it walks with two guards (`cur`
//! and `save`, the latter pinning the node that owns the `prev` link),
//! helps unlink marked nodes it passes, and restarts on interference. The
//! delete mark lives in bit 0 of each node's `next` pointer — the
//! `marked_ptr` trick the interface exists for.
//!
//! Every list belongs to a reclamation [`DomainRef`]; the `*_with` variants
//! take an explicit [`LocalHandle`] (TLS-free), the plain variants resolve
//! the thread's cached handle once per call.

use crate::reclaim::{
    alloc_node, ConcurrentPtr, DomainRef, GuardPtr, LocalHandle, MarkedPtr, Reclaimer,
};
use std::sync::atomic::Ordering;

/// A list node: key plus optional value (the set uses `V = ()`; the
/// hash-map stores payloads).
pub struct LNode<K: Send + Sync + 'static, V: Send + Sync + 'static, R: Reclaimer> {
    key: K,
    value: V,
    next: ConcurrentPtr<LNode<K, V, R>, R>,
}

impl<K: Send + Sync + 'static, V: Send + Sync + 'static, R: Reclaimer> LNode<K, V, R> {
    pub fn key(&self) -> &K {
        &self.key
    }

    pub fn value(&self) -> &V {
        &self.value
    }
}

/// Result of a `find`: the insertion point and (on hit) the guarded node.
pub struct FindResult<K: Send + Sync + 'static, V: Send + Sync + 'static, R: Reclaimer> {
    /// Pointer to the `next` field to CAS for insertion (head or a node
    /// kept alive by `save`).
    prev: *const ConcurrentPtr<LNode<K, V, R>, R>,
    /// Snapshot of `*prev` (what an insertion CAS must expect).
    next: MarkedPtr<LNode<K, V, R>, R>,
    /// Guard on the node at `next` (the found node on a hit).
    cur: GuardPtr<LNode<K, V, R>, R>,
    /// Guard on the node owning `prev` (null when `prev` is the head).
    _save: GuardPtr<LNode<K, V, R>, R>,
    found: bool,
}

/// Sorted lock-free set/map list under reclamation scheme `R`.
pub struct List<K, V, R>
where
    K: Ord + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    domain: DomainRef<R>,
    head: ConcurrentPtr<LNode<K, V, R>, R>,
}

impl<K, V, R> Default for List<K, V, R>
where
    K: Ord + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    fn default() -> Self {
        Self::new()
    }
}

impl<K, V, R> List<K, V, R>
where
    K: Ord + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    /// An empty list on the global domain.
    pub const fn new() -> Self {
        Self { domain: DomainRef::global(), head: ConcurrentPtr::null() }
    }

    /// An empty list whose nodes are retired into `domain`.
    pub fn new_in(domain: DomainRef<R>) -> Self {
        Self { domain, head: ConcurrentPtr::null() }
    }

    /// The list's reclamation domain.
    pub fn domain(&self) -> &DomainRef<R> {
        &self.domain
    }

    /// Paper Listing 1: locate `key`, helping unlink marked nodes on the
    /// way. On return, `prev`/`next` define the insertion point and `cur`
    /// guards the first node with `node.key >= key` (if any).
    fn find(&self, h: &LocalHandle<R>, key: &K) -> FindResult<K, V, R> {
        'retry: loop {
            let mut prev: *const ConcurrentPtr<LNode<K, V, R>, R> = &self.head;
            let mut save: GuardPtr<LNode<K, V, R>, R> = h.guard();
            let mut cur: GuardPtr<LNode<K, V, R>, R> = h.guard();
            // SAFETY: prev is the head (owned by self) here; below it is a
            // field of the node pinned by `save`.
            let mut next = unsafe { (*prev).load(Ordering::Acquire) };
            loop {
                // Acquire the snapshot; restart if prev moved under us.
                // SAFETY: prev valid as above.
                if !unsafe { cur.acquire_if_equal(&*prev, next.with_mark(0)) } {
                    continue 'retry;
                }
                if cur.is_null() {
                    let next = next.with_mark(0);
                    return FindResult { prev, next, cur, _save: save, found: false };
                }
                let cur_ptr = cur.get();
                // SAFETY: cur is guarded.
                let cur_node = unsafe { cur_ptr.deref_data() };
                let succ = cur_node.next.load(Ordering::Acquire);
                if succ.mark() != 0 {
                    // cur is logically deleted: help splice it out.
                    // SAFETY: prev valid (head or pinned by save).
                    if unsafe {
                        (*prev)
                            .compare_exchange(
                                cur_ptr.with_mark(0),
                                succ.with_mark(0),
                                Ordering::AcqRel,
                                Ordering::Acquire,
                            )
                            .is_err()
                    } {
                        continue 'retry;
                    }
                    // SAFETY: we unlinked cur; the unlinking CAS winner
                    // retires it (Michael's rule).
                    unsafe { cur.reclaim() };
                    next = succ.with_mark(0);
                    continue;
                }
                // Validate prev still points at cur (paper line 15).
                // SAFETY: prev valid as above.
                if unsafe { (*prev).load(Ordering::Acquire) } != cur_ptr.with_mark(0) {
                    continue 'retry;
                }
                if cur_node.key >= *key {
                    let found = cur_node.key == *key;
                    return FindResult { prev, next: cur_ptr.with_mark(0), cur, _save: save, found };
                }
                prev = &cur_node.next;
                save = cur.take(); // `save = std::move(cur)` (Listing 1)
                next = succ;
            }
        }
    }

    /// Does the set contain `key`?
    pub fn contains(&self, key: &K) -> bool {
        self.domain.with_handle(|h| self.contains_with(h, key))
    }

    /// [`Self::contains`] through an explicit handle (no TLS).
    pub fn contains_with(&self, h: &LocalHandle<R>, key: &K) -> bool {
        self.find(h, key).found
    }

    /// Read the value under `key` through `f` (guarded access — no clone).
    pub fn get_with<U>(&self, key: &K, f: impl FnOnce(&V) -> U) -> Option<U> {
        self.domain.with_handle(|h| self.get_with_handle(h, key, f))
    }

    /// [`Self::get_with`] through an explicit handle (no TLS).
    pub fn get_with_handle<U>(
        &self,
        h: &LocalHandle<R>,
        key: &K,
        f: impl FnOnce(&V) -> U,
    ) -> Option<U> {
        let r = self.find(h, key);
        if r.found {
            // SAFETY: cur is guarded and non-null on a hit.
            Some(f(unsafe { r.cur.get().deref_data().value() }))
        } else {
            None
        }
    }

    /// Insert `key → value` if absent. Returns false (and drops `value`)
    /// when the key already exists.
    pub fn insert(&self, key: K, value: V) -> bool {
        self.domain.with_handle(|h| self.insert_with(h, key, value))
    }

    /// [`Self::insert`] through an explicit handle (no TLS).
    pub fn insert_with(&self, h: &LocalHandle<R>, key: K, value: V) -> bool {
        let node = alloc_node::<LNode<K, V, R>, R>(LNode {
            key,
            value,
            next: ConcurrentPtr::null(),
        });
        let node_ptr = MarkedPtr::new(node, 0);
        loop {
            // SAFETY: node is still private.
            let node_ref = unsafe { &*node };
            let r = self.find(h, &node_ref.data().key);
            if r.found {
                // SAFETY: never published.
                unsafe { crate::reclaim::free_node(node) };
                return false;
            }
            node_ref.data().next.store(r.next, Ordering::Relaxed);
            // Release publishes the node's contents.
            // SAFETY: r.prev is the head or pinned by r._save.
            if unsafe {
                (*r.prev)
                    .compare_exchange(r.next, node_ptr, Ordering::Release, Ordering::Relaxed)
                    .is_ok()
            } {
                return true;
            }
        }
    }

    /// Remove `key`. Returns true if this call removed it.
    pub fn remove(&self, key: &K) -> bool {
        self.domain.with_handle(|h| self.remove_with(h, key))
    }

    /// [`Self::remove`] through an explicit handle (no TLS).
    pub fn remove_with(&self, h: &LocalHandle<R>, key: &K) -> bool {
        loop {
            let mut r = self.find(h, key);
            if !r.found {
                return false;
            }
            let cur_ptr = r.cur.get();
            // SAFETY: guarded.
            let cur_node = unsafe { cur_ptr.deref_data() };
            let succ = cur_node.next.load(Ordering::Acquire);
            if succ.mark() != 0 {
                continue; // someone else is deleting it; re-find (help)
            }
            // Logical delete: set the mark (the linearization point).
            if cur_node
                .next
                .compare_exchange(succ, succ.with_mark(1), Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            // Physical unlink; on failure find() will clean up later.
            // SAFETY: r.prev is the head or pinned by r._save.
            if unsafe {
                (*r.prev)
                    .compare_exchange(
                        cur_ptr.with_mark(0),
                        succ.with_mark(0),
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
            } {
                // SAFETY: we unlinked it and we won the marking CAS.
                unsafe { r.cur.reclaim() };
            } else {
                let _ = self.find(h, key); // helper pass retires it
            }
            return true;
        }
    }

    /// Number of (unmarked) nodes — O(n), diagnostics.
    pub fn len(&self) -> usize {
        self.domain.with_handle(|h| {
            let mut n = 0;
            let mut g: GuardPtr<LNode<K, V, R>, R> = h.guard();
            #[allow(unused_assignments)]
            let mut _save: GuardPtr<LNode<K, V, R>, R> = h.guard();
            let mut prev: *const ConcurrentPtr<LNode<K, V, R>, R> = &self.head;
            loop {
                // SAFETY: prev is the head or a field of the node pinned by
                // `save`.
                let cur = g.acquire(unsafe { &*prev });
                if cur.is_null() {
                    return n;
                }
                // SAFETY: guarded.
                let node = unsafe { cur.deref_data() };
                if node.next.load(Ordering::Acquire).mark() == 0 {
                    n += 1;
                }
                prev = &node.next;
                // Pin the node owning `prev`; the previous pin drops after
                // the reassignment (prev no longer points into it).
                _save = g.take();
            }
        })
    }

    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Acquire).is_null()
    }
}

impl<K, V, R> Drop for List<K, V, R>
where
    K: Ord + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    fn drop(&mut self) {
        // Exclusive access: free all nodes directly.
        let mut cur = self.head.load(Ordering::Relaxed);
        while !cur.is_null() {
            // SAFETY: exclusive during drop.
            unsafe {
                let next = cur.deref_data().next.load(Ordering::Relaxed);
                crate::reclaim::free_node(cur.get());
                cur = next.with_mark(0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::hp::Hp;
    use crate::reclaim::leaky::Leaky;
    use crate::reclaim::stamp::StampIt;

    #[test]
    fn set_semantics_single_thread() {
        let l: List<u64, (), Leaky> = List::new();
        assert!(!l.contains(&5));
        assert!(l.insert(5, ()));
        assert!(!l.insert(5, ()), "duplicate insert must fail");
        assert!(l.insert(3, ()));
        assert!(l.insert(7, ()));
        assert_eq!(l.len(), 3);
        assert!(l.contains(&3) && l.contains(&5) && l.contains(&7));
        assert!(!l.contains(&4));
        assert!(l.remove(&5));
        assert!(!l.remove(&5), "double remove must fail");
        assert!(!l.contains(&5));
        assert_eq!(l.len(), 2);
    }

    #[test]
    fn values_accessible_through_get_with() {
        let l: List<u32, String, Leaky> = List::new();
        l.insert(1, "one".to_string());
        l.insert(2, "two".to_string());
        assert_eq!(l.get_with(&1, |v| v.clone()), Some("one".to_string()));
        assert_eq!(l.get_with(&3, |v| v.clone()), None);
    }

    fn concurrent_set_exercise<R: Reclaimer>() {
        use crate::util::rng::Xoshiro256;
        use std::sync::Arc;
        let l: Arc<List<u64, (), R>> = Arc::new(List::new_in(DomainRef::new_owned()));
        let key_range = 20u64; // paper: key range = 2 × list size (10)
        let threads = 4;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    let h = l.domain().register();
                    let mut rng = Xoshiro256::new(0xD5 + t as u64);
                    for i in 0..3000 {
                        let k = rng.below(key_range);
                        match rng.below(10) {
                            0..=3 => {
                                l.insert_with(&h, k, ());
                            }
                            4..=7 => {
                                l.remove_with(&h, &k);
                            }
                            _ => {
                                l.contains_with(&h, &k);
                            }
                        }
                        if i % 128 == 0 {
                            std::thread::yield_now();
                        }
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        // Structural sanity: strictly sorted, unique keys.
        let h = l.domain().register();
        let mut prev_key = None;
        let mut g: GuardPtr<LNode<u64, (), R>, R> = h.guard();
        #[allow(unused_assignments)]
        let mut _save: GuardPtr<LNode<u64, (), R>, R> = h.guard();
        let mut prev: *const ConcurrentPtr<LNode<u64, (), R>, R> = &l.head;
        loop {
            let cur = g.acquire(unsafe { &*prev });
            if cur.is_null() {
                break;
            }
            let node = unsafe { cur.deref_data() };
            if let Some(p) = prev_key {
                assert!(node.key > p, "keys must be strictly sorted: {} !> {}", node.key, p);
            }
            prev_key = Some(node.key);
            prev = &node.next;
            _save = g.take(); // pin the node owning `prev`
        }
    }

    #[test]
    fn concurrent_set_under_hp() {
        concurrent_set_exercise::<Hp>();
    }

    #[test]
    fn concurrent_set_under_stamp_it() {
        concurrent_set_exercise::<StampIt>();
    }
}

//! Harris–Michael lock-free ordered list-based set (paper §2 and §4.1:
//! "the linked-list and hash-map [are based] on Michael's improved version
//! [18] of Harris' list-based set [14]").
//!
//! `find` follows the paper's Listing 1: it walks with two shields (`cur`
//! and `save`, the latter pinning the node that owns the `prev` link),
//! helps unlink marked nodes it passes, and restarts on interference. The
//! delete mark lives in bit 0 of each node's `next` pointer — the
//! `marked_ptr` trick the interface exists for.
//!
//! Written entirely against the safe facade ([`Atomic`] / [`Guard`] /
//! [`Shared`] / [`Owned`]): the `prev` link is re-derived from the `save`
//! shield on every use (so it is valid by construction — no raw pointer
//! into a node), traversal dereferences are safe through [`Shared`], and
//! trusted code narrows to the two unlink-then-retire sites plus the
//! exclusive-access teardown in `Drop`, each with its safety argument.
//!
//! Every list belongs to a reclamation [`DomainRef`]; each operation takes
//! an `impl HandleSource<R>`: pass [`Cached`](crate::reclaim::Cached) to
//! resolve the thread's cached handle (one TLS lookup), or a registered
//! [`&LocalHandle`](LocalHandle) for the TLS-free fast path.

use crate::reclaim::{
    Atomic, DomainRef, Guard, HandleSource, LocalHandle, MarkedPtr, Owned, Reclaimer,
};
use std::sync::atomic::Ordering;

/// A list node: key plus optional value (the set uses `V = ()`; the
/// hash-map stores payloads).
pub struct LNode<K: Send + Sync + 'static, V: Send + Sync + 'static, R: Reclaimer> {
    key: K,
    value: V,
    next: Atomic<LNode<K, V, R>, R>,
}

impl<K: Send + Sync + 'static, V: Send + Sync + 'static, R: Reclaimer> LNode<K, V, R> {
    pub fn key(&self) -> &K {
        &self.key
    }

    pub fn value(&self) -> &V {
        &self.value
    }
}

/// Result of a `find`: the two traversal shields plus the insertion-point
/// snapshot. `save` pins the node owning the predecessor link (empty when
/// that link is the list head — see [`List::prev_link`]); `cur` pins the
/// first node with `node.key >= key` (on a hit, the found node).
struct FindResult<'h, K, V, R>
where
    K: Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    /// Shield on the node owning the predecessor link.
    save: Guard<'h, LNode<K, V, R>, R>,
    /// Shield on the node at `next` (the found node on a hit).
    cur: Guard<'h, LNode<K, V, R>, R>,
    /// Snapshot of the predecessor link (what an insertion CAS expects).
    next: MarkedPtr<LNode<K, V, R>, R>,
    found: bool,
}

/// Sorted lock-free set/map list under reclamation scheme `R`.
pub struct List<K, V, R>
where
    K: Ord + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    domain: DomainRef<R>,
    head: Atomic<LNode<K, V, R>, R>,
}

impl<K, V, R> Default for List<K, V, R>
where
    K: Ord + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    fn default() -> Self {
        Self::new()
    }
}

impl<K, V, R> List<K, V, R>
where
    K: Ord + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    /// An empty list on the global domain.
    pub const fn new() -> Self {
        Self { domain: DomainRef::global(), head: Atomic::null() }
    }

    /// An empty list whose nodes are retired into `domain`.
    pub fn new_in(domain: DomainRef<R>) -> Self {
        Self { domain, head: Atomic::null() }
    }

    /// The list's reclamation domain.
    pub fn domain(&self) -> &DomainRef<R> {
        &self.domain
    }

    /// The predecessor link for the current traversal position: the
    /// `next` field of the node pinned by `save`, or the list head while
    /// `save` is empty. Re-derived on every use, so the returned reference
    /// is valid by construction (the shield freezes while it is borrowed).
    fn prev_link<'a>(
        &'a self,
        save: &'a Guard<'_, LNode<K, V, R>, R>,
    ) -> &'a Atomic<LNode<K, V, R>, R> {
        match save.shared() {
            Some(s) => &s.get().next,
            None => &self.head,
        }
    }

    /// Paper Listing 1: locate `key`, helping unlink marked nodes on the
    /// way. On return, `save`/`next` define the insertion point and `cur`
    /// pins the first node with `node.key >= key` (if any).
    fn find<'h>(&self, h: &'h LocalHandle<R>, key: &K) -> FindResult<'h, K, V, R> {
        'retry: loop {
            let mut save: Guard<'h, LNode<K, V, R>, R> = Guard::new(h);
            let mut cur: Guard<'h, LNode<K, V, R>, R> = Guard::new(h);
            let mut next = self.head.load(Ordering::Acquire);
            loop {
                // Acquire the snapshot; restart if prev moved under us.
                if cur.try_protect(self.prev_link(&save), next.with_mark(0)).is_err() {
                    continue 'retry;
                }
                if cur.is_empty() {
                    return FindResult { save, cur, next: next.with_mark(0), found: false };
                }
                let cur_shared = cur.shared().expect("non-empty shield");
                let cur_marked = cur_shared.as_marked();
                let cur_node = cur_shared.get();
                let succ = cur_node.next.load(Ordering::Acquire);
                if succ.mark() != 0 {
                    // cur is logically deleted: help splice it out.
                    if self
                        .prev_link(&save)
                        .compare_exchange(
                            cur_marked,
                            succ.with_mark(0),
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_err()
                    {
                        continue 'retry;
                    }
                    // SAFETY: our CAS unlinked cur (Michael's rule: the
                    // unlinking-CAS winner is the unique retirer), and its
                    // readers are protected through this list's domain.
                    unsafe { cur.retire() };
                    next = succ.with_mark(0);
                    continue;
                }
                // Validate prev still points at cur (paper line 15).
                if self.prev_link(&save).load(Ordering::Acquire) != cur_marked {
                    continue 'retry;
                }
                if cur_node.key >= *key {
                    let found = cur_node.key == *key;
                    return FindResult { save, cur, next: cur_marked, found };
                }
                // Advance: the shield that pinned cur becomes `save`
                // (`save = std::move(cur)` in Listing 1), and the freed
                // shield walks on.
                next = succ;
                std::mem::swap(&mut save, &mut cur);
                cur.reset();
            }
        }
    }

    /// Does the set contain `key`?
    pub fn contains(&self, h: impl HandleSource<R>, key: &K) -> bool {
        h.with_source(&self.domain, |h| self.find(h, key).found)
    }

    /// Read the value under `key` through `f` (guarded access — no clone).
    pub fn get<U>(&self, h: impl HandleSource<R>, key: &K, f: impl FnOnce(&V) -> U) -> Option<U> {
        h.with_source(&self.domain, |h| {
            let r = self.find(h, key);
            if !r.found {
                return None;
            }
            // The shield keeps the node protected for the callback.
            r.cur.shared().map(|s| f(&s.get().value))
        })
    }

    /// Insert `key → value` if absent. Returns false (and drops `value`)
    /// when the key already exists.
    pub fn insert(&self, h: impl HandleSource<R>, key: K, value: V) -> bool {
        h.with_source(&self.domain, |h| self.insert_inner(h, key, value))
    }

    fn insert_inner(&self, h: &LocalHandle<R>, key: K, value: V) -> bool {
        let mut node = Owned::<LNode<K, V, R>, R>::new(LNode { key, value, next: Atomic::null() });
        loop {
            let r = self.find(h, &node.key);
            if r.found {
                // Never published: dropping the Owned frees it.
                return false;
            }
            // Still private: link the successor, then publish with a
            // Release CAS on the predecessor link.
            node.next.store(r.next, Ordering::Relaxed);
            match self.prev_link(&r.save).cas_publish(
                r.next,
                node,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err((_, n)) => node = n,
            }
        }
    }

    /// Remove `key`. Returns true if this call removed it.
    pub fn remove(&self, h: impl HandleSource<R>, key: &K) -> bool {
        h.with_source(&self.domain, |h| self.remove_inner(h, key))
    }

    fn remove_inner(&self, h: &LocalHandle<R>, key: &K) -> bool {
        loop {
            let mut r = self.find(h, key);
            if !r.found {
                return false;
            }
            let cur_shared = r.cur.shared().expect("found implies a pinned node");
            let cur_marked = cur_shared.as_marked();
            let cur_node = cur_shared.get();
            let succ = cur_node.next.load(Ordering::Acquire);
            if succ.mark() != 0 {
                continue; // someone else is deleting it; re-find (help)
            }
            // Logical delete: set the mark (the linearization point).
            if cur_node
                .next
                .compare_exchange(succ, succ.with_mark(1), Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            // Physical unlink; on failure find() will clean up later.
            if self
                .prev_link(&r.save)
                .compare_exchange(
                    cur_marked,
                    succ.with_mark(0),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                // SAFETY: we won both the marking CAS and the unlinking
                // CAS, so we are the unique retirer of an unlinked node;
                // readers are protected through this list's domain.
                unsafe { r.cur.retire() };
            } else {
                let _ = self.find(h, key); // helper pass retires it
            }
            return true;
        }
    }

    /// Number of (unmarked) nodes — O(n), diagnostics.
    pub fn len(&self, h: impl HandleSource<R>) -> usize {
        h.with_source(&self.domain, |h| {
            let mut n = 0;
            let mut save: Guard<'_, LNode<K, V, R>, R> = Guard::new(h);
            let mut walk: Guard<'_, LNode<K, V, R>, R> = Guard::new(h);
            loop {
                let Some(node) = walk.protect(self.prev_link(&save)) else {
                    return n;
                };
                if node.next.load(Ordering::Acquire).mark() == 0 {
                    n += 1;
                }
                // Pin the node owning the next prev link; the old pin is
                // released once the swapped-out shield resets.
                std::mem::swap(&mut save, &mut walk);
                walk.reset();
            }
        })
    }

    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Acquire).is_null()
    }
}

impl<K, V, R> Drop for List<K, V, R>
where
    K: Ord + Send + Sync + 'static,
    V: Send + Sync + 'static,
    R: Reclaimer,
{
    fn drop(&mut self) {
        let mut cur = self.head.load(Ordering::Relaxed);
        while !cur.is_null() {
            // SAFETY: `&mut self` proves exclusive access (no concurrent
            // operations, no live shields on these nodes): every node is
            // reachable exactly once and freed exactly once.
            unsafe {
                let next = cur.deref_data().next.load(Ordering::Relaxed);
                crate::reclaim::free_node(cur.get());
                cur = next.with_mark(0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::hp::Hp;
    use crate::reclaim::leaky::Leaky;
    use crate::reclaim::stamp::StampIt;
    use crate::reclaim::Cached;

    #[test]
    fn set_semantics_single_thread() {
        let l: List<u64, (), Leaky> = List::new();
        assert!(!l.contains(Cached, &5));
        assert!(l.insert(Cached, 5, ()));
        assert!(!l.insert(Cached, 5, ()), "duplicate insert must fail");
        assert!(l.insert(Cached, 3, ()));
        assert!(l.insert(Cached, 7, ()));
        assert_eq!(l.len(Cached), 3);
        assert!(l.contains(Cached, &3) && l.contains(Cached, &5) && l.contains(Cached, &7));
        assert!(!l.contains(Cached, &4));
        assert!(l.remove(Cached, &5));
        assert!(!l.remove(Cached, &5), "double remove must fail");
        assert!(!l.contains(Cached, &5));
        assert_eq!(l.len(Cached), 2);
    }

    #[test]
    fn values_accessible_through_get() {
        let l: List<u32, String, Leaky> = List::new();
        l.insert(Cached, 1, "one".to_string());
        l.insert(Cached, 2, "two".to_string());
        assert_eq!(l.get(Cached, &1, |v| v.clone()), Some("one".to_string()));
        assert_eq!(l.get(Cached, &3, |v| v.clone()), None);
    }

    #[test]
    fn cached_and_explicit_handles_interoperate() {
        let l: List<u64, u64, StampIt> = List::new_in(DomainRef::new_owned());
        let h = l.domain().register();
        assert!(l.insert(&h, 1, 10));
        assert!(l.insert(Cached, 2, 20));
        assert_eq!(l.get(&h, &2, |v| *v), Some(20));
        assert_eq!(l.get(Cached, &1, |v| *v), Some(10));
        assert!(l.remove(&h, &2));
        assert_eq!(l.len(&h), 1);
    }

    fn concurrent_set_exercise<R: Reclaimer>() {
        use crate::util::rng::Xoshiro256;
        use std::sync::Arc;
        let l: Arc<List<u64, (), R>> = Arc::new(List::new_in(DomainRef::new_owned()));
        let key_range = 20u64; // paper: key range = 2 × list size (10)
        let threads = 4;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    let h = l.domain().register();
                    let mut rng = Xoshiro256::new(0xD5 + t as u64);
                    for i in 0..3000 {
                        let k = rng.below(key_range);
                        match rng.below(10) {
                            0..=3 => {
                                l.insert(&h, k, ());
                            }
                            4..=7 => {
                                l.remove(&h, &k);
                            }
                            _ => {
                                l.contains(&h, &k);
                            }
                        }
                        if i % 128 == 0 {
                            std::thread::yield_now();
                        }
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        // Structural sanity: strictly sorted, unique keys — a safe-facade
        // walk with the same two-shield dance `find` uses.
        let h = l.domain().register();
        let mut prev_key = None;
        let mut save: Guard<'_, LNode<u64, (), R>, R> = Guard::new(&h);
        let mut walk: Guard<'_, LNode<u64, (), R>, R> = Guard::new(&h);
        loop {
            let Some(node) = walk.protect(l.prev_link(&save)) else {
                break;
            };
            if let Some(p) = prev_key {
                assert!(node.key > p, "keys must be strictly sorted: {} !> {}", node.key, p);
            }
            prev_key = Some(node.key);
            std::mem::swap(&mut save, &mut walk);
            walk.reset();
        }
    }

    #[test]
    fn concurrent_set_under_hp() {
        concurrent_set_exercise::<Hp>();
    }

    #[test]
    fn concurrent_set_under_stamp_it() {
        concurrent_set_exercise::<StampIt>();
    }
}

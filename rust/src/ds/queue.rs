//! Michael–Scott lock-free FIFO queue (paper §4.1: "the queue is based on
//! Michael and Scott's design" [20]), generic over the reclamation scheme.
//!
//! The queue keeps a dummy node: `head` always points at it, values live in
//! the nodes after it. Dequeue advances `head` and retires the old dummy
//! through the reclaimer — this retired-dummy stream is exactly the
//! workload of the paper's Queue benchmark (Figures 3, 8, 12, 16).
//!
//! Written against the safe facade: nodes are allocated as [`Owned`] and
//! published with [`Atomic::cas_publish`], traversal goes through
//! [`Guard`]/[`Shared`], and trusted code remains only in `dequeue` (the
//! unique-dequeuer value take and the dummy's unlink-then-retire site) and
//! the exclusive-access teardown in `Drop`.
//!
//! Every queue belongs to a reclamation [`DomainRef`]: [`Queue::new`] uses
//! the process-wide global domain, [`Queue::new_in`] pins the queue to an
//! owned domain (one per shard/test/trial). Operations take an
//! `impl HandleSource<R>` — [`Cached`](crate::reclaim::Cached) for the
//! one-TLS-lookup quickstart path, or a registered
//! [`&LocalHandle`](crate::reclaim::LocalHandle) for the TLS-free fast
//! path.

use crate::reclaim::{
    Atomic, DomainRef, Guard, HandleSource, LocalHandle, MarkedPtr, Owned, Reclaimer,
};
use std::cell::UnsafeCell;
use std::sync::atomic::Ordering;

/// A queue node: the value is taken (once) by the unique successful
/// dequeuer, hence the `UnsafeCell`.
pub struct QNode<T: Send + Sync + 'static, R: Reclaimer> {
    value: UnsafeCell<Option<T>>,
    next: Atomic<QNode<T, R>, R>,
}

// SAFETY: `value` is accessed mutably only by the single thread whose
// head-CAS succeeded (exclusive by protocol); `next` is an atomic.
unsafe impl<T: Send + Sync + 'static, R: Reclaimer> Sync for QNode<T, R> {}
unsafe impl<T: Send + Sync + 'static, R: Reclaimer> Send for QNode<T, R> {}

/// Michael–Scott queue under reclamation scheme `R`.
pub struct Queue<T: Send + Sync + 'static, R: Reclaimer> {
    domain: DomainRef<R>,
    head: Atomic<QNode<T, R>, R>,
    tail: Atomic<QNode<T, R>, R>,
}

impl<T: Send + Sync + 'static, R: Reclaimer> Default for Queue<T, R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> Queue<T, R> {
    /// An empty queue on the global domain (allocates the dummy node).
    pub fn new() -> Self {
        Self::new_in(DomainRef::global())
    }

    /// An empty queue whose nodes are retired into `domain`.
    pub fn new_in(domain: DomainRef<R>) -> Self {
        let dummy = Owned::<QNode<T, R>, R>::new(QNode {
            value: UnsafeCell::new(None),
            next: Atomic::null(),
        });
        let q = Self { domain, head: Atomic::new(dummy), tail: Atomic::null() };
        // head and tail share the dummy; still constructor-private.
        q.tail.store(q.head.load(Ordering::Relaxed), Ordering::Relaxed);
        q
    }

    /// The queue's reclamation domain.
    pub fn domain(&self) -> &DomainRef<R> {
        &self.domain
    }

    /// Append `value` (lock-free).
    pub fn enqueue(&self, h: impl HandleSource<R>, value: T) {
        h.with_source(&self.domain, |h| self.enqueue_inner(h, value))
    }

    fn enqueue_inner(&self, h: &LocalHandle<R>, value: T) {
        let mut node = Owned::<QNode<T, R>, R>::new(QNode {
            value: UnsafeCell::new(Some(value)),
            next: Atomic::null(),
        });
        let mut tail_guard: Guard<'_, QNode<T, R>, R> = Guard::new(h);
        loop {
            let tail = tail_guard.protect(&self.tail).expect("queue tail is never null");
            let tail_marked = tail.as_marked();
            let next = tail.next.load(Ordering::Acquire);
            if tail_marked != self.tail.load(Ordering::Acquire) {
                continue; // stale snapshot
            }
            if !next.is_null() {
                // Tail lags behind: help advance it.
                let _ = self.tail.compare_exchange(
                    tail_marked,
                    next,
                    Ordering::Release,
                    Ordering::Relaxed,
                );
                continue;
            }
            let published = tail.next.cas_publish(
                MarkedPtr::null(),
                node,
                Ordering::Release,
                Ordering::Relaxed,
            );
            match published {
                Ok(published) => {
                    // Linked; swing tail (failure is fine — someone helped).
                    let _ = self.tail.compare_exchange(
                        tail_marked,
                        published,
                        Ordering::Release,
                        Ordering::Relaxed,
                    );
                    return;
                }
                Err((_, n)) => node = n,
            }
        }
    }

    /// Remove the oldest value (lock-free); `None` when empty.
    pub fn dequeue(&self, h: impl HandleSource<R>) -> Option<T> {
        h.with_source(&self.domain, |h| self.dequeue_inner(h))
    }

    fn dequeue_inner(&self, h: &LocalHandle<R>) -> Option<T> {
        let mut head_guard: Guard<'_, QNode<T, R>, R> = Guard::new(h);
        let mut next_guard: Guard<'_, QNode<T, R>, R> = Guard::new(h);
        loop {
            let head = head_guard.protect(&self.head).expect("queue head is never null");
            let head_marked = head.as_marked();
            let next = next_guard.protect(&head.next);
            if head_marked != self.head.load(Ordering::Acquire) {
                continue;
            }
            let Some(next) = next else {
                return None; // empty
            };
            let tail = self.tail.load(Ordering::Acquire);
            if head.ptr_eq(tail) {
                // Tail lags: help before moving head past it.
                let _ = self.tail.compare_exchange(
                    tail,
                    next.as_marked(),
                    Ordering::Release,
                    Ordering::Relaxed,
                );
                continue;
            }
            let advanced = self.head.compare_exchange(
                head_marked,
                next.as_marked(),
                Ordering::AcqRel,
                Ordering::Relaxed,
            );
            if advanced.is_ok() {
                // SAFETY: our head-CAS succeeded, so we are the unique
                // dequeuer of `next`'s value; `next` is pinned by its
                // shield for the duration of the take.
                let value = unsafe { (*next.get().value.get()).take() };
                debug_assert!(value.is_some());
                // SAFETY: the old dummy is unlinked (head moved past it)
                // and only the successful CASer retires it; its readers
                // are protected through this queue's domain.
                unsafe { head_guard.retire() };
                return value;
            }
        }
    }

    /// Approximate emptiness check.
    pub fn is_empty(&self, h: impl HandleSource<R>) -> bool {
        h.with_source(&self.domain, |h| {
            let mut head_guard: Guard<'_, QNode<T, R>, R> = Guard::new(h);
            let head = head_guard.protect(&self.head).expect("queue head is never null");
            head.next.load(Ordering::Acquire).is_null()
        })
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> Drop for Queue<T, R> {
    fn drop(&mut self) {
        let mut cur = self.head.load(Ordering::Relaxed);
        while !cur.is_null() {
            // SAFETY: `&mut self` proves exclusive access during drop (no
            // concurrent operations, no live shields): the dummy and any
            // remaining nodes are each freed exactly once.
            unsafe {
                let next = cur.deref_data().next.load(Ordering::Relaxed);
                crate::reclaim::free_node(cur.get());
                cur = next;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::ebr::Ebr;
    use crate::reclaim::leaky::Leaky;
    use crate::reclaim::stamp::StampIt;
    use crate::reclaim::Cached;

    #[test]
    fn fifo_order_single_thread() {
        let q: Queue<u64, Leaky> = Queue::new();
        assert!(q.is_empty(Cached));
        assert_eq!(q.dequeue(Cached), None);
        for i in 0..100 {
            q.enqueue(Cached, i);
        }
        assert!(!q.is_empty(Cached));
        for i in 0..100 {
            assert_eq!(q.dequeue(Cached), Some(i));
        }
        assert_eq!(q.dequeue(Cached), None);
    }

    #[test]
    fn values_drop_exactly_once() {
        use crate::reclaim::tests_common::{flush_until, Payload};
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        let domain = DomainRef::<Ebr>::new_owned();
        let drops = Arc::new(AtomicUsize::new(0));
        {
            let q: Queue<Payload, Ebr> = Queue::new_in(domain.clone());
            let h = domain.register();
            for i in 0..50 {
                q.enqueue(&h, Payload::new(i, &drops));
            }
            for _ in 0..20 {
                let v = q.dequeue(&h).unwrap();
                v.read();
            }
            // 20 dequeued values dropped here; 30 remain in the queue.
        }
        // Queue drop frees the rest.
        let h = domain.register();
        flush_until(&h, || drops.load(std::sync::atomic::Ordering::Relaxed) == 50);
        assert_eq!(drops.load(std::sync::atomic::Ordering::Relaxed), 50);
    }

    #[test]
    fn explicit_handle_ops_match_cached_ops() {
        let domain = DomainRef::<StampIt>::new_owned();
        let q: Queue<u64, StampIt> = Queue::new_in(domain.clone());
        let h = domain.register();
        for i in 0..64 {
            q.enqueue(&h, i);
        }
        for i in 0..32 {
            assert_eq!(q.dequeue(&h), Some(i));
        }
        // Mixed: cached-path ops see the same structure.
        for i in 32..64 {
            assert_eq!(q.dequeue(Cached), Some(i));
        }
        assert_eq!(q.dequeue(&h), None);
    }

    fn mpmc_exercise<R: Reclaimer>() {
        use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
        use std::sync::Arc;
        let q: Arc<Queue<u64, R>> = Arc::new(Queue::new_in(DomainRef::new_owned()));
        let producers = 3;
        let consumers = 3;
        let per = 2000u64;
        let sum_in: u64 = (0..producers as u64 * per).sum();
        let sum_out = Arc::new(AtomicU64::new(0));
        let count_out = Arc::new(AtomicUsize::new(0));

        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let h = q.domain().register();
                for i in 0..per {
                    q.enqueue(&h, p as u64 * per + i);
                    if i % 64 == 0 {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for _ in 0..consumers {
            let q = q.clone();
            let sum_out = sum_out.clone();
            let count_out = count_out.clone();
            let total = producers as usize * per as usize;
            handles.push(std::thread::spawn(move || {
                let h = q.domain().register();
                loop {
                    if count_out.load(Ordering::Relaxed) >= total {
                        break;
                    }
                    match q.dequeue(&h) {
                        Some(v) => {
                            sum_out.fetch_add(v, Ordering::Relaxed);
                            count_out.fetch_add(1, Ordering::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(count_out.load(Ordering::Relaxed), producers as usize * per as usize);
        assert_eq!(sum_out.load(Ordering::Relaxed), sum_in, "every value exactly once");
        let h = q.domain().register();
        assert!(q.is_empty(&h));
    }

    #[test]
    fn mpmc_under_ebr() {
        mpmc_exercise::<Ebr>();
    }

    #[test]
    fn mpmc_under_stamp_it() {
        mpmc_exercise::<StampIt>();
    }
}

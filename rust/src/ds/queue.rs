//! Michael–Scott lock-free FIFO queue (paper §4.1: "the queue is based on
//! Michael and Scott's design" [20]), generic over the reclamation scheme.
//!
//! The queue keeps a dummy node: `head` always points at it, values live in
//! the nodes after it. Dequeue advances `head` and retires the old dummy
//! through the reclaimer — this retired-dummy stream is exactly the
//! workload of the paper's Queue benchmark (Figures 3, 8, 12, 16).
//!
//! Every queue belongs to a reclamation [`DomainRef`]: [`Queue::new`] uses
//! the process-wide global domain (quickstart one-liner), [`Queue::new_in`]
//! pins the queue to an owned domain (one per shard/test/trial). The
//! `*_with` operation variants take an explicit [`LocalHandle`] — the
//! TLS-free fast path; the plain variants resolve the thread's cached
//! handle once per call.

use crate::reclaim::{
    alloc_node, ConcurrentPtr, DomainRef, GuardPtr, LocalHandle, MarkedPtr, Reclaimer,
};
use std::cell::UnsafeCell;
use std::sync::atomic::Ordering;

/// A queue node: the value is taken (once) by the unique successful
/// dequeuer, hence the `UnsafeCell`.
pub struct QNode<T: Send + Sync + 'static, R: Reclaimer> {
    value: UnsafeCell<Option<T>>,
    next: ConcurrentPtr<QNode<T, R>, R>,
}

// SAFETY: `value` is accessed mutably only by the single thread whose
// head-CAS succeeded (exclusive by protocol); `next` is an atomic.
unsafe impl<T: Send + Sync + 'static, R: Reclaimer> Sync for QNode<T, R> {}
unsafe impl<T: Send + Sync + 'static, R: Reclaimer> Send for QNode<T, R> {}

/// Michael–Scott queue under reclamation scheme `R`.
pub struct Queue<T: Send + Sync + 'static, R: Reclaimer> {
    domain: DomainRef<R>,
    head: ConcurrentPtr<QNode<T, R>, R>,
    tail: ConcurrentPtr<QNode<T, R>, R>,
}

impl<T: Send + Sync + 'static, R: Reclaimer> Default for Queue<T, R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> Queue<T, R> {
    /// An empty queue on the global domain (allocates the dummy node).
    pub fn new() -> Self {
        Self::new_in(DomainRef::global())
    }

    /// An empty queue whose nodes are retired into `domain`.
    pub fn new_in(domain: DomainRef<R>) -> Self {
        let dummy = alloc_node::<QNode<T, R>, R>(QNode {
            value: UnsafeCell::new(None),
            next: ConcurrentPtr::null(),
        });
        let p = MarkedPtr::new(dummy, 0);
        Self { domain, head: ConcurrentPtr::new(p), tail: ConcurrentPtr::new(p) }
    }

    /// The queue's reclamation domain.
    pub fn domain(&self) -> &DomainRef<R> {
        &self.domain
    }

    /// Append `value` (lock-free).
    pub fn enqueue(&self, value: T) {
        self.domain.with_handle(|h| self.enqueue_with(h, value))
    }

    /// [`Self::enqueue`] through an explicit handle (no TLS).
    pub fn enqueue_with(&self, h: &LocalHandle<R>, value: T) {
        let node = alloc_node::<QNode<T, R>, R>(QNode {
            value: UnsafeCell::new(Some(value)),
            next: ConcurrentPtr::null(),
        });
        let node_ptr = MarkedPtr::new(node, 0);
        let mut tail_guard: GuardPtr<QNode<T, R>, R> = h.guard();
        loop {
            let tail = tail_guard.acquire(&self.tail);
            debug_assert!(!tail.is_null());
            // SAFETY: tail is guarded.
            let tail_node = unsafe { tail.deref_data() };
            let next = tail_node.next.load(Ordering::Acquire);
            if tail != self.tail.load(Ordering::Acquire) {
                continue; // stale snapshot
            }
            if !next.is_null() {
                // Tail lags behind: help advance it.
                let _ =
                    self.tail.compare_exchange(tail, next, Ordering::Release, Ordering::Relaxed);
                continue;
            }
            if tail_node
                .next
                .compare_exchange(MarkedPtr::null(), node_ptr, Ordering::Release, Ordering::Relaxed)
                .is_ok()
            {
                // Linked; swing tail (failure is fine — someone helped).
                let _ = self.tail.compare_exchange(
                    tail,
                    node_ptr,
                    Ordering::Release,
                    Ordering::Relaxed,
                );
                return;
            }
        }
    }

    /// Remove the oldest value (lock-free); `None` when empty.
    pub fn dequeue(&self) -> Option<T> {
        self.domain.with_handle(|h| self.dequeue_with(h))
    }

    /// [`Self::dequeue`] through an explicit handle (no TLS).
    pub fn dequeue_with(&self, h: &LocalHandle<R>) -> Option<T> {
        let mut head_guard: GuardPtr<QNode<T, R>, R> = h.guard();
        let mut next_guard: GuardPtr<QNode<T, R>, R> = h.guard();
        loop {
            let head = head_guard.acquire(&self.head);
            debug_assert!(!head.is_null());
            // SAFETY: head is guarded.
            let head_node = unsafe { head.deref_data() };
            let next = next_guard.acquire(&head_node.next);
            if head != self.head.load(Ordering::Acquire) {
                continue;
            }
            if next.is_null() {
                return None; // empty
            }
            let tail = self.tail.load(Ordering::Acquire);
            if head.get() == tail.get() {
                // Tail lags: help before moving head past it.
                let _ =
                    self.tail.compare_exchange(tail, next, Ordering::Release, Ordering::Relaxed);
                continue;
            }
            if self.head.compare_exchange(head, next, Ordering::AcqRel, Ordering::Relaxed).is_ok() {
                // SAFETY: our CAS succeeded, so we are the unique dequeuer
                // of `next`'s value; next is guarded.
                let value = unsafe { (*next.deref_data().value.get()).take() };
                debug_assert!(value.is_some());
                // SAFETY: the old dummy is unlinked (head moved past it);
                // only the successful CASer retires it.
                unsafe { head_guard.reclaim() };
                return value;
            }
        }
    }

    /// Approximate emptiness check.
    pub fn is_empty(&self) -> bool {
        self.domain.with_handle(|h| {
            let mut head_guard: GuardPtr<QNode<T, R>, R> = h.guard();
            let head = head_guard.acquire(&self.head);
            // SAFETY: guarded.
            unsafe { head.deref_data().next.load(Ordering::Acquire).is_null() }
        })
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> Drop for Queue<T, R> {
    fn drop(&mut self) {
        // Exclusive access: free the dummy and any remaining nodes
        // directly (no retire round-trip needed).
        let mut cur = self.head.load(Ordering::Relaxed);
        while !cur.is_null() {
            // SAFETY: exclusive access during drop.
            unsafe {
                let next = cur.deref_data().next.load(Ordering::Relaxed);
                crate::reclaim::free_node(cur.get());
                cur = next;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::ebr::Ebr;
    use crate::reclaim::leaky::Leaky;
    use crate::reclaim::stamp::StampIt;

    #[test]
    fn fifo_order_single_thread() {
        let q: Queue<u64, Leaky> = Queue::new();
        assert!(q.is_empty());
        assert_eq!(q.dequeue(), None);
        for i in 0..100 {
            q.enqueue(i);
        }
        assert!(!q.is_empty());
        for i in 0..100 {
            assert_eq!(q.dequeue(), Some(i));
        }
        assert_eq!(q.dequeue(), None);
    }

    #[test]
    fn values_drop_exactly_once() {
        use crate::reclaim::tests_common::{flush_until, Payload};
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        let domain = DomainRef::<Ebr>::new_owned();
        let drops = Arc::new(AtomicUsize::new(0));
        {
            let q: Queue<Payload, Ebr> = Queue::new_in(domain.clone());
            for i in 0..50 {
                q.enqueue(Payload::new(i, &drops));
            }
            for _ in 0..20 {
                let v = q.dequeue().unwrap();
                v.read();
            }
            // 20 dequeued values dropped here; 30 remain in the queue.
        }
        // Queue drop frees the rest.
        let h = domain.register();
        flush_until(&h, || drops.load(std::sync::atomic::Ordering::Relaxed) == 50);
        assert_eq!(drops.load(std::sync::atomic::Ordering::Relaxed), 50);
    }

    #[test]
    fn explicit_handle_ops_match_tls_ops() {
        let domain = DomainRef::<StampIt>::new_owned();
        let q: Queue<u64, StampIt> = Queue::new_in(domain.clone());
        let h = domain.register();
        for i in 0..64 {
            q.enqueue_with(&h, i);
        }
        for i in 0..32 {
            assert_eq!(q.dequeue_with(&h), Some(i));
        }
        // Mixed: TLS-path ops see the same structure.
        for i in 32..64 {
            assert_eq!(q.dequeue(), Some(i));
        }
        assert_eq!(q.dequeue_with(&h), None);
    }

    fn mpmc_exercise<R: Reclaimer>() {
        use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
        use std::sync::Arc;
        let q: Arc<Queue<u64, R>> = Arc::new(Queue::new_in(DomainRef::new_owned()));
        let producers = 3;
        let consumers = 3;
        let per = 2000u64;
        let sum_in: u64 = (0..producers as u64 * per).sum();
        let sum_out = Arc::new(AtomicU64::new(0));
        let count_out = Arc::new(AtomicUsize::new(0));

        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let h = q.domain().register();
                for i in 0..per {
                    q.enqueue_with(&h, p as u64 * per + i);
                    if i % 64 == 0 {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for _ in 0..consumers {
            let q = q.clone();
            let sum_out = sum_out.clone();
            let count_out = count_out.clone();
            let total = producers as usize * per as usize;
            handles.push(std::thread::spawn(move || {
                let h = q.domain().register();
                loop {
                    if count_out.load(Ordering::Relaxed) >= total {
                        break;
                    }
                    match q.dequeue_with(&h) {
                        Some(v) => {
                            sum_out.fetch_add(v, Ordering::Relaxed);
                            count_out.fetch_add(1, Ordering::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(count_out.load(Ordering::Relaxed), producers as usize * per as usize);
        assert_eq!(sum_out.load(Ordering::Relaxed), sum_in, "every value exactly once");
        assert!(q.is_empty());
    }

    #[test]
    fn mpmc_under_ebr() {
        mpmc_exercise::<Ebr>();
    }

    #[test]
    fn mpmc_under_stamp_it() {
        mpmc_exercise::<StampIt>();
    }
}

//! QSR — quiescent-state-based reclamation (McKenney & Slingwine 1998,
//! RCU-style), as set up in the paper (§4.2): the thread "executes a fuzzy
//! barrier when it exits the critical region" — i.e. region exit is the
//! quiescent state at which the thread announces the current epoch.
//!
//! Characteristics reproduced from the paper:
//!
//! * region entry is nearly free (no announcement, no fence) — QSR has the
//!   cheapest guards of all schemes;
//! * a registered thread that stops passing quiescent states (idle, long
//!   region, or busy elsewhere) blocks reclamation in its domain — the
//!   reason QSR "basically fails completely to reliably reclaim nodes" in
//!   the update-heavy HashMap benchmark (paper App. A.2).

use super::epoch_core::{epoch_reclaimer_impl, EpochConfig, EpochDomain};
use super::Domain;

/// Quiescent-state-based reclamation.
pub struct Qsr;

epoch_reclaimer_impl!(
    Qsr,
    "QSR",
    EpochConfig {
        // With quiescent_at_exit, `advance_every` counts quiescent passes
        // between advance attempts; the fuzzy barrier itself is every exit.
        advance_every: 1,
        debra_check_every: None,
        quiescent_at_exit: true,
    }
);

/// The global domain's epoch state (benchmark diagnostics / ablations).
pub fn domain() -> &'static EpochDomain {
    Domain::<Qsr>::global().state()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;

    #[test]
    fn nodes_reclaimed_after_quiescent_states() {
        exercise_basic_reclamation::<Qsr>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        exercise_guard_blocks_reclamation::<Qsr>();
    }

    #[test]
    fn region_guard_amortizes_and_protects() {
        exercise_region_guard::<Qsr>();
    }

    #[test]
    fn concurrent_smoke() {
        exercise_concurrent_smoke::<Qsr>(4, 500);
    }
}

//! `marked_ptr` (paper §2): a pointer to a reclaimable [`Node`] that borrows
//! its low-order bits for marks — the "pointer mark tricks" lock-free
//! algorithms rely on (Harris-style delete marks etc.).
//!
//! Two bits are available (nodes are ≥ 8-byte aligned); the data structures
//! in this crate use bit 0 as the Harris delete mark.

use super::{Node, Reclaimer};
use std::fmt;
use std::marker::PhantomData;

/// Number of borrowable low-order bits.
pub const MARK_BITS: u32 = 2;
const MARK_MASK: usize = (1 << MARK_BITS) - 1;

/// A (possibly marked) pointer to a `Node<T, R>`. Plain value type — copies
/// freely, conveys no protection by itself (that is the job of the facade
/// [`Guard`]/[`Shared`] pair).
///
/// [`Guard`]: super::facade::Guard
/// [`Shared`]: super::facade::Shared
pub struct MarkedPtr<T, R: Reclaimer> {
    raw: usize,
    _phantom: PhantomData<*mut Node<T, R>>,
}

impl<T, R: Reclaimer> MarkedPtr<T, R> {
    /// The null pointer (mark 0).
    #[inline]
    pub const fn null() -> Self {
        Self { raw: 0, _phantom: PhantomData }
    }

    /// Construct from a node pointer and mark bits.
    #[inline]
    pub fn new(ptr: *mut Node<T, R>, mark: usize) -> Self {
        debug_assert_eq!(ptr as usize & MARK_MASK, 0, "node pointer under-aligned");
        debug_assert!(mark <= MARK_MASK);
        Self { raw: ptr as usize | mark, _phantom: PhantomData }
    }

    /// Reconstruct from the raw word of a [`ConcurrentPtr`].
    ///
    /// [`ConcurrentPtr`]: super::ConcurrentPtr
    #[inline]
    pub(crate) const fn from_raw(raw: usize) -> Self {
        Self { raw, _phantom: PhantomData }
    }

    #[inline]
    pub(crate) const fn into_raw(self) -> usize {
        self.raw
    }

    /// The raw pointer with mark bits stripped (paper: `get`).
    #[inline]
    pub fn get(self) -> *mut Node<T, R> {
        (self.raw & !MARK_MASK) as *mut Node<T, R>
    }

    /// The mark bits (paper: `mark`).
    #[inline]
    pub fn mark(self) -> usize {
        self.raw & MARK_MASK
    }

    /// Same pointer with different mark bits.
    #[inline]
    pub fn with_mark(self, mark: usize) -> Self {
        debug_assert!(mark <= MARK_MASK);
        Self::from_raw((self.raw & !MARK_MASK) | mark)
    }

    /// True when the stripped pointer is null (whatever the mark).
    #[inline]
    pub fn is_null(self) -> bool {
        self.get().is_null()
    }

    /// Shared reference to the node.
    ///
    /// # Safety
    /// The node must be live and protected (by a guard or by exclusive
    /// access) for the lifetime of the reference, and non-null.
    #[inline]
    pub unsafe fn as_node<'a>(self) -> &'a Node<T, R> {
        debug_assert!(!self.is_null());
        &*self.get()
    }

    /// Shared reference to the node's payload.
    ///
    /// # Safety
    /// Same contract as [`Self::as_node`].
    #[inline]
    pub unsafe fn deref_data<'a>(self) -> &'a T {
        self.as_node().data()
    }
}

// Manual impls: `derive` would add unwanted `T: Copy`-style bounds.
impl<T, R: Reclaimer> Copy for MarkedPtr<T, R> {}
impl<T, R: Reclaimer> Clone for MarkedPtr<T, R> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T, R: Reclaimer> PartialEq for MarkedPtr<T, R> {
    fn eq(&self, other: &Self) -> bool {
        self.raw == other.raw
    }
}
impl<T, R: Reclaimer> Eq for MarkedPtr<T, R> {}
impl<T, R: Reclaimer> Default for MarkedPtr<T, R> {
    fn default() -> Self {
        Self::null()
    }
}
impl<T, R: Reclaimer> fmt::Debug for MarkedPtr<T, R> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MarkedPtr({:p}|{})", self.get(), self.mark())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::leaky::Leaky;

    type P = MarkedPtr<u64, Leaky>;

    #[test]
    fn null_roundtrip() {
        let p = P::null();
        assert!(p.is_null());
        assert_eq!(p.mark(), 0);
        assert_eq!(p, P::default());
    }

    #[test]
    fn mark_roundtrip() {
        let node = crate::reclaim::alloc_node::<u64, Leaky>(7);
        let p = P::new(node, 1);
        assert_eq!(p.get(), node);
        assert_eq!(p.mark(), 1);
        assert_eq!(p.with_mark(0).mark(), 0);
        assert_eq!(p.with_mark(3).mark(), 3);
        assert_eq!(p.with_mark(3).get(), node);
        assert!(!p.is_null());
        unsafe {
            assert_eq!(*p.deref_data(), 7);
            crate::reclaim::free_node(node);
        }
    }

    #[test]
    fn equality_includes_mark() {
        let node = crate::reclaim::alloc_node::<u64, Leaky>(1);
        let a = P::new(node, 0);
        let b = P::new(node, 1);
        assert_ne!(a, b);
        assert_eq!(a, b.with_mark(0));
        unsafe { crate::reclaim::free_node(node) };
    }
}

//! The **safe SMR facade**: lifetime-branded, misuse-resistant types over
//! the raw `guard_ptr` layer (see DESIGN.md §2 for the layering).
//!
//! The paper's N3712-style interface ([`ConcurrentPtr`] / `GuardPtr`) is
//! faithful but raw: every data structure juggles bare [`MarkedPtr`] words
//! and carries `unsafe` at each dereference. This module rebuilds that
//! surface in the style of `crossbeam-epoch`'s `Guard`/`Shared`, adapted to
//! the per-domain [`LocalHandle`] model:
//!
//! * [`Atomic<T, R>`] — a typed atomic marked pointer, the link word of a
//!   lock-free structure (replaces bare `ConcurrentPtr` use in ds code).
//! * [`Guard`] — a **reusable shield** created from a [`LocalHandle`]
//!   ([`LocalHandle::guard`]). One guard is re-aimed at node after node in
//!   a hot loop, so the hazard-slot / region-token amortization of the
//!   paper's Listing 1 is preserved (no per-acquire registration cost).
//! * [`Shared<'g, T, R>`] — a **non-null, mark-carrying protected
//!   pointer** whose lifetime `'g` is branded by the borrow of its guard:
//!   safe code cannot hold a node reference past the protection that makes
//!   it valid. Dereferencing is *safe* — the brand is the proof.
//! * [`Owned<T, R>`] — a uniquely-owned, **unpublished** node (replaces
//!   raw `alloc_node` / `free_node` at ds level). Dropping an `Owned`
//!   frees the node; publishing it ([`Atomic::cas_publish`]) transfers
//!   ownership to the structure.
//! * [`HandleSource`] — collapses the old `op` / `op_with(handle)` method
//!   duplication: every data-structure operation takes one
//!   `impl HandleSource<R>` argument, which is either [`Cached`] (resolve
//!   the calling thread's cached handle — the quickstart path) or a
//!   borrowed [`&LocalHandle`](LocalHandle) (the TLS-free fast path).
//!
//! ## What stays `unsafe`, and why
//!
//! Exactly one obligation cannot be expressed in the type system: *a node
//! may be retired only after it has been unlinked* (no new references can
//! be created from any [`Atomic`]), and only once. That is
//! [`Guard::retire`] / [`LocalHandle::retire`] — the unlink-then-retire
//! sites in the data structures, each carrying a one-line `// SAFETY:`
//! argument. Everything else (allocation, publication, traversal,
//! dereference, unpublished-node disposal) is safe. Note the standard SMR
//! caveat, documented on [`Atomic::store`]: pointer *values* are treated
//! as data, so the structure-wide reachability invariant is discharged at
//! the retire sites, not re-checked per store (DESIGN.md §2.3).

use std::marker::PhantomData;
use std::sync::atomic::Ordering;

use super::domain::{DomainRef, LocalHandle};
use super::{alloc_node, free_node, ConcurrentPtr, GuardPtr, MarkedPtr, Node, Reclaimer};

// ---------------------------------------------------------------------------
// Atomic
// ---------------------------------------------------------------------------

/// A typed atomic marked pointer — the link word of a lock-free structure.
///
/// `Atomic` stores [`MarkedPtr`] *values*; a value conveys no protection
/// and cannot be dereferenced. Protected access goes through a
/// [`Guard`] ([`Guard::protect`] / [`Guard::try_protect`]), which yields a
/// branded [`Shared`].
pub struct Atomic<T: Send + Sync + 'static, R: Reclaimer> {
    inner: ConcurrentPtr<T, R>,
}

impl<T: Send + Sync + 'static, R: Reclaimer> Atomic<T, R> {
    /// A null link.
    pub const fn null() -> Self {
        Self { inner: ConcurrentPtr::null() }
    }

    /// A link initialized to a freshly published node (constructor-time
    /// publication; ownership moves into the structure).
    pub fn new(node: Owned<T, R>) -> Self {
        Self { inner: ConcurrentPtr::new(MarkedPtr::new(node.into_raw(), 0)) }
    }

    /// Snapshot the current value (pointer + mark). The snapshot is plain
    /// data: comparable, storable, not dereferenceable.
    #[inline]
    pub fn load(&self, order: Ordering) -> MarkedPtr<T, R> {
        self.inner.load(order)
    }

    /// Store a pointer value.
    ///
    /// Safe under the facade's invariant (DESIGN.md §2.3): the only values
    /// a structure stores are null, just-published [`Owned`]s, and
    /// snapshots of pointers still reachable from the same structure —
    /// and a node stops being reachable only at its (unsafe, argued)
    /// retire site. Storing a pointer to an already-retired node would
    /// violate that retire site's safety argument, not this method's.
    #[inline]
    pub fn store(&self, value: MarkedPtr<T, R>, order: Ordering) {
        self.inner.store(value, order)
    }

    /// Single-word CAS on the (pointer, mark) value; returns the witness
    /// value on failure. Same invariant note as [`Self::store`].
    #[inline]
    pub fn compare_exchange(
        &self,
        expected: MarkedPtr<T, R>,
        desired: MarkedPtr<T, R>,
        success: Ordering,
        failure: Ordering,
    ) -> Result<(), MarkedPtr<T, R>> {
        self.inner.compare_exchange(expected, desired, success, failure)
    }

    /// Weak CAS variant for retry loops.
    #[inline]
    pub fn compare_exchange_weak(
        &self,
        expected: MarkedPtr<T, R>,
        desired: MarkedPtr<T, R>,
        success: Ordering,
        failure: Ordering,
    ) -> Result<(), MarkedPtr<T, R>> {
        self.inner.compare_exchange_weak(expected, desired, success, failure)
    }

    /// Atomically set mark bits (Harris delete marks), returning the
    /// previous value.
    #[inline]
    pub fn fetch_mark(&self, mark: usize, order: Ordering) -> MarkedPtr<T, R> {
        self.inner.fetch_mark(mark, order)
    }

    /// Publish an unpublished node: CAS `expected → node`. On success the
    /// node's ownership transfers to the structure and the published
    /// pointer is returned; on failure the witness value and the
    /// still-owned node are handed back for the retry loop.
    pub fn cas_publish(
        &self,
        expected: MarkedPtr<T, R>,
        node: Owned<T, R>,
        success: Ordering,
        failure: Ordering,
    ) -> Result<MarkedPtr<T, R>, (MarkedPtr<T, R>, Owned<T, R>)> {
        let desired = MarkedPtr::new(node.as_raw(), 0);
        match self.inner.compare_exchange(expected, desired, success, failure) {
            Ok(()) => {
                // Ownership moved into the structure: skip Owned's drop.
                std::mem::forget(node);
                Ok(desired)
            }
            Err(witness) => Err((witness, node)),
        }
    }

    /// The raw N3712 `concurrent_ptr` underneath (scheme-layer plumbing
    /// and the micro_region facade-overhead gate).
    #[inline]
    pub(crate) fn raw(&self) -> &ConcurrentPtr<T, R> {
        &self.inner
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> Default for Atomic<T, R> {
    fn default() -> Self {
        Self::null()
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> std::fmt::Debug for Atomic<T, R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Atomic({:?})", self.load(Ordering::Relaxed))
    }
}

// ---------------------------------------------------------------------------
// Owned
// ---------------------------------------------------------------------------

/// A uniquely-owned, unpublished node. The safe replacement for raw
/// `alloc_node` / `free_node` at data-structure level:
///
/// * [`Owned::new`] allocates (policy-routed, counted — see
///   [`crate::alloc`]);
/// * dropping an `Owned` frees the node (it was never published, so no
///   reclamation protocol is needed);
/// * [`Atomic::cas_publish`] / [`Atomic::new`] transfer ownership into a
///   structure;
/// * [`LocalHandle::retire_owned`] retires an unpublished node safely
///   (trivially "unlinked").
pub struct Owned<T: Send + Sync + 'static, R: Reclaimer> {
    node: *mut Node<T, R>,
}

// SAFETY: an Owned is exclusive ownership of a private (unpublished) node;
// moving it between threads moves the T, so T's own Send + Sync bounds
// (already required for reclaimable payloads) are what governs.
unsafe impl<T: Send + Sync + 'static, R: Reclaimer> Send for Owned<T, R> {}
unsafe impl<T: Send + Sync + 'static, R: Reclaimer> Sync for Owned<T, R> {}

impl<T: Send + Sync + 'static, R: Reclaimer> Owned<T, R> {
    /// Allocate a fresh, private node holding `data`.
    pub fn new(data: T) -> Self {
        Self { node: alloc_node::<T, R>(data) }
    }

    /// The raw node pointer, ownership retained.
    #[inline]
    fn as_raw(&self) -> *mut Node<T, R> {
        self.node
    }

    /// The raw node pointer, ownership released (no drop).
    #[inline]
    pub(crate) fn into_raw(self) -> *mut Node<T, R> {
        let p = self.node;
        std::mem::forget(self);
        p
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> std::ops::Deref for Owned<T, R> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        // SAFETY: the node is private to this Owned (unpublished), fully
        // initialized by alloc_node, and live until into_raw/drop.
        unsafe { (*self.node).data() }
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> Drop for Owned<T, R> {
    fn drop(&mut self) {
        // SAFETY: still unpublished (cas_publish/into_raw forget self), so
        // no other thread can reference the node; freed exactly once.
        unsafe { free_node(self.node) }
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> std::fmt::Debug for Owned<T, R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Owned({:p})", self.node)
    }
}

// ---------------------------------------------------------------------------
// Guard + Shared
// ---------------------------------------------------------------------------

/// A reusable protection shield attached to one [`LocalHandle`] (and, by
/// the `'h` brand, unable to outlive it).
///
/// A guard is aimed at nodes with [`Guard::protect`] /
/// [`Guard::try_protect`] and re-aimed freely — the underlying hazard slot
/// or region token is acquired once and reused, which is what keeps hot
/// loops at the amortized cost the paper's Listing 1 relies on. Protected
/// access comes back as a [`Shared`] branded by the borrow of the guard:
/// while any `Shared` from a guard is alive, every operation that could
/// drop the protection (`protect`, `reset`, `retire`, moving the guard)
/// is rejected by the borrow checker.
pub struct Guard<'h, T: Send + Sync + 'static, R: Reclaimer> {
    inner: GuardPtr<T, R>,
    _handle: PhantomData<&'h LocalHandle<R>>,
}

impl<'h, T: Send + Sync + 'static, R: Reclaimer> Guard<'h, T, R> {
    /// An empty shield attached to `handle` (alias:
    /// [`LocalHandle::guard`]).
    pub fn new(handle: &'h LocalHandle<R>) -> Self {
        Self { inner: GuardPtr::new_in(handle), _handle: PhantomData }
    }

    /// Atomically snapshot `src` and protect the target (paper:
    /// `guard_ptr::acquire`). Returns the protected node, or `None` when
    /// the link was null (mark bits of a null snapshot carry no node).
    /// Any previous protection is dropped first.
    #[inline]
    pub fn protect(&mut self, src: &Atomic<T, R>) -> Option<Shared<'_, T, R>> {
        let p = self.inner.acquire(src.raw());
        (!p.is_null()).then_some(Shared { ptr: p, _guard: PhantomData })
    }

    /// Protect only if `src` still holds `expected` (paper:
    /// `guard_ptr::acquire_if_equal`; never loops unboundedly — wait-free
    /// for HPR). On `Ok` the guard protects `expected` (empty if
    /// `expected` was null) — read it back with [`Self::shared`]. On
    /// [`Stale`] the guard is left empty and the caller restarts.
    #[inline]
    pub fn try_protect(
        &mut self,
        src: &Atomic<T, R>,
        expected: MarkedPtr<T, R>,
    ) -> Result<(), Stale> {
        if self.inner.acquire_if_equal(src.raw(), expected) {
            Ok(())
        } else {
            Err(Stale)
        }
    }

    /// The currently protected node, if any — a re-borrow that keeps the
    /// guard frozen (immutably) while the `Shared` is alive.
    #[inline]
    pub fn shared(&self) -> Option<Shared<'_, T, R>> {
        let p = self.inner.get();
        (!p.is_null()).then_some(Shared { ptr: p, _guard: PhantomData })
    }

    /// Raw snapshot of the guarded value (null when empty; mark bits
    /// preserved from acquire time). Plain data, not dereferenceable.
    #[inline]
    pub fn marked(&self) -> MarkedPtr<T, R> {
        self.inner.get()
    }

    /// Is the shield currently empty?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.inner.is_null()
    }

    /// Drop the current protection; the shield stays usable (paper:
    /// `guard_ptr::reset`).
    #[inline]
    pub fn reset(&mut self) {
        self.inner.reset()
    }

    /// Retire the protected node into the handle's domain and reset the
    /// shield (paper: `guard_ptr::reclaim`). This — together with
    /// [`LocalHandle::retire`] — is the facade's *only* unsafe surface.
    ///
    /// # Safety
    /// The protected node must be **unlinked** (no new reference can be
    /// created from any [`Atomic`] of the structure), and across all
    /// threads exactly one call site retires it (typically: the winner of
    /// the unlinking CAS). The node's readers must be protected through
    /// the same domain this guard's handle is registered with.
    pub unsafe fn retire(&mut self) {
        self.inner.reclaim()
    }
}

/// `try_protect` lost the race: the link no longer holds the expected
/// value. Restart the traversal.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Stale;

/// A non-null, mark-carrying pointer to a node that is **protected** for
/// the lifetime `'g` — the borrow of the [`Guard`] (or [`Owned`]-free
/// exclusive context) that produced it. Because every protection-dropping
/// guard operation needs `&mut Guard`, no `Shared` can witness its node
/// unprotected: dereferencing is safe.
pub struct Shared<'g, T: Send + Sync + 'static, R: Reclaimer> {
    ptr: MarkedPtr<T, R>,
    _guard: PhantomData<&'g ()>,
}

// Manual impls: `derive` would bound `T: Copy`/`T: Clone`.
impl<T: Send + Sync + 'static, R: Reclaimer> Copy for Shared<'_, T, R> {}
impl<T: Send + Sync + 'static, R: Reclaimer> Clone for Shared<'_, T, R> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<'g, T: Send + Sync + 'static, R: Reclaimer> Shared<'g, T, R> {
    /// Borrow the node's payload for the whole protected lifetime `'g`.
    #[inline]
    pub fn get(self) -> &'g T {
        // SAFETY: the `'g` brand ties this reference to a live borrow of
        // the guard that protects the node; protection cannot be dropped
        // (all dropping operations take `&mut Guard`) while 'g is alive.
        unsafe { self.ptr.deref_data() }
    }

    /// The (pointer, mark) value — plain data for CAS arguments.
    #[inline]
    pub fn as_marked(self) -> MarkedPtr<T, R> {
        self.ptr
    }

    /// The acquire-time mark bits (bit 0 = Harris delete mark).
    #[inline]
    pub fn mark(self) -> usize {
        self.ptr.mark()
    }

    /// Does this point at the same node as `other` (marks ignored)?
    #[inline]
    pub fn ptr_eq(self, other: MarkedPtr<T, R>) -> bool {
        self.ptr.get() == other.get()
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> std::ops::Deref for Shared<'_, T, R> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        (*self).get()
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> std::fmt::Debug for Shared<'_, T, R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Shared({:?})", self.ptr)
    }
}

// ---------------------------------------------------------------------------
// HandleSource
// ---------------------------------------------------------------------------

/// How a data-structure operation obtains the per-thread [`LocalHandle`]
/// it runs under — the single generic entry point that replaces the old
/// `op()` / `op_with(handle)` method pairs.
///
/// Two sources exist:
/// * [`Cached`] — resolve the calling thread's cached handle for the
///   structure's domain (one TLS lookup; the quickstart path);
/// * `&LocalHandle<R>` — a handle the caller registered explicitly
///   (TLS-free; the hot-loop path). Debug builds assert it belongs to the
///   structure's domain.
pub trait HandleSource<R: Reclaimer>: Copy {
    /// Run `f` with a handle registered to `domain`.
    fn with_source<O>(self, domain: &DomainRef<R>, f: impl FnOnce(&LocalHandle<R>) -> O) -> O;
}

/// Resolve the calling thread's cached handle for the structure's domain
/// (registering on first use): `queue.enqueue(Cached, v)`.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct Cached;

impl<R: Reclaimer> HandleSource<R> for Cached {
    #[inline]
    fn with_source<O>(self, domain: &DomainRef<R>, f: impl FnOnce(&LocalHandle<R>) -> O) -> O {
        domain.with_handle(f)
    }
}

impl<R: Reclaimer> HandleSource<R> for &LocalHandle<R> {
    #[inline]
    fn with_source<O>(self, domain: &DomainRef<R>, f: impl FnOnce(&LocalHandle<R>) -> O) -> O {
        debug_assert!(
            std::ptr::eq(self.domain(), domain.domain()),
            "handle registered with a different domain than the structure's"
        );
        f(self)
    }
}

// ---------------------------------------------------------------------------
// Guard-across-await lint
// ---------------------------------------------------------------------------

/// Detects a [`Guard`] (or raw `GuardPtr`) held across an executor park.
///
/// A parked task that keeps a guard alive is the stall adversary E19
/// measures: for epoch schemes it blocks reclamation *domain-wide*, for
/// HP/Stamp-it it pins a bounded set, and even for Hyaline it strands the
/// batches that guard holds. Guards are `!Send`, so a guard cannot
/// literally live inside a `Send` future across an `.await` — but a future
/// polled on an executor thread can still leak protection onto that thread
/// (e.g. by forgetting a guard or stashing a registered region in TLS),
/// and a blocking future driven in place can hold one across `park()`.
///
/// The mechanism is a thread-local count of live guards, bumped at guard
/// creation and dropped at guard drop. The executor snapshots it around
/// each `poll` ([`check_after_poll`]); a task that returns `Pending` with
/// more guards live than it started with gets flagged: a
/// `lint.guard_await` trace event, a global violation counter, and a
/// `debug_assert!` (caught by the executor's per-task `catch_unwind`, so a
/// debug build kills the offending task, not the worker thread).
///
/// Opt out with [`set_enabled`]`(false)` (knob string: `off`).
pub mod lint {
    use std::cell::Cell;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    static ENABLED: AtomicBool = AtomicBool::new(true);
    static VIOLATIONS: AtomicU64 = AtomicU64::new(0);

    thread_local! {
        static LIVE_GUARDS: Cell<u64> = const { Cell::new(0) };
    }

    /// Hook: a guard came to life on this thread.
    #[inline]
    pub(crate) fn guard_created() {
        let _ = LIVE_GUARDS.try_with(|c| c.set(c.get() + 1));
    }

    /// Hook: a guard died on this thread.
    #[inline]
    pub(crate) fn guard_dropped() {
        let _ = LIVE_GUARDS.try_with(|c| c.set(c.get().saturating_sub(1)));
    }

    /// Live guards on the calling thread (0 during TLS teardown).
    pub fn live_guards() -> u64 {
        LIVE_GUARDS.try_with(|c| c.get()).unwrap_or(0)
    }

    /// Globally enable/disable the lint (default: enabled).
    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    /// Is the lint currently enabled?
    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    /// Parse an ablation-knob string (`on` / `off`), mirroring the trace
    /// and magazine knobs.
    pub fn apply_knob(v: &str) -> bool {
        match v {
            "on" | "1" | "true" => set_enabled(true),
            "off" | "0" | "false" => set_enabled(false),
            _ => return false,
        }
        true
    }

    /// Total violations recorded process-wide.
    pub fn violations() -> u64 {
        VIOLATIONS.load(Ordering::Relaxed)
    }

    /// Executor hook: `before` is [`live_guards`] sampled before polling a
    /// task that has now returned `Pending`. Returns whether a violation
    /// was recorded. Call *inside* the per-task `catch_unwind` so the
    /// debug assertion downs the task, not the worker.
    pub fn check_after_poll(before: u64) -> bool {
        if !enabled() {
            return false;
        }
        let after = live_guards();
        if after <= before {
            return false;
        }
        VIOLATIONS.fetch_add(1, Ordering::Relaxed);
        crate::trace::event!("lint.guard_await", (after - before) as u32);
        debug_assert!(
            false,
            "task parked while holding {} SMR guard(s) acquired during this poll \
             (guards must not be held across an await point; \
             opt out with reclaim::facade::lint::set_enabled(false))",
            after - before
        );
        true
    }

    #[cfg(test)]
    mod tests {
        #[test]
        fn knob_parses() {
            assert!(super::apply_knob("off"));
            assert!(!super::enabled());
            assert!(super::apply_knob("on"));
            assert!(super::enabled());
            assert!(!super::apply_knob("sideways"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::ebr::Ebr;
    use crate::reclaim::leaky::Leaky;

    #[test]
    fn owned_drop_frees_unpublished_nodes() {
        let before = crate::alloc::snapshot();
        {
            let o: Owned<u64, Leaky> = Owned::new(17);
            assert_eq!(*o, 17);
        }
        let after = crate::alloc::snapshot();
        assert!(after.reclaimed >= before.reclaimed + 1, "Owned drop must free");
    }

    #[test]
    fn protect_brands_and_derefs() {
        let domain = DomainRef::<Ebr>::new_owned();
        let h = domain.register();
        let cell: Atomic<u64, Ebr> = Atomic::new(Owned::new(99));
        let mut g: Guard<u64, Ebr> = h.guard();
        assert!(g.is_empty());
        {
            let s = g.protect(&cell).expect("non-null");
            assert_eq!(*s.get(), 99);
            assert_eq!(*s, 99); // Deref
            assert_eq!(s.mark(), 0);
            assert!(s.ptr_eq(cell.load(Ordering::Relaxed)));
        }
        assert!(!g.is_empty());
        g.reset();
        assert!(g.is_empty());
        // Drain: unlink + retire so the owned domain shuts down clean.
        let last = cell.load(Ordering::Relaxed);
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked above; sole retirer; readers (none) in-domain.
        unsafe { h.retire(last.get()) };
    }

    #[test]
    fn try_protect_reports_stale_links() {
        let domain = DomainRef::<Ebr>::new_owned();
        let h = domain.register();
        let cell: Atomic<u64, Ebr> = Atomic::new(Owned::new(5));
        let actual = cell.load(Ordering::Relaxed);
        let mut g: Guard<u64, Ebr> = h.guard();
        assert_eq!(g.try_protect(&cell, MarkedPtr::null()), Err(Stale));
        assert!(g.is_empty(), "failed try_protect leaves the shield empty");
        assert_eq!(g.try_protect(&cell, actual), Ok(()));
        assert_eq!(g.shared().map(|s| *s.get()), Some(5));
        g.reset();
        let last = cell.load(Ordering::Relaxed);
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked above; sole retirer.
        unsafe { h.retire(last.get()) };
    }

    #[test]
    fn cas_publish_returns_owned_on_failure() {
        let domain = DomainRef::<Ebr>::new_owned();
        let h = domain.register();
        let cell: Atomic<u64, Ebr> = Atomic::new(Owned::new(1));
        let occupant = cell.load(Ordering::Relaxed);
        // Expected null but the cell is occupied: node comes back.
        let fresh = Owned::new(2);
        let (witness, fresh) = cell
            .cas_publish(MarkedPtr::null(), fresh, Ordering::AcqRel, Ordering::Acquire)
            .expect_err("cell was occupied");
        assert_eq!(witness, occupant);
        assert_eq!(*fresh, 2);
        // Correct expected value: publishes, ownership moves.
        let published = cell
            .cas_publish(occupant, fresh, Ordering::AcqRel, Ordering::Acquire)
            .expect("uncontended");
        assert_eq!(cell.load(Ordering::Relaxed), published);
        // SAFETY: `occupant` was unlinked by the successful CAS just
        // above; sole retirer.
        unsafe { h.retire(occupant.get()) };
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked above; sole retirer.
        unsafe { h.retire(published.get()) };
    }

    #[test]
    fn handle_source_routes_both_paths() {
        fn resolves_in<R: Reclaimer, H: HandleSource<R>>(h: H, domain: &DomainRef<R>) -> bool {
            h.with_source(domain, |inner| std::ptr::eq(inner.domain(), domain.domain()))
        }
        let domain = DomainRef::<Ebr>::new_owned();
        let h = domain.register();
        // Explicit handle: hands back the borrow we gave it, same domain.
        assert!(resolves_in(&h, &domain));
        // Cached: resolves some handle registered to the same domain.
        assert!(resolves_in(Cached, &domain));
    }
}

//! Shared machinery for the epoch-family schemes: ER (Fraser), NER (Hart),
//! QSR (McKenney) and DEBRA (Brown) are four policies over the same core —
//! a global epoch counter, per-thread epoch announcements, stamped
//! per-thread retire lists and an orphan hand-off list. One [`EpochDomain`]
//! is the `DomainState` of each scheme (instantiated per
//! [`crate::reclaim::Domain`]); [`LocalEpoch`] is the per-thread state a
//! [`crate::reclaim::LocalHandle`] caches.
//!
//! ## Reclamation rule
//!
//! A node is stamped with the **domain's** epoch value read *after* it was
//! unlinked, and reclaimed once `global >= stamp + 2`. Correctness (the
//! classic two-advance argument, in C++-memory-model terms):
//!
//! * Any thread that could still hold a reference was inside a critical
//!   region when the node was unlinked, so its announced epoch is at most
//!   `stamp` and is **not updated** while it stays in the region
//!   (ER/NER/DEBRA) or until its next quiescent point (QSR).
//! * Advancing `stamp → stamp+1` requires every announced epoch to equal
//!   `stamp`; advancing to `stamp+2` requires them to equal `stamp+1`.
//!   A pre-unlink region would still announce ≤ `stamp` and block the second
//!   advance. Hence `global = stamp+2` implies every such region has ended;
//!   the announcement stores are ordered against the scans by the SeqCst
//!   fences at entry and scan.
//!
//! ## Policy knobs (paper §4.2)
//!
//! * ER/NER try to advance the epoch every **100** critical-region entries.
//! * DEBRA checks **one** other thread every **20** entries, advancing when
//!   a full pass over the registry succeeds.
//! * QSR announces at region *exit* (the fuzzy barrier) and its threads
//!   count as epoch-blocking from registration until thread exit.
//!
//! ## Reentrancy discipline
//!
//! Reclaiming runs user `Drop` code, which may itself create guards or
//! retire nodes through the same scheme. All entry points therefore release
//! the [`LocalCell`] borrow *before* reclaiming; nested retires land in the
//! (temporarily emptied) local list and are merged back after.

use std::sync::atomic::{AtomicU64, Ordering};

use super::domain::LocalCell;
use super::registry::{EntryRef, ThreadList};
use super::retire::{prepare_retire, GlobalRetireList, RetireList};
use super::{Node, Reclaimer};
use crate::util::cache_pad::CachePadded;

/// Scheme-policy parameters.
#[derive(Copy, Clone, Debug)]
pub struct EpochConfig {
    /// Attempt a (full-scan) epoch advance every N outermost region entries
    /// (ER/NER) or N quiescent passes (QSR). Ignored under DEBRA.
    pub advance_every: u32,
    /// DEBRA-style incremental advance: check one thread every N entries.
    pub debra_check_every: Option<u32>,
    /// QSR: announce epochs at region *exit* only; registered threads block
    /// advancement even outside regions.
    pub quiescent_at_exit: bool,
}

/// Shared per-thread slot read by epoch scanners.
/// `state = (epoch << 1) | blocking` — one word, so a scan reads an
/// (epoch, blocking) pair atomically.
#[derive(Default)]
pub struct EpochSlot {
    state: AtomicU64,
}

impl EpochSlot {
    #[inline]
    fn announce(&self, epoch: u64, blocking: bool, order: Ordering) {
        self.state.store((epoch << 1) | blocking as u64, order);
    }
}

/// One epoch domain (shared state); the `DomainState` of every epoch-family
/// scheme — each [`crate::reclaim::Domain`] owns its own instance.
pub struct EpochDomain {
    pub cfg: EpochConfig,
    /// Runtime-tunable copy of `cfg.advance_every` / the DEBRA check
    /// stride (ablation bench A3).
    period: std::sync::atomic::AtomicU32,
    global: CachePadded<AtomicU64>,
    threads: ThreadList<EpochSlot>,
    orphans: GlobalRetireList,
}

impl EpochDomain {
    pub const fn new(cfg: EpochConfig) -> Self {
        let period = match cfg.debra_check_every {
            Some(n) => n,
            None => cfg.advance_every,
        };
        Self {
            cfg,
            period: std::sync::atomic::AtomicU32::new(period),
            global: CachePadded::new(AtomicU64::new(0)),
            threads: ThreadList::new(),
            orphans: GlobalRetireList::new(),
        }
    }

    /// Current advance/check period (paper §4.2: 100 for ER/NER, 20 for
    /// DEBRA's per-thread checks).
    pub fn period(&self) -> u32 {
        self.period.load(Ordering::Relaxed)
    }

    /// Tune the advance/check period (ablation bench A3).
    pub fn set_period(&self, n: u32) {
        self.period.store(n.max(1), Ordering::Relaxed);
    }

    #[inline]
    pub fn global_epoch(&self) -> u64 {
        self.global.load(Ordering::Acquire)
    }

    /// Full-scan advance attempt. Returns true if the epoch moved.
    pub fn try_advance(&self) -> bool {
        // Order this scan after our own announcement store; pairs with the
        // region-entry fences of other threads.
        std::sync::atomic::fence(Ordering::SeqCst);
        let e = self.global.load(Ordering::Relaxed);
        for entry in self.threads.iter() {
            if !entry.is_active() {
                continue;
            }
            let s = entry.data().state.load(Ordering::Acquire);
            if s & 1 == 1 && (s >> 1) != e {
                return false; // someone still announces an older epoch
            }
        }
        // CAS, not store: concurrent scanners may race; at most one advance
        // per observed epoch value.
        self.global.compare_exchange(e, e + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok()
    }

    /// Can a node with this retire stamp be reclaimed now?
    #[inline]
    fn reclaimable(&self, stamp: u64) -> bool {
        stamp + 2 <= self.global.load(Ordering::Acquire)
    }

    /// Reclaim eligible orphans (runs user drops — never call while holding
    /// a [`LocalCell`] borrow).
    fn drain_orphans(&self) -> usize {
        if self.orphans.is_empty() {
            return 0;
        }
        // SAFETY: the two-advance rule (module docs).
        unsafe { self.orphans.reclaim_where(|s| self.reclaimable(s)) }
    }

    /// Nodes currently parked on the orphan list (diagnostics).
    pub fn orphan_count(&self) -> usize {
        self.orphans.count()
    }
}

/// Thread-local epoch state (the `LocalState` cached by a handle).
pub struct LocalEpoch {
    entry: EntryRef<EpochSlot>,
    retired: RetireList,
    nesting: u32,
    /// Outermost entries since the last advance attempt / DEBRA check.
    entries: u32,
    /// DEBRA: registry-walk position and the epoch the pass started at.
    scan_pos: usize,
    scan_epoch: u64,
}

/// Action decided under the borrow, executed after releasing it.
enum Deferred {
    None,
    TryAdvance,
    DebraCheck,
}

impl LocalEpoch {
    /// Register the calling thread with `domain` (recycling an inactive
    /// registry entry when one exists; the [`EntryRef`] stays valid because
    /// the handle holding this state keeps the domain — and hence its
    /// entry arena — alive).
    pub fn register(domain: &EpochDomain) -> Self {
        let entry = domain.threads.acquire(EpochSlot::default, |slot| {
            slot.announce(0, false, Ordering::Release);
        });
        if domain.cfg.quiescent_at_exit {
            // QSR: the thread blocks epoch advancement from registration on.
            let e = domain.global.load(Ordering::Relaxed);
            entry.data().announce(e, true, Ordering::Release);
            std::sync::atomic::fence(Ordering::SeqCst);
        }
        Self {
            entry,
            retired: RetireList::new(),
            nesting: 0,
            entries: 0,
            scan_pos: 0,
            scan_epoch: 0,
        }
    }

    fn enter_inner(&mut self, domain: &EpochDomain) -> Deferred {
        self.nesting += 1;
        if self.nesting > 1 {
            return Deferred::None;
        }
        let cfg = domain.cfg;
        if !cfg.quiescent_at_exit {
            // Announce (epoch, blocking): Release store + SeqCst fence
            // orders the announcement before all subsequent shared-data
            // loads (pairs with the scan fence in try_advance).
            let e = domain.global.load(Ordering::Relaxed);
            self.entry.data().announce(e, true, Ordering::Release);
            std::sync::atomic::fence(Ordering::SeqCst);
        }
        self.entries += 1;
        let period = domain.period();
        if cfg.debra_check_every.is_some() {
            if self.entries >= period {
                self.entries = 0;
                return Deferred::DebraCheck;
            }
        } else if !cfg.quiescent_at_exit && self.entries >= period {
            self.entries = 0;
            return Deferred::TryAdvance;
        }
        Deferred::None
    }

    fn exit_inner(&mut self, domain: &EpochDomain) -> Deferred {
        debug_assert!(self.nesting > 0, "unbalanced region exit");
        self.nesting -= 1;
        if self.nesting > 0 {
            return Deferred::None;
        }
        let cfg = domain.cfg;
        if cfg.quiescent_at_exit {
            // QSR's fuzzy barrier: announce passage through a quiescent
            // state by adopting the current global epoch.
            let e = domain.global.load(Ordering::Relaxed);
            self.entry.data().announce(e, true, Ordering::Release);
            std::sync::atomic::fence(Ordering::SeqCst);
            self.entries += 1;
            if self.entries >= domain.period() {
                self.entries = 0;
                return Deferred::TryAdvance;
            }
        } else {
            // Stop blocking advancement; Release pairs with scanners.
            let s = self.entry.data().state.load(Ordering::Relaxed);
            self.entry.data().announce(s >> 1, false, Ordering::Release);
        }
        Deferred::None
    }

    #[inline]
    pub fn in_region(&self) -> bool {
        self.nesting > 0
    }

    /// Append nodes from `other` (all stamped at ≥ our max stamp) keeping
    /// the order invariant.
    fn append_merge(&mut self, mut other: RetireList) {
        let (chain, _) = other.take_chain();
        let mut cur = chain;
        while !cur.is_null() {
            // SAFETY: we own the detached chain.
            let next = unsafe { (*cur).next_in_chain() };
            self.retired.push_back(cur);
            cur = next;
        }
    }
}

// ---- Borrow-safe entry points (see "Reentrancy discipline" above) ----

/// Enter a critical region in `domain`.
pub fn enter(domain: &EpochDomain, local: &LocalCell<LocalEpoch>) {
    let deferred = local.with(|l| l.enter_inner(domain));
    run_deferred(domain, local, deferred);
}

/// Leave a critical region; reclaims the eligible local prefix.
pub fn exit(domain: &EpochDomain, local: &LocalCell<LocalEpoch>) {
    let deferred = local.with(|l| l.exit_inner(domain));
    run_deferred(domain, local, deferred);
    reclaim_local(domain, local);
}

fn run_deferred(domain: &EpochDomain, local: &LocalCell<LocalEpoch>, deferred: Deferred) {
    match deferred {
        Deferred::None => {}
        Deferred::TryAdvance => {
            if domain.try_advance() {
                domain.drain_orphans();
            }
        }
        Deferred::DebraCheck => debra_check_one(domain, local),
    }
}

/// Retire a node: stamp with the domain epoch (read after unlink — Acquire
/// pairs with the unlink CAS) and append to the ordered local retire list.
///
/// # Safety
/// See [`Reclaimer::retire`].
pub unsafe fn retire<T: Send + Sync + 'static, R: Reclaimer>(
    domain: &EpochDomain,
    local: &LocalCell<LocalEpoch>,
    node: *mut Node<T, R>,
) {
    let stamp = domain.global.load(Ordering::Acquire);
    let r = prepare_retire::<T, R>(node, stamp);
    local.with(|l| l.retired.push_back(r));
}

/// Reclaim the eligible prefix of the local retire list. The list is
/// detached while user drops run; nested retires are merged back after.
pub fn reclaim_local(domain: &EpochDomain, local: &LocalCell<LocalEpoch>) -> usize {
    if local.with(|l| l.retired.is_empty()) {
        return 0;
    }
    let mut mine = local.with(|l| std::mem::take(&mut l.retired));
    // SAFETY: reclaimable() implements the two-advance rule (module docs).
    let freed = unsafe { mine.reclaim_prefix(|s| domain.reclaimable(s)) };
    local.with(|l| {
        let nested = std::mem::replace(&mut l.retired, mine);
        l.append_merge(nested);
    });
    freed
}

/// DEBRA: check a single registry entry; advance the epoch when a full pass
/// over the registry observed everyone at the current epoch.
fn debra_check_one(domain: &EpochDomain, local: &LocalCell<LocalEpoch>) {
    std::sync::atomic::fence(Ordering::SeqCst);
    let e = domain.global.load(Ordering::Relaxed);
    let pos = local.with(|l| {
        if e != l.scan_epoch {
            // Epoch moved since the pass started: restart.
            l.scan_epoch = e;
            l.scan_pos = 0;
        }
        l.scan_pos
    });
    match domain.threads.iter().nth(pos) {
        None => {
            // Full pass done at epoch e: advance.
            let advanced = domain
                .global
                .compare_exchange(e, e + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok();
            local.with(|l| {
                l.scan_pos = 0;
                l.scan_epoch = e + 1;
            });
            if advanced {
                domain.drain_orphans();
            }
        }
        Some(entry) => {
            let s = entry.data().state.load(Ordering::Acquire);
            let blocking = entry.is_active() && s & 1 == 1;
            if !blocking || (s >> 1) == e {
                local.with(|l| l.scan_pos += 1);
            }
            // else: stay on this entry; re-check on the next opportunity.
        }
    }
}

/// Bench/test hook: repeatedly advance + reclaim until quiescent.
pub fn flush(domain: &EpochDomain, local: &LocalCell<LocalEpoch>) {
    for _ in 0..4 {
        // Cycle a region so *our own* announcement stops blocking the
        // advance: the exit updates QSR's quiescent state and clears the
        // blocking bit for the in-region schemes. A nested cycle (flush
        // under a live guard) deliberately changes nothing — the guard
        // must keep blocking.
        enter(domain, local);
        exit(domain, local);
        domain.try_advance();
        reclaim_local(domain, local);
        domain.drain_orphans();
    }
}

/// Thread exit / handle drop: hand unreclaimed nodes to the orphan list
/// (the paper: "when a thread terminates, all schemes add the remaining
/// nodes to a global list") and release the registry entry for reuse.
pub fn unregister(domain: &EpochDomain, local: &mut LocalEpoch) {
    debug_assert_eq!(local.nesting, 0, "handle dropped inside a critical region");
    let (chain, _) = local.retired.take_chain();
    domain.orphans.push_sublist(chain);
    local.entry.data().announce(0, false, Ordering::Release);
    domain.threads.release(&local.entry);
}

/// Domain teardown: reclaim every parked orphan. Exclusive access — no
/// handles, guards or regions reference the domain anymore.
pub fn drain(domain: &mut EpochDomain) {
    // SAFETY: exclusive access (see above); nothing can still hold a
    // reference into the orphaned nodes.
    unsafe {
        domain.orphans.reclaim_where(|_| true);
    }
}

/// Node header for epoch-family schemes: just the retire metadata.
#[derive(Default)]
#[repr(C)]
pub struct EpochHeader {
    retire: super::retire::RetireHeader,
}

impl super::retire::AsRetireHeader for EpochHeader {
    fn retire_header(&self) -> &super::retire::RetireHeader {
        &self.retire
    }
}

/// Guard token: whether this guard entered a region it must exit on drop.
#[derive(Default)]
pub struct EpochGuardToken {
    pub(crate) entered: bool,
}

/// Implements [`Reclaimer`] for an epoch-family scheme: `DomainState` is an
/// [`EpochDomain`] built from the given [`EpochConfig`], `LocalState` a
/// [`LocalEpoch`].
///
/// Protection argument: `protect` is a plain Acquire load — being inside a
/// critical region (entered by the guard token or an enclosing
/// [`crate::reclaim::Region`]) is what protects the target (paper §2/§3).
macro_rules! epoch_reclaimer_impl {
    ($scheme:ty, $name:literal, $cfg:expr) => {
        // SAFETY: the epoch protocol (see epoch_core module docs) reclaims a
        // retired node only after every region in the same domain that could
        // reference it has exited; domains share nothing.
        unsafe impl $crate::reclaim::Reclaimer for $scheme {
            const NAME: &'static str = $name;
            type Header = $crate::reclaim::epoch_core::EpochHeader;
            type GuardState = $crate::reclaim::epoch_core::EpochGuardToken;
            type DomainState = $crate::reclaim::epoch_core::EpochDomain;
            type LocalState = $crate::reclaim::epoch_core::LocalEpoch;

            fn new_domain_state() -> Self::DomainState {
                $crate::reclaim::epoch_core::EpochDomain::new($cfg)
            }

            $crate::reclaim::domain::impl_domain_statics!($scheme);

            fn register(domain: &Self::DomainState) -> Self::LocalState {
                $crate::reclaim::epoch_core::LocalEpoch::register(domain)
            }

            fn unregister(domain: &Self::DomainState, local: &mut Self::LocalState) {
                $crate::reclaim::epoch_core::unregister(domain, local)
            }

            fn enter_region(
                domain: &Self::DomainState,
                local: &$crate::reclaim::LocalCell<Self::LocalState>,
            ) {
                $crate::reclaim::epoch_core::enter(domain, local)
            }

            fn exit_region(
                domain: &Self::DomainState,
                local: &$crate::reclaim::LocalCell<Self::LocalState>,
            ) {
                $crate::reclaim::epoch_core::exit(domain, local)
            }

            #[inline]
            fn protect<T: Send + Sync + 'static>(
                domain: &Self::DomainState,
                local: &$crate::reclaim::LocalCell<Self::LocalState>,
                state: &mut Self::GuardState,
                src: &$crate::reclaim::ConcurrentPtr<T, Self>,
            ) -> $crate::reclaim::MarkedPtr<T, Self> {
                if !state.entered {
                    state.entered = true;
                    $crate::reclaim::epoch_core::enter(domain, local);
                }
                // Acquire pairs with the Release publication of the node.
                src.load(std::sync::atomic::Ordering::Acquire)
            }

            #[inline]
            fn protect_if_equal<T: Send + Sync + 'static>(
                domain: &Self::DomainState,
                local: &$crate::reclaim::LocalCell<Self::LocalState>,
                state: &mut Self::GuardState,
                src: &$crate::reclaim::ConcurrentPtr<T, Self>,
                expected: $crate::reclaim::MarkedPtr<T, Self>,
            ) -> bool {
                if !state.entered {
                    state.entered = true;
                    $crate::reclaim::epoch_core::enter(domain, local);
                }
                src.load(std::sync::atomic::Ordering::Acquire) == expected
            }

            #[inline]
            fn release<T: Send + Sync + 'static>(
                _domain: &Self::DomainState,
                _local: &$crate::reclaim::LocalCell<Self::LocalState>,
                _state: &mut Self::GuardState,
                _ptr: $crate::reclaim::MarkedPtr<T, Self>,
            ) {
                // Protection is region-scoped; the region is left when the
                // guard is dropped (drop_guard_state).
            }

            fn drop_guard_state(
                domain: &Self::DomainState,
                local: &$crate::reclaim::LocalCell<Self::LocalState>,
                state: &mut Self::GuardState,
            ) {
                if state.entered {
                    state.entered = false;
                    $crate::reclaim::epoch_core::exit(domain, local);
                }
            }

            unsafe fn retire<T: Send + Sync + 'static>(
                domain: &Self::DomainState,
                local: &$crate::reclaim::LocalCell<Self::LocalState>,
                node: *mut $crate::reclaim::Node<T, Self>,
            ) {
                $crate::reclaim::epoch_core::retire::<T, Self>(domain, local, node)
            }

            fn flush(
                domain: &Self::DomainState,
                local: &$crate::reclaim::LocalCell<Self::LocalState>,
            ) {
                $crate::reclaim::epoch_core::flush(domain, local)
            }

            fn drain_domain(domain: &mut Self::DomainState) {
                $crate::reclaim::epoch_core::drain(domain)
            }
        }
    };
}
pub(crate) use epoch_reclaimer_impl;

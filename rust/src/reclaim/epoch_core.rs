//! Shared machinery for the epoch-family schemes: ER (Fraser), NER (Hart),
//! QSR (McKenney) and DEBRA (Brown) are four policies over the same core —
//! a global epoch counter, per-thread epoch announcements, stamped
//! per-thread retire lists and an orphan hand-off list.
//!
//! ## Reclamation rule
//!
//! A node is stamped with the **global** epoch value read *after* it was
//! unlinked, and reclaimed once `global >= stamp + 2`. Correctness (the
//! classic two-advance argument, in C++-memory-model terms):
//!
//! * Any thread that could still hold a reference was inside a critical
//!   region when the node was unlinked, so its announced epoch is at most
//!   `stamp` and is **not updated** while it stays in the region
//!   (ER/NER/DEBRA) or until its next quiescent point (QSR).
//! * Advancing `stamp → stamp+1` requires every announced epoch to equal
//!   `stamp`; advancing to `stamp+2` requires them to equal `stamp+1`.
//!   A pre-unlink region would still announce ≤ `stamp` and block the second
//!   advance. Hence `global = stamp+2` implies every such region has ended;
//!   the announcement stores are ordered against the scans by the SeqCst
//!   fences at entry and scan.
//!
//! ## Policy knobs (paper §4.2)
//!
//! * ER/NER try to advance the epoch every **100** critical-region entries.
//! * DEBRA checks **one** other thread every **20** entries, advancing when
//!   a full pass over the registry succeeds.
//! * QSR announces at region *exit* (the fuzzy barrier) and its threads
//!   count as epoch-blocking from registration until thread exit.
//!
//! ## Reentrancy discipline
//!
//! Reclaiming runs user `Drop` code, which may itself create guards or
//! retire nodes through the same scheme. All entry points therefore release
//! the thread-local `RefCell` borrow *before* reclaiming; nested retires
//! land in the (temporarily emptied) local list and are merged back after.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

use super::registry::{ThreadEntry, ThreadList};
use super::retire::{prepare_retire, GlobalRetireList, RetireList};
use super::{Node, Reclaimer};
use crossbeam_utils::CachePadded;

/// Scheme-policy parameters.
#[derive(Copy, Clone, Debug)]
pub struct EpochConfig {
    /// Attempt a (full-scan) epoch advance every N outermost region entries
    /// (ER/NER) or N quiescent passes (QSR). Ignored under DEBRA.
    pub advance_every: u32,
    /// DEBRA-style incremental advance: check one thread every N entries.
    pub debra_check_every: Option<u32>,
    /// QSR: announce epochs at region *exit* only; registered threads block
    /// advancement even outside regions.
    pub quiescent_at_exit: bool,
}

/// Shared per-thread slot read by epoch scanners.
/// `state = (epoch << 1) | blocking` — one word, so a scan reads an
/// (epoch, blocking) pair atomically.
#[derive(Default)]
pub struct EpochSlot {
    state: AtomicU64,
}

impl EpochSlot {
    #[inline]
    fn announce(&self, epoch: u64, blocking: bool, order: Ordering) {
        self.state.store((epoch << 1) | blocking as u64, order);
    }
}

/// One epoch domain (global state); each scheme owns a static one.
pub struct EpochDomain {
    pub cfg: EpochConfig,
    /// Runtime-tunable copy of `cfg.advance_every` / the DEBRA check
    /// stride (ablation bench A3).
    period: std::sync::atomic::AtomicU32,
    global: CachePadded<AtomicU64>,
    threads: ThreadList<EpochSlot>,
    orphans: GlobalRetireList,
}

impl EpochDomain {
    pub const fn new(cfg: EpochConfig) -> Self {
        let period = match cfg.debra_check_every {
            Some(n) => n,
            None => cfg.advance_every,
        };
        Self {
            cfg,
            period: std::sync::atomic::AtomicU32::new(period),
            global: CachePadded::new(AtomicU64::new(0)),
            threads: ThreadList::new(),
            orphans: GlobalRetireList::new(),
        }
    }

    /// Current advance/check period (paper §4.2: 100 for ER/NER, 20 for
    /// DEBRA's per-thread checks).
    pub fn period(&self) -> u32 {
        self.period.load(Ordering::Relaxed)
    }

    /// Tune the advance/check period (ablation bench A3).
    pub fn set_period(&self, n: u32) {
        self.period.store(n.max(1), Ordering::Relaxed);
    }

    #[inline]
    pub fn global_epoch(&self) -> u64 {
        self.global.load(Ordering::Acquire)
    }

    /// Full-scan advance attempt. Returns true if the epoch moved.
    pub fn try_advance(&self) -> bool {
        // Order this scan after our own announcement store; pairs with the
        // region-entry fences of other threads.
        std::sync::atomic::fence(Ordering::SeqCst);
        let e = self.global.load(Ordering::Relaxed);
        for entry in self.threads.iter() {
            if !entry.is_active() {
                continue;
            }
            let s = entry.data().state.load(Ordering::Acquire);
            if s & 1 == 1 && (s >> 1) != e {
                return false; // someone still announces an older epoch
            }
        }
        // CAS, not store: concurrent scanners may race; at most one advance
        // per observed epoch value.
        self.global.compare_exchange(e, e + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok()
    }

    /// Can a node with this retire stamp be reclaimed now?
    #[inline]
    fn reclaimable(&self, stamp: u64) -> bool {
        stamp + 2 <= self.global.load(Ordering::Acquire)
    }

    /// Reclaim eligible orphans (runs user drops — never call while holding
    /// a thread-local borrow).
    fn drain_orphans(&self) -> usize {
        if self.orphans.is_empty() {
            return 0;
        }
        // SAFETY: the two-advance rule (module docs).
        unsafe { self.orphans.reclaim_where(|s| self.reclaimable(s)) }
    }

    /// Nodes currently parked on the orphan list (diagnostics).
    pub fn orphan_count(&self) -> usize {
        self.orphans.count()
    }
}

/// Thread-local epoch state (one per scheme per thread).
pub struct LocalEpoch {
    domain: &'static EpochDomain,
    entry: &'static ThreadEntry<EpochSlot>,
    retired: RetireList,
    nesting: u32,
    /// Outermost entries since the last advance attempt / DEBRA check.
    entries: u32,
    /// DEBRA: registry-walk position and the epoch the pass started at.
    scan_pos: usize,
    scan_epoch: u64,
}

/// Action decided under the borrow, executed after releasing it.
enum Deferred {
    None,
    TryAdvance,
    DebraCheck,
}

impl LocalEpoch {
    pub fn new(domain: &'static EpochDomain) -> Self {
        let entry = domain.threads.acquire(EpochSlot::default, |slot| {
            slot.announce(0, false, Ordering::Release);
        });
        if domain.cfg.quiescent_at_exit {
            // QSR: the thread blocks epoch advancement from registration on.
            let e = domain.global.load(Ordering::Relaxed);
            entry.data().announce(e, true, Ordering::Release);
            std::sync::atomic::fence(Ordering::SeqCst);
        }
        Self {
            domain,
            entry,
            retired: RetireList::new(),
            nesting: 0,
            entries: 0,
            scan_pos: 0,
            scan_epoch: 0,
        }
    }

    fn enter_inner(&mut self) -> Deferred {
        self.nesting += 1;
        if self.nesting > 1 {
            return Deferred::None;
        }
        let cfg = self.domain.cfg;
        if !cfg.quiescent_at_exit {
            // Announce (epoch, blocking): Release store + SeqCst fence
            // orders the announcement before all subsequent shared-data
            // loads (pairs with the scan fence in try_advance).
            let e = self.domain.global.load(Ordering::Relaxed);
            self.entry.data().announce(e, true, Ordering::Release);
            std::sync::atomic::fence(Ordering::SeqCst);
        }
        self.entries += 1;
        let period = self.domain.period();
        if cfg.debra_check_every.is_some() {
            if self.entries >= period {
                self.entries = 0;
                return Deferred::DebraCheck;
            }
        } else if !cfg.quiescent_at_exit && self.entries >= period {
            self.entries = 0;
            return Deferred::TryAdvance;
        }
        Deferred::None
    }

    fn exit_inner(&mut self) -> Deferred {
        debug_assert!(self.nesting > 0, "unbalanced region exit");
        self.nesting -= 1;
        if self.nesting > 0 {
            return Deferred::None;
        }
        let cfg = self.domain.cfg;
        if cfg.quiescent_at_exit {
            // QSR's fuzzy barrier: announce passage through a quiescent
            // state by adopting the current global epoch.
            let e = self.domain.global.load(Ordering::Relaxed);
            self.entry.data().announce(e, true, Ordering::Release);
            std::sync::atomic::fence(Ordering::SeqCst);
            self.entries += 1;
            if self.entries >= self.domain.period() {
                self.entries = 0;
                return Deferred::TryAdvance;
            }
        } else {
            // Stop blocking advancement; Release pairs with scanners.
            let s = self.entry.data().state.load(Ordering::Relaxed);
            self.entry.data().announce(s >> 1, false, Ordering::Release);
        }
        Deferred::None
    }

    #[inline]
    pub fn in_region(&self) -> bool {
        self.nesting > 0
    }

    /// Append nodes from `other` (all stamped at ≥ our max stamp) keeping
    /// the order invariant.
    fn append_merge(&mut self, mut other: RetireList) {
        let (chain, _) = other.take_chain();
        let mut cur = chain;
        while !cur.is_null() {
            // SAFETY: we own the detached chain.
            let next = unsafe { (*cur).next_in_chain() };
            self.retired.push_back(cur);
            cur = next;
        }
    }
}

impl Drop for LocalEpoch {
    fn drop(&mut self) {
        // Thread exit: hand unreclaimed nodes to the orphan list (the paper:
        // "when a thread terminates, all schemes add the remaining nodes to
        // a global list") and release the registry entry for reuse.
        let (chain, _) = self.retired.take_chain();
        self.domain.orphans.push_sublist(chain);
        self.entry.data().announce(0, false, Ordering::Release);
        self.domain.threads.release(self.entry);
    }
}

// ---- Borrow-safe entry points (see "Reentrancy discipline" above) ----

/// Enter a critical region for the scheme owning `cell`.
pub fn enter(domain: &'static EpochDomain, cell: &RefCell<LocalEpoch>) {
    let deferred = cell.borrow_mut().enter_inner();
    run_deferred(domain, cell, deferred);
}

/// Leave a critical region; reclaims the eligible local prefix.
pub fn exit(domain: &'static EpochDomain, cell: &RefCell<LocalEpoch>) {
    let deferred = cell.borrow_mut().exit_inner();
    run_deferred(domain, cell, deferred);
    reclaim_local(domain, cell);
}

fn run_deferred(domain: &'static EpochDomain, cell: &RefCell<LocalEpoch>, deferred: Deferred) {
    match deferred {
        Deferred::None => {}
        Deferred::TryAdvance => {
            if domain.try_advance() {
                domain.drain_orphans();
            }
        }
        Deferred::DebraCheck => debra_check_one(domain, cell),
    }
}

/// Retire a node: stamp with the global epoch (read after unlink — Acquire
/// pairs with the unlink CAS) and append to the ordered local retire list.
///
/// # Safety
/// See [`Reclaimer::retire`].
pub unsafe fn retire<T: Send + Sync + 'static, R: Reclaimer>(
    domain: &'static EpochDomain,
    cell: &RefCell<LocalEpoch>,
    node: *mut Node<T, R>,
) {
    let stamp = domain.global.load(Ordering::Acquire);
    let r = prepare_retire::<T, R>(node, stamp);
    cell.borrow_mut().retired.push_back(r);
}

/// Orphan-path retire for when the thread-local state is unavailable
/// (thread teardown).
///
/// # Safety
/// See [`Reclaimer::retire`].
pub unsafe fn retire_to_orphans<T: Send + Sync + 'static, R: Reclaimer>(
    domain: &'static EpochDomain,
    node: *mut Node<T, R>,
) {
    let stamp = domain.global.load(Ordering::Acquire);
    let r = prepare_retire::<T, R>(node, stamp);
    domain.orphans.push_sublist(r);
}

/// Reclaim the eligible prefix of the local retire list. The list is
/// detached while user drops run; nested retires are merged back after.
pub fn reclaim_local(domain: &'static EpochDomain, cell: &RefCell<LocalEpoch>) -> usize {
    if cell.borrow().retired.is_empty() {
        return 0;
    }
    let mut mine = std::mem::take(&mut cell.borrow_mut().retired);
    // SAFETY: reclaimable() implements the two-advance rule (module docs).
    let freed = unsafe { mine.reclaim_prefix(|s| domain.reclaimable(s)) };
    let mut l = cell.borrow_mut();
    let nested = std::mem::replace(&mut l.retired, mine);
    l.append_merge(nested);
    freed
}

/// DEBRA: check a single registry entry; advance the epoch when a full pass
/// over the registry observed everyone at the current epoch.
fn debra_check_one(domain: &'static EpochDomain, cell: &RefCell<LocalEpoch>) {
    std::sync::atomic::fence(Ordering::SeqCst);
    let e = domain.global.load(Ordering::Relaxed);
    let pos = {
        let mut l = cell.borrow_mut();
        if e != l.scan_epoch {
            // Epoch moved since the pass started: restart.
            l.scan_epoch = e;
            l.scan_pos = 0;
        }
        l.scan_pos
    };
    match domain.threads.iter().nth(pos) {
        None => {
            // Full pass done at epoch e: advance.
            let advanced =
                domain.global.compare_exchange(e, e + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok();
            {
                let mut l = cell.borrow_mut();
                l.scan_pos = 0;
                l.scan_epoch = e + 1;
            }
            if advanced {
                domain.drain_orphans();
            }
        }
        Some(entry) => {
            let s = entry.data().state.load(Ordering::Acquire);
            let blocking = entry.is_active() && s & 1 == 1;
            if !blocking || (s >> 1) == e {
                cell.borrow_mut().scan_pos += 1;
            }
            // else: stay on this entry; re-check on the next opportunity.
        }
    }
}

/// Bench/test hook: repeatedly advance + reclaim until quiescent.
pub fn flush(domain: &'static EpochDomain, cell: &RefCell<LocalEpoch>) {
    for _ in 0..4 {
        // Cycle a region so *our own* announcement stops blocking the
        // advance: the exit updates QSR's quiescent state and clears the
        // blocking bit for the in-region schemes. A nested cycle (flush
        // under a live guard) deliberately changes nothing — the guard
        // must keep blocking.
        enter(domain, cell);
        exit(domain, cell);
        domain.try_advance();
        reclaim_local(domain, cell);
        domain.drain_orphans();
    }
}

/// Node header for epoch-family schemes: just the retire metadata.
#[derive(Default)]
#[repr(C)]
pub struct EpochHeader {
    retire: super::retire::RetireHeader,
}

impl super::retire::AsRetireHeader for EpochHeader {
    fn retire_header(&self) -> &super::retire::RetireHeader {
        &self.retire
    }
}

/// Guard token: whether this guard entered a region it must exit on drop.
#[derive(Default)]
pub struct EpochGuardToken {
    pub(crate) entered: bool,
}

/// Implements [`Reclaimer`] for an epoch-family scheme over its `DOMAIN`
/// static and `LOCAL` thread-local.
///
/// Protection argument: `protect` is a plain Acquire load — being inside a
/// critical region (entered by the guard token or an enclosing
/// [`crate::reclaim::Region`]) is what protects the target (paper §2/§3).
macro_rules! epoch_reclaimer_impl {
    ($scheme:ty, $name:literal, $domain:ident, $local:ident, $region:ident) => {
        /// RAII region token for this scheme.
        pub struct $region {
            _not_send: std::marker::PhantomData<*const ()>,
        }

        impl Drop for $region {
            fn drop(&mut self) {
                let _ = $local.try_with(|l| $crate::reclaim::epoch_core::exit(&$domain, l));
            }
        }

        thread_local! {
            static $local: std::cell::RefCell<$crate::reclaim::epoch_core::LocalEpoch> =
                std::cell::RefCell::new($crate::reclaim::epoch_core::LocalEpoch::new(&$domain));
        }

        // SAFETY: the epoch protocol (see epoch_core module docs) reclaims a
        // retired node only after every region that could reference it has
        // exited.
        unsafe impl $crate::reclaim::Reclaimer for $scheme {
            const NAME: &'static str = $name;
            type Header = $crate::reclaim::epoch_core::EpochHeader;
            type GuardState = $crate::reclaim::epoch_core::EpochGuardToken;
            type Region = $region;

            fn enter_region() -> Self::Region {
                $local.with(|l| $crate::reclaim::epoch_core::enter(&$domain, l));
                $region { _not_send: std::marker::PhantomData }
            }

            #[inline]
            fn protect<T: Send + Sync + 'static>(
                state: &mut Self::GuardState,
                src: &$crate::reclaim::ConcurrentPtr<T, Self>,
            ) -> $crate::reclaim::MarkedPtr<T, Self> {
                if !state.entered {
                    state.entered = true;
                    $local.with(|l| $crate::reclaim::epoch_core::enter(&$domain, l));
                }
                // Acquire pairs with the Release publication of the node.
                src.load(std::sync::atomic::Ordering::Acquire)
            }

            #[inline]
            fn protect_if_equal<T: Send + Sync + 'static>(
                state: &mut Self::GuardState,
                src: &$crate::reclaim::ConcurrentPtr<T, Self>,
                expected: $crate::reclaim::MarkedPtr<T, Self>,
            ) -> bool {
                if !state.entered {
                    state.entered = true;
                    $local.with(|l| $crate::reclaim::epoch_core::enter(&$domain, l));
                }
                src.load(std::sync::atomic::Ordering::Acquire) == expected
            }

            #[inline]
            fn release<T: Send + Sync + 'static>(
                _state: &mut Self::GuardState,
                _ptr: $crate::reclaim::MarkedPtr<T, Self>,
            ) {
                // Protection is region-scoped; the region is left when the
                // guard is dropped (drop_guard_state).
            }

            fn drop_guard_state(state: &mut Self::GuardState) {
                if state.entered {
                    state.entered = false;
                    let _ = $local.try_with(|l| $crate::reclaim::epoch_core::exit(&$domain, l));
                }
            }

            unsafe fn retire<T: Send + Sync + 'static>(
                node: *mut $crate::reclaim::Node<T, Self>,
            ) {
                $local
                    .try_with(|l| $crate::reclaim::epoch_core::retire::<T, Self>(&$domain, l, node))
                    .unwrap_or_else(|_| {
                        // Thread teardown: hand straight to the orphan list.
                        $crate::reclaim::epoch_core::retire_to_orphans::<T, Self>(&$domain, node)
                    });
            }

            fn flush() {
                $local.with(|l| $crate::reclaim::epoch_core::flush(&$domain, l));
            }
        }
    };
}
pub(crate) use epoch_reclaimer_impl;

//! Retire-list machinery shared by all schemes (paper §3).
//!
//! Every node carries a [`RetireHeader`] inside its scheme header. When a
//! node is retired the header is filled with a type-erased destructor and a
//! scheme-specific *stamp* (epoch number, stamp value, ...), and the node is
//! linked into a thread-local [`RetireList`]. Because nodes are appended in
//! stamp order, reclamation scans only the reclaimable prefix — the paper's
//! "no time is wasted on nodes that cannot yet be reclaimed" property
//! (Proposition 2).
//!
//! [`GlobalRetireList`] is the lock-free global list used for orphan
//! hand-off (threads exiting with unreclaimed nodes) and for Stamp-it's
//! "list of ordered sublists" (§3): sublists are chained through the head
//! node's `next_list` link, so a scan touches each sublist only up to the
//! first non-reclaimable node — the `O(n + m)` bound of §3.
//!
//! Reclamation closes the **retire→reuse loop**: [`reclaim_one`] frees the
//! node through `free_raw` → `pool::free`, which lands pool-backed slots in
//! the *reclaiming* thread's magazine ([`crate::alloc::magazine`]) — the
//! next `Owned::new` on that thread takes the slot back with a non-atomic
//! pop, turning the paper's "reclaims earlier" property into allocation
//! throughput. The LFRC offset-0 contract is unaffected: magazines never
//! write a cached slot's first word.

use std::ptr;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};

use super::{Node, Reclaimer};

/// Flag: the node's memory came from the type-stable pool.
const FROM_POOL: u32 = 1;

/// Per-node retire metadata. Embedded (via the scheme header) in every node;
/// written once at allocation (`flags`) and once at retire time (the rest).
/// After retire the node has a single logical owner (whoever holds the
/// retire list), so `Relaxed` atomics suffice — they exist to make the type
/// `Sync` and to make the orphan hand-off explicit.
#[derive(Default)]
#[repr(C)]
pub struct RetireHeader {
    /// Intrusive link in a retire list (`*mut RetireHeader`).
    next: AtomicUsize,
    /// Chains ordered *sublists* in a global retire list (only meaningful
    /// on a sublist's head node).
    next_list: AtomicUsize,
    /// Scheme stamp at retire time (epoch / stamp value).
    stamp: AtomicU64,
    /// The full node pointer (`*mut Node<T, R>` erased to `*mut ()`).
    node: AtomicUsize,
    /// `unsafe fn(*mut ())` that drops the payload and frees the node.
    drop_fn: AtomicUsize,
    /// `*const AtomicU64` to the owning domain's pending-retire counter
    /// (null for nodes freed without retiring). Written by the domain
    /// wrapper layer *before* the scheme's `retire` runs; decremented by
    /// [`reclaim_one`]. The counter outlives the node: nodes are reclaimed
    /// either by handles (which pin the domain) or by `Domain::drop`'s
    /// drain (the domain is still alive while dropping).
    pending: AtomicUsize,
    /// [`FROM_POOL`] etc.; written at allocation.
    flags: AtomicU32,
}

/// Type-erased pointer to a retired node's header.
pub type Retired = *mut RetireHeader;

/// Access to the embedded [`RetireHeader`]; every scheme header implements
/// this so generic machinery (orphan lists, node allocation) can reach it.
pub trait AsRetireHeader: Default + Send + Sync + 'static {
    fn retire_header(&self) -> &RetireHeader;
}

impl RetireHeader {
    /// Record (at allocation) whether the node memory is pool-backed.
    pub(crate) fn set_from_pool(&self, pooled: bool) {
        self.flags.store(if pooled { FROM_POOL } else { 0 }, Ordering::Relaxed);
    }

    pub(crate) fn is_from_pool(&self) -> bool {
        self.flags.load(Ordering::Relaxed) & FROM_POOL != 0
    }

    /// The scheme stamp assigned at retire time.
    #[inline]
    pub fn stamp(&self) -> u64 {
        self.stamp.load(Ordering::Relaxed)
    }

    #[inline]
    fn next(&self) -> Retired {
        self.next.load(Ordering::Relaxed) as Retired
    }

    /// The next retired node in a detached chain (crate-internal; used when
    /// re-linking chains taken via [`RetireList::take_chain`]).
    #[inline]
    pub(crate) fn next_in_chain(&self) -> Retired {
        self.next()
    }

    /// Link `n` after this node in a detached chain (crate-internal).
    /// Hyaline chains batches manually — its birth-era stamps are not
    /// monotone in retire order, so [`RetireList::push_back`]'s sortedness
    /// invariant does not apply to them.
    #[inline]
    pub(crate) fn set_next_in_chain(&self, n: Retired) {
        self.set_next(n);
    }

    /// Address of the retired node (what hazard slots publish).
    #[inline]
    pub(crate) fn node_addr(&self) -> usize {
        self.node.load(Ordering::Relaxed)
    }

    #[inline]
    fn set_next(&self, n: Retired) {
        self.next.store(n as usize, Ordering::Relaxed);
    }

    #[inline]
    pub(crate) fn next_list(&self) -> Retired {
        self.next_list.load(Ordering::Relaxed) as Retired
    }

    #[inline]
    pub(crate) fn set_next_list(&self, n: Retired) {
        self.next_list.store(n as usize, Ordering::Relaxed);
    }

    /// Tag this node with its domain's pending-retire counter (called by the
    /// domain wrapper before the scheme's `retire`; see field docs). Visibility
    /// rides the same mechanism as `drop_fn`: every path to [`reclaim_one`]
    /// passes through an atomic that orders the retire-time header stores.
    pub(crate) fn set_pending_counter(&self, counter: &AtomicU64) {
        self.pending.store(counter as *const AtomicU64 as usize, Ordering::Relaxed);
    }
}

/// Erased destructor for `Node<T, R>`: drop the payload, free the memory.
///
/// # Safety
/// `node` must be a `*mut Node<T, R>` produced by [`super::alloc_node`],
/// retired exactly once and no longer reachable by any thread.
unsafe fn drop_node_erased<T: Send + Sync + 'static, R: Reclaimer>(node: *mut ()) {
    super::free_node::<T, R>(node as *mut Node<T, R>);
}

/// Fill a node's retire header: stamp, self pointer, erased destructor.
/// Called by schemes at the top of `retire`.
///
/// # Safety
/// `node` must be valid and owned by the caller for retiring.
pub unsafe fn prepare_retire<T: Send + Sync + 'static, R: Reclaimer>(
    node: *mut Node<T, R>,
    stamp: u64,
) -> Retired {
    let hdr = (*node).header().retire_header();
    hdr.stamp.store(stamp, Ordering::Relaxed);
    hdr.node.store(node as usize, Ordering::Relaxed);
    hdr.drop_fn.store(drop_node_erased::<T, R> as *const () as usize, Ordering::Relaxed);
    hdr.set_next(ptr::null_mut());
    hdr.set_next_list(ptr::null_mut());
    hdr as *const RetireHeader as Retired
}

/// Reclaim one retired node: run its erased destructor.
///
/// Pool-backed nodes return to the calling thread's magazine rack (see the
/// module docs), so a thread that both reclaims and allocates reuses hot
/// slots without touching the global free-list.
///
/// # Safety
/// The node must be safe to reclaim (no live references) and reclaimed
/// exactly once.
pub unsafe fn reclaim_one(r: Retired) {
    crate::trace::event!("smr.reclaim");
    let hdr = &*r;
    let node = hdr.node.load(Ordering::Relaxed) as *mut ();
    let drop_fn: unsafe fn(*mut ()) =
        std::mem::transmute(hdr.drop_fn.load(Ordering::Relaxed));
    // Read the domain counter *before* drop_fn frees the header's memory.
    let pending = hdr.pending.load(Ordering::Relaxed) as *const AtomicU64;
    drop_fn(node);
    if !pending.is_null() {
        // SAFETY: the counter lives in the node's domain, which is alive for
        // the duration of any reclaim (see the `pending` field docs).
        (*pending).fetch_sub(1, Ordering::Relaxed);
    }
}

/// Thread-private FIFO retire list, append-ordered by stamp (appending with
/// monotonically non-decreasing stamps keeps it sorted — the invariant the
/// reclaim-prefix scan relies on).
pub struct RetireList {
    head: Retired,
    tail: Retired,
    len: usize,
}

impl Default for RetireList {
    fn default() -> Self {
        Self::new()
    }
}

impl RetireList {
    pub const fn new() -> Self {
        Self { head: ptr::null_mut(), tail: ptr::null_mut(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.head.is_null()
    }

    /// Stamp of the oldest (front) entry, if any.
    pub fn front_stamp(&self) -> Option<u64> {
        // SAFETY: head, when non-null, is a retired node we own.
        (!self.head.is_null()).then(|| unsafe { (*self.head).stamp() })
    }

    /// Append one retired node (stamps must be non-decreasing; debug-checked).
    pub fn push_back(&mut self, r: Retired) {
        // SAFETY: r is a valid retired node owned by the caller.
        unsafe {
            debug_assert!(self.tail.is_null() || (*self.tail).stamp() <= (*r).stamp());
            (*r).set_next(ptr::null_mut());
        }
        if self.tail.is_null() {
            self.head = r;
        } else {
            // SAFETY: tail is valid while the list is non-empty.
            unsafe { (*self.tail).set_next(r) };
        }
        self.tail = r;
        self.len += 1;
    }

    /// Reclaim the longest prefix whose stamps satisfy `can_reclaim`.
    /// Returns the number of nodes reclaimed.
    ///
    /// # Safety
    /// `can_reclaim(stamp) == true` must imply no thread still references
    /// nodes retired at `stamp` (the scheme's Proposition-1 argument).
    pub unsafe fn reclaim_prefix(&mut self, mut can_reclaim: impl FnMut(u64) -> bool) -> usize {
        let mut freed = 0;
        while !self.head.is_null() {
            let hdr = &*self.head;
            if !can_reclaim(hdr.stamp()) {
                break;
            }
            let next = hdr.next();
            reclaim_one(self.head);
            self.head = next;
            freed += 1;
        }
        if self.head.is_null() {
            self.tail = ptr::null_mut();
        }
        self.len -= freed;
        freed
    }

    /// Reclaim everything (used on clean shutdown when safety is externally
    /// guaranteed, e.g. all threads stopped).
    ///
    /// # Safety
    /// No thread may reference any node in the list.
    pub unsafe fn reclaim_all(&mut self) -> usize {
        self.reclaim_prefix(|_| true)
    }

    /// Detach the whole chain (head pointer), leaving the list empty.
    /// The chain stays linked via `next` and sorted by stamp.
    pub fn take_chain(&mut self) -> (Retired, usize) {
        let (h, n) = (self.head, self.len);
        self.head = ptr::null_mut();
        self.tail = ptr::null_mut();
        self.len = 0;
        (h, n)
    }
}

impl Drop for RetireList {
    fn drop(&mut self) {
        // Retire lists must be drained or handed off before drop; leaking
        // here would hide bugs, so be loud in debug builds.
        debug_assert!(self.is_empty(), "RetireList dropped with {} entries", self.len);
    }
}

/// Lock-free global list of retired-node *sublists*.
///
/// Each pushed chain is an ordered sublist; chains are linked through the
/// head node's `next_list` pointer. Consumers either steal everything
/// ([`Self::steal_all`], the epoch-family orphan protocol) or scan sublists
/// up to the first non-reclaimable node (Stamp-it's global reclaim, §3).
pub struct GlobalRetireList {
    head: AtomicUsize, // Retired (sublist head) chained via next_list
}

impl Default for GlobalRetireList {
    fn default() -> Self {
        Self::new()
    }
}

impl GlobalRetireList {
    pub const fn new() -> Self {
        Self { head: AtomicUsize::new(0) }
    }

    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Acquire) == 0
    }

    /// Push an ordered sublist (chain linked via `next`). O(1).
    pub fn push_sublist(&self, chain: Retired) {
        if chain.is_null() {
            return;
        }
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            // SAFETY: we own `chain` until the CAS succeeds.
            unsafe { (*chain).set_next_list(head as Retired) };
            match self.head.compare_exchange_weak(
                head,
                chain as usize,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(h) => head = h,
            }
        }
    }

    /// Steal the entire list (all sublists). Returns the sublist chain head.
    pub fn steal_all(&self) -> Retired {
        self.head.swap(0, Ordering::AcqRel) as Retired
    }

    /// Reclaim every node (across all sublists) whose stamp satisfies
    /// `can_reclaim`; unreclaimable suffixes are pushed back. Returns the
    /// number reclaimed. This is the steal → reclaim → re-add protocol the
    /// paper describes (§4.4) — prone to the end-of-run race it discusses,
    /// which Stamp-it's last-thread rule avoids at its call site.
    ///
    /// # Safety
    /// Same contract as [`RetireList::reclaim_prefix`].
    pub unsafe fn reclaim_where(&self, mut can_reclaim: impl FnMut(u64) -> bool) -> usize {
        let mut sublist = self.steal_all();
        let mut freed = 0;
        while !sublist.is_null() {
            let next_list = (*sublist).next_list();
            // Scan this ordered sublist's reclaimable prefix.
            let mut cur = sublist;
            while !cur.is_null() && can_reclaim((*cur).stamp()) {
                let next = (*cur).next();
                reclaim_one(cur);
                freed += 1;
                cur = next;
            }
            // Push back the unreclaimable remainder (still ordered).
            self.push_sublist(cur);
            sublist = next_list;
        }
        freed
    }

    /// Total nodes currently parked here (O(n); diagnostics only).
    pub fn count(&self) -> usize {
        let mut n = 0;
        let mut sublist = self.head.load(Ordering::Acquire) as Retired;
        while !sublist.is_null() {
            // SAFETY: nodes on the global list are quiescent; traversal is
            // racy with steal_all and only used in tests/diagnostics where
            // no concurrent steal runs.
            unsafe {
                let mut cur = sublist;
                while !cur.is_null() {
                    n += 1;
                    cur = (*cur).next();
                }
                sublist = (*sublist).next_list();
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::leaky::Leaky;
    use crate::reclaim::{alloc_node, HeaderOf};
    use std::sync::atomic::AtomicUsize as StdAtomicUsize;
    use std::sync::Arc;

    /// Payload that counts drops.
    struct DropCounter(Arc<StdAtomicUsize>);
    impl Drop for DropCounter {
        fn drop(&mut self) {
            self.0.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn retired(stamp: u64, drops: &Arc<StdAtomicUsize>) -> Retired {
        let node = alloc_node::<DropCounter, Leaky>(DropCounter(drops.clone()));
        unsafe { prepare_retire::<DropCounter, Leaky>(node, stamp) }
    }

    #[test]
    fn header_is_reachable_through_scheme_header() {
        let node = alloc_node::<u32, Leaky>(3);
        let hdr: &HeaderOf<Leaky> = unsafe { (*node).header() };
        assert!(!hdr.retire_header().is_from_pool() || hdr.retire_header().is_from_pool());
        unsafe { crate::reclaim::free_node(node) };
    }

    #[test]
    fn prefix_reclaim_respects_stamps() {
        let drops = Arc::new(StdAtomicUsize::new(0));
        let mut list = RetireList::new();
        for s in [1, 2, 3, 5, 8] {
            list.push_back(retired(s, &drops));
        }
        assert_eq!(list.len(), 5);
        assert_eq!(list.front_stamp(), Some(1));
        let freed = unsafe { list.reclaim_prefix(|s| s < 4) };
        assert_eq!(freed, 3);
        assert_eq!(drops.load(Ordering::Relaxed), 3);
        assert_eq!(list.front_stamp(), Some(5));
        let freed = unsafe { list.reclaim_all() };
        assert_eq!(freed, 2);
        assert_eq!(drops.load(Ordering::Relaxed), 5);
        assert!(list.is_empty());
    }

    #[test]
    fn take_chain_preserves_order() {
        let drops = Arc::new(StdAtomicUsize::new(0));
        let mut list = RetireList::new();
        for s in [10, 20, 30] {
            list.push_back(retired(s, &drops));
        }
        let (chain, n) = list.take_chain();
        assert_eq!(n, 3);
        assert!(list.is_empty());
        unsafe {
            assert_eq!((*chain).stamp(), 10);
            assert_eq!((*(*chain).next()).stamp(), 20);
        }
        // Re-attach and drain to not leak.
        let mut l2 = RetireList::new();
        let mut cur = chain;
        while !cur.is_null() {
            let next = unsafe { (*cur).next() };
            l2.push_back(cur);
            cur = next;
        }
        unsafe { l2.reclaim_all() };
        assert_eq!(drops.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn global_list_sublist_scan() {
        let drops = Arc::new(StdAtomicUsize::new(0));
        let global = GlobalRetireList::new();
        assert!(global.is_empty());

        // Two ordered sublists: [1,4,9] and [2,3,50].
        for stamps in [[1, 4, 9], [2, 3, 50]] {
            let mut l = RetireList::new();
            for s in stamps {
                l.push_back(retired(s, &drops));
            }
            let (chain, _) = l.take_chain();
            global.push_sublist(chain);
        }
        assert_eq!(global.count(), 6);

        // Reclaim stamps < 5: 1,4 from the first list, 2,3 from the second.
        let freed = unsafe { global.reclaim_where(|s| s < 5) };
        assert_eq!(freed, 4);
        assert_eq!(drops.load(Ordering::Relaxed), 4);
        assert_eq!(global.count(), 2);

        let freed = unsafe { global.reclaim_where(|_| true) };
        assert_eq!(freed, 2);
        assert!(global.is_empty());
    }

    #[test]
    fn global_list_concurrent_push_steal() {
        let drops = Arc::new(StdAtomicUsize::new(0));
        let global = Arc::new(GlobalRetireList::new());
        let n_threads = 4;
        let per_thread = 100;
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                let global = global.clone();
                let drops = drops.clone();
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        global.push_sublist(retired(i as u64, &drops));
                        if i % 10 == 0 {
                            std::thread::yield_now();
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let freed = unsafe { global.reclaim_where(|_| true) };
        assert_eq!(freed, n_threads * per_thread);
        assert_eq!(drops.load(Ordering::Relaxed), n_threads * per_thread);
    }
}

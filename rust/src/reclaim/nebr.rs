//! NER — "new epoch-based reclamation" (Hart et al. 2007): identical epoch
//! protocol to ER, but critical regions are *application-scoped* — the
//! benchmark wraps 100 operations in one `region_guard` (paper §4.2), so
//! the entry/exit cost and the epoch bookkeeping are amortized across the
//! whole region instead of being paid per operation.
//!
//! In this crate the scheme mechanics are shared with [`super::ebr`]; the
//! semantic difference materializes through separate domains and the
//! benchmark drivers entering [`crate::reclaim::Region`]s.

use super::epoch_core::{epoch_reclaimer_impl, EpochConfig, EpochDomain};
use super::Domain;

/// New epoch-based reclamation (Hart et al.).
pub struct Nebr;

epoch_reclaimer_impl!(
    Nebr,
    "NER",
    EpochConfig {
        advance_every: 100, // paper §4.2
        debra_check_every: None,
        quiescent_at_exit: false,
    }
);

/// The global domain's epoch state (benchmark diagnostics / ablations).
pub fn domain() -> &'static EpochDomain {
    Domain::<Nebr>::global().state()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;

    #[test]
    fn nodes_reclaimed_after_epoch_advances() {
        exercise_basic_reclamation::<Nebr>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        exercise_guard_blocks_reclamation::<Nebr>();
    }

    #[test]
    fn region_guard_amortizes_and_protects() {
        exercise_region_guard::<Nebr>();
    }

    #[test]
    fn concurrent_smoke() {
        exercise_concurrent_smoke::<Nebr>(4, 500);
    }
}

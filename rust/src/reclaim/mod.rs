//! Safe memory reclamation (SMR) for lock-free data structures.
//!
//! The module is layered (DESIGN.md §2):
//!
//! 1. The **facade** ([`facade`]): [`Atomic`], [`Guard`], [`Shared`],
//!    [`Owned`] and [`HandleSource`] — the lifetime-branded, safe surface
//!    data structures are written against. `unsafe` at ds level narrows to
//!    the unlink-then-retire sites.
//! 2. The raw rendering of the C++ interface the paper builds on
//!    (Robison's N3712 proposal, paper §2): [`MarkedPtr`] (`marked_ptr`),
//!    [`ConcurrentPtr`] (`concurrent_ptr`), the crate-internal `GuardPtr`
//!    (`guard_ptr`) and [`Region`] (`region_guard`), generic over a
//!    [`Reclaimer`].
//! 3. Instance-based **reclamation domains** (see [`domain`]):
//!
//! * [`Domain<R>`] owns one instance of a scheme's shared state (what used
//!   to be process-global statics); [`Domain::global()`] is the default.
//! * [`LocalHandle<R>`] caches a thread's registry entry + retire list for
//!   one domain; guards and regions created through it touch no TLS and no
//!   `RefCell` on the fast path.
//! * [`DomainRef<R>`] (global | owned `Arc`) is what data structures store;
//!   their default constructors use the global domain so the quickstart API
//!   stays one-liner simple, while `new_in` gives every shard/test/trial
//!   its own isolated reclamation universe.
//!
//! Eight schemes implement [`Reclaimer`]:
//!
//! | scheme | module | origin |
//! |--------|--------|--------|
//! | Stamp-it (the paper's contribution) | [`stamp`] | Pöter & Träff 2018 |
//! | Lock-free reference counting (LFRC) | [`lfrc`] | Valois 1995 |
//! | Hazard pointers (HPR) | [`hp`] | Michael 2004 |
//! | Epoch-based (ER) | [`ebr`] | Fraser 2004 |
//! | New epoch-based (NER) | [`nebr`] | Hart et al. 2007 |
//! | Quiescent-state-based (QSR) | [`qsr`] | McKenney & Slingwine 1998 |
//! | DEBRA | [`debra`] | Brown 2015 |
//! | Hyaline (robust, batch-refcounted) | [`hyaline`] | Nikolaev & Ravindran 2019 |
//! | Leaky baseline (never reclaims) | [`leaky`] | — |
//!
//! The first seven form the paper's comparison set ([`SchemeId::PAPER_SET`]);
//! Hyaline extends it with a stall-robust scheme (E19) and is opt-in via
//! `--schemes hyaline`.
//!
//! The memory-model discipline follows the paper: Rust shares the C++11
//! memory model, and each atomic operation below carries the weakest
//! ordering we can argue correct (documented at the call sites).

pub mod concurrent_ptr;
pub mod debra;
pub mod domain;
pub mod ebr;
pub mod epoch_core;
pub mod facade;
pub mod hp;
pub mod hyaline;
pub mod leaky;
pub mod lfrc;
pub mod marked_ptr;
pub mod nebr;
pub mod qsr;
pub mod registry;
pub mod retire;
pub mod stamp;
#[doc(hidden)]
pub mod tests_common;

pub use concurrent_ptr::ConcurrentPtr;
pub use domain::{set_default_stall_watermark, Domain, DomainRef, LocalCell, LocalHandle, Region};
pub use facade::{Atomic, Cached, Guard, HandleSource, Owned, Shared, Stale};
pub use marked_ptr::MarkedPtr;
pub use retire::AsRetireHeader;

use std::alloc::Layout;
use std::mem::ManuallyDrop;

/// Shorthand for a reclaimer's node header type.
pub type HeaderOf<R> = <R as Reclaimer>::Header;

/// A reclaimable node: scheme header + user payload.
///
/// `repr(C)` with the header first: LFRC relies on its refcount word being
/// the node's first word (see [`crate::alloc::pool`]), and the retire
/// machinery recovers node pointers stored at retire time.
#[repr(C)]
pub struct Node<T, R: Reclaimer> {
    header: R::Header,
    data: ManuallyDrop<T>,
}

impl<T, R: Reclaimer> Node<T, R> {
    /// The scheme header.
    #[inline]
    pub fn header(&self) -> &R::Header {
        &self.header
    }

    /// The user payload.
    #[inline]
    pub fn data(&self) -> &T {
        &self.data
    }
}

/// Allocate a node (policy-routed, counted). The node starts unpublished —
/// the caller links it into a structure via [`ConcurrentPtr`] CAS.
/// Allocation is domain-independent: the domain matters only at retire
/// time, so a node must be retired into the domain whose regions/hazards
/// protect its readers.
///
/// Pool-routed allocations are served from the calling thread's magazine
/// rack first ([`crate::alloc::magazine`]): in steady-state churn the slot
/// returned here is one this thread reclaimed moments ago, without any
/// shared-cache-line traffic.
pub fn alloc_node<T: Send + Sync + 'static, R: Reclaimer>(data: T) -> *mut Node<T, R> {
    let layout = Layout::new::<Node<T, R>>();
    // The node is tagged with the provenance `alloc_raw` *actually used*
    // (single policy sample) — re-sampling the policy here would race with
    // a concurrent ablation-knob toggle and mis-route the eventual free.
    let (raw, pooled) = crate::alloc::alloc_raw(layout, R::FORCE_POOL);
    let raw = raw as *mut Node<T, R>;
    // SAFETY: fresh allocation of the right layout.
    unsafe {
        raw.write(Node { header: R::Header::default(), data: ManuallyDrop::new(data) });
        (*raw).header.retire_header().set_from_pool(pooled);
        R::on_alloc(raw);
    }
    raw
}

/// Drop a node's payload and free its memory.
///
/// # Safety
/// `node` must come from [`alloc_node`], be unreachable by all other
/// threads, and not be used afterwards. Must be called at most once.
pub unsafe fn free_node<T: Send + Sync + 'static, R: Reclaimer>(node: *mut Node<T, R>) {
    let pooled = (*node).header.retire_header().is_from_pool();
    free_node_parts::<T, R>(node, pooled, true)
}

/// Free a node with explicit control over payload dropping (LFRC drops the
/// payload when the refcount hits zero but recycles the allocation).
///
/// # Safety
/// Same as [`free_node`]; if `drop_payload` is false the payload must have
/// been dropped already.
pub unsafe fn free_node_parts<T: Send + Sync + 'static, R: Reclaimer>(
    node: *mut Node<T, R>,
    pooled: bool,
    drop_payload: bool,
) {
    if drop_payload {
        ManuallyDrop::drop(&mut (*node).data);
    }
    std::ptr::drop_in_place(&mut (*node).header);
    crate::alloc::free_raw(node as *mut u8, Layout::new::<Node<T, R>>(), pooled);
}

/// A safe-memory-reclamation scheme, shaped as a two-layer instance model:
/// every operation takes the owning [`Domain`]'s state and the calling
/// thread's [`LocalCell`]-wrapped local state (resolved once by a
/// [`LocalHandle`] — no TLS on the fast path).
///
/// # Safety
/// Implementations must guarantee, **per domain**: a node passed to
/// [`Reclaimer::retire`] is dropped/freed only after every [`GuardPtr`]
/// registered with the *same domain* that protected it *before* the retire
/// has been reset — the paper's Proposition 1 ("a node is reclaimed only
/// when it is referenced by no thread"). Protection established by
/// `protect`/`protect_if_equal` must hold until the matching `release`.
/// Domains must be independent: state shared between two `DomainState`
/// instances would silently couple their reclamation decisions.
pub unsafe trait Reclaimer: Sized + Send + Sync + 'static {
    /// Scheme name as used in benchmark output (paper plot legends).
    const NAME: &'static str;

    /// LFRC sets this: node memory must be type-stable (pool-backed).
    const FORCE_POOL: bool = false;

    /// Per-node header; must expose the embedded [`retire::RetireHeader`].
    type Header: AsRetireHeader;

    /// Per-guard scheme state (hazard slot, region token, ...).
    type GuardState: Default;

    /// Shared scheme state owned by a [`Domain`] (stamp pool, epoch state,
    /// hazard registry, global retire lists — the former statics).
    type DomainState: Send + Sync + 'static;

    /// Per-thread, per-domain state cached by a [`LocalHandle`] (registry
    /// entry, local retire list, nesting counters).
    type LocalState: 'static;

    /// Fresh shared state for a new [`Domain`].
    fn new_domain_state() -> Self::DomainState;

    /// The process-wide default domain. Schemes generate this (plus the
    /// thread-local handle cache behind [`Self::cached_handle`]) with the
    /// crate-internal `impl_domain_statics!` macro — statics cannot be
    /// generic.
    fn global() -> &'static Domain<Self>;

    /// The calling thread's cached handle for `domain`, registering on
    /// first use. `None` during thread teardown (TLS gone) — callers fall
    /// back to an ephemeral registration.
    fn cached_handle(domain: &DomainRef<Self>) -> Option<LocalHandle<Self>>;

    /// Register the calling thread with a domain (acquire/recycle the
    /// registry entry, fresh retire list). Must not run user code.
    fn register(domain: &Self::DomainState) -> Self::LocalState;

    /// Release a thread's attachment: hand unreclaimed retired nodes to the
    /// domain's shared lists and recycle the registry entry. May run user
    /// drops (e.g. a final HP scan) — called with exclusive `local` access
    /// and no live guards/regions on this handle.
    fn unregister(domain: &Self::DomainState, local: &mut Self::LocalState);

    /// Enter a critical region (reentrant; guards nest inside). No-op for
    /// schemes whose protection is per-guard (HPR, LFRC, leaky).
    fn enter_region(_domain: &Self::DomainState, _local: &LocalCell<Self::LocalState>) {}

    /// Leave a critical region; typically reclaims the eligible local
    /// prefix (runs user drops — never under a [`LocalCell`] borrow).
    fn exit_region(_domain: &Self::DomainState, _local: &LocalCell<Self::LocalState>) {}

    /// `guard_ptr::acquire`: snapshot `src` and protect the target until
    /// `release`. Returns the protected (possibly null/marked) value.
    fn protect<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
    ) -> MarkedPtr<T, Self>;

    /// `guard_ptr::acquire_if_equal`: protect only if `src` still holds
    /// `expected`; never loops unboundedly (wait-free for HPR — paper §2).
    /// Returns true on success (protection established or expected null).
    fn protect_if_equal<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
        expected: MarkedPtr<T, Self>,
    ) -> bool;

    /// Drop the protection for `ptr` (guard reset). `ptr` is the value the
    /// matching `protect` returned (non-null).
    fn release<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        ptr: MarkedPtr<T, Self>,
    );

    /// Return guard resources (hazard slot, region nesting) on guard drop.
    fn drop_guard_state(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
    ) {
    }

    /// Scheme hook running right after a node is allocated and initialized
    /// (still private to the allocating thread). LFRC uses it to prepare the
    /// type-erased destructor and atomically arm its refcount word.
    ///
    /// # Safety
    /// `node` is a fresh, fully initialized, unpublished node.
    unsafe fn on_alloc<T: Send + Sync + 'static>(_node: *mut Node<T, Self>) {}

    /// Retire a node into a domain: reclaim it once no thread registered
    /// with that domain can hold a reference.
    ///
    /// # Safety
    /// The node must be unlinked (unreachable for new references), retired
    /// exactly once, allocated by [`alloc_node`] for this scheme, and its
    /// readers must be protected through the *same* domain.
    unsafe fn retire<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        node: *mut Node<T, Self>,
    );

    /// Best-effort: reclaim everything currently reclaimable (bench/test
    /// hook; e.g. forces an epoch advance attempt or HP scan).
    fn flush(_domain: &Self::DomainState, _local: &LocalCell<Self::LocalState>) {}

    /// Reclaim every node still parked in the domain's shared lists. Called
    /// from `Domain::drop` with exclusive access — no handles, guards or
    /// regions exist, so everything retired is reclaimable.
    fn drain_domain(_domain: &mut Self::DomainState) {}
}

/// `guard_ptr` (paper §2): shared ownership of one node. While a non-null
/// `GuardPtr` holds a node, the node will not be reclaimed.
///
/// Crate-internal since the facade redesign: user code holds the
/// lifetime-branded [`facade::Guard`] instead, which wraps a `GuardPtr`
/// and mediates access through [`facade::Shared`]. Guards stay attached
/// to the [`LocalHandle`] that created them: acquire/release resolve the
/// thread's cached registry entry through the handle — no TLS lookup per
/// operation. Guards are single-threaded, like the handle they came from.
pub(crate) struct GuardPtr<T: Send + Sync + 'static, R: Reclaimer> {
    ptr: MarkedPtr<T, R>,
    state: R::GuardState,
    handle: LocalHandle<R>,
}

impl<T: Send + Sync + 'static, R: Reclaimer> GuardPtr<T, R> {
    /// An empty guard attached to `handle` (see [`LocalHandle::guard`]).
    pub(crate) fn new_in(handle: &LocalHandle<R>) -> Self {
        facade::lint::guard_created();
        Self { ptr: MarkedPtr::null(), state: R::GuardState::default(), handle: handle.clone() }
    }

    /// Atomically snapshot `src` and protect the target (paper: `acquire`).
    /// Returns the protected value (also kept in the guard).
    pub(crate) fn acquire(&mut self, src: &ConcurrentPtr<T, R>) -> MarkedPtr<T, R> {
        self.reset();
        self.ptr =
            R::protect(self.handle.domain_state(), self.handle.local(), &mut self.state, src);
        self.ptr
    }

    /// Protect only if `src` still equals `expected`; returns whether the
    /// snapshot succeeded (paper: `acquire_if_equal`).
    pub(crate) fn acquire_if_equal(
        &mut self,
        src: &ConcurrentPtr<T, R>,
        expected: MarkedPtr<T, R>,
    ) -> bool {
        self.reset();
        if R::protect_if_equal(
            self.handle.domain_state(),
            self.handle.local(),
            &mut self.state,
            src,
            expected,
        ) {
            self.ptr = expected;
            true
        } else {
            false
        }
    }

    /// The guarded value (null if empty). Mark bits are preserved from the
    /// acquire-time snapshot.
    #[inline]
    pub(crate) fn get(&self) -> MarkedPtr<T, R> {
        self.ptr
    }

    /// Is the guard empty?
    #[inline]
    pub(crate) fn is_null(&self) -> bool {
        self.ptr.is_null()
    }

    /// Release ownership; the guard becomes empty (paper: `reset`).
    pub(crate) fn reset(&mut self) {
        if !self.ptr.is_null() {
            R::release(self.handle.domain_state(), self.handle.local(), &mut self.state, self.ptr);
            self.ptr = MarkedPtr::null();
        }
    }

    /// Mark the guarded node for reclamation once safe, and reset the guard
    /// (paper: `reclaim`). Retires into the guard's domain.
    ///
    /// # Safety
    /// The node must be unlinked from its data structure: no new references
    /// can be created from any `ConcurrentPtr`, and `retire` is called at
    /// most once for the node across all threads.
    pub(crate) unsafe fn reclaim(&mut self) {
        debug_assert!(!self.ptr.is_null());
        let node = self.ptr.get();
        self.reset();
        // Route through the handle wrapper so the domain's pending-retire
        // accounting always runs (one funnel for every retire path).
        self.handle.retire(node);
    }
}

impl<T: Send + Sync + 'static, R: Reclaimer> Drop for GuardPtr<T, R> {
    fn drop(&mut self) {
        self.reset();
        R::drop_guard_state(self.handle.domain_state(), self.handle.local(), &mut self.state);
        facade::lint::guard_dropped();
    }
}

/// Identifiers for the implemented schemes (benchmark configuration).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum SchemeId {
    Leaky,
    Lfrc,
    Hp,
    Ebr,
    Nebr,
    Qsr,
    Debra,
    Stamp,
    Hyaline,
}

impl SchemeId {
    /// All schemes the paper compares (Figures 3–19), in legend order.
    pub const PAPER_SET: [SchemeId; 7] = [
        SchemeId::Lfrc,
        SchemeId::Hp,
        SchemeId::Ebr,
        SchemeId::Nebr,
        SchemeId::Qsr,
        SchemeId::Debra,
        SchemeId::Stamp,
    ];

    pub fn parse(s: &str) -> Option<SchemeId> {
        Some(match s.to_ascii_lowercase().as_str() {
            "leaky" | "none" => SchemeId::Leaky,
            "lfrc" => SchemeId::Lfrc,
            "hp" | "hpr" => SchemeId::Hp,
            "ebr" | "er" | "epoch" => SchemeId::Ebr,
            "nebr" | "ner" => SchemeId::Nebr,
            "qsr" | "qsbr" => SchemeId::Qsr,
            "debra" => SchemeId::Debra,
            "stamp" | "stampit" | "stamp-it" => SchemeId::Stamp,
            "hyaline" => SchemeId::Hyaline,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SchemeId::Leaky => "Leaky",
            SchemeId::Lfrc => "LFRC",
            SchemeId::Hp => "HPR",
            SchemeId::Ebr => "ER",
            SchemeId::Nebr => "NER",
            SchemeId::Qsr => "QSR",
            SchemeId::Debra => "DEBRA",
            SchemeId::Stamp => "Stamp-it",
            SchemeId::Hyaline => "Hyaline",
        }
    }

    /// Parse a comma-separated scheme list; `all`/`paper` expands to the
    /// paper's comparison set.
    pub fn parse_list(s: &str) -> Option<Vec<SchemeId>> {
        if s == "all" || s == "paper" {
            return Some(Self::PAPER_SET.to_vec());
        }
        s.split(',').map(|p| Self::parse(p.trim())).collect()
    }
}

/// Monomorphize a generic function over a runtime [`SchemeId`]:
/// `dispatch_scheme!(id, run_bench, arg1, arg2)` calls
/// `run_bench::<SchemeType>(arg1, arg2)`.
#[macro_export]
macro_rules! dispatch_scheme {
    ($id:expr, $f:ident $(, $args:expr)* $(,)?) => {{
        use $crate::reclaim::SchemeId as __S;
        match $id {
            __S::Leaky => $f::<$crate::reclaim::leaky::Leaky>($($args),*),
            __S::Lfrc => $f::<$crate::reclaim::lfrc::Lfrc>($($args),*),
            __S::Hp => $f::<$crate::reclaim::hp::Hp>($($args),*),
            __S::Ebr => $f::<$crate::reclaim::ebr::Ebr>($($args),*),
            __S::Nebr => $f::<$crate::reclaim::nebr::Nebr>($($args),*),
            __S::Qsr => $f::<$crate::reclaim::qsr::Qsr>($($args),*),
            __S::Debra => $f::<$crate::reclaim::debra::Debra>($($args),*),
            __S::Stamp => $f::<$crate::reclaim::stamp::StampIt>($($args),*),
            __S::Hyaline => $f::<$crate::reclaim::hyaline::Hyaline>($($args),*),
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_id_parsing() {
        assert_eq!(SchemeId::parse("stamp-it"), Some(SchemeId::Stamp));
        assert_eq!(SchemeId::parse("HP"), Some(SchemeId::Hp));
        assert_eq!(SchemeId::parse("hyaline"), Some(SchemeId::Hyaline));
        assert_eq!(SchemeId::parse("bogus"), None);
        // `all` stays the paper's seven-scheme comparison set; Hyaline is
        // the opt-in robust extension.
        assert_eq!(SchemeId::parse_list("all").unwrap().len(), 7);
        assert!(!SchemeId::PAPER_SET.contains(&SchemeId::Hyaline));
        assert_eq!(
            SchemeId::parse_list("ebr, stamp").unwrap(),
            vec![SchemeId::Ebr, SchemeId::Stamp]
        );
        assert!(SchemeId::parse_list("ebr,nope").is_none());
    }

    #[test]
    fn scheme_names_match_paper_legends() {
        assert_eq!(SchemeId::Stamp.name(), "Stamp-it");
        assert_eq!(SchemeId::Hp.name(), "HPR");
        assert_eq!(SchemeId::Ebr.name(), "ER");
        assert_eq!(SchemeId::Hyaline.name(), "Hyaline");
    }
}

//! HPR — hazard pointers (Michael 2004), with the *dynamic* extension the
//! paper needs for its HashMap benchmark ("we have to use the extended
//! hazard pointer scheme that supports a dynamic number of hazard pointers
//! as explained by Michael").
//!
//! * Each thread owns a registry entry with `K_STATIC` inline hazard slots
//!   plus a chain of overflow chunks, allocated on demand and owned by the
//!   entry (the domain's registry arena frees entries and chunks together
//!   when the domain drops; while the domain lives they are recycled, never
//!   freed).
//! * `protect` publishes the candidate pointer in a slot and re-validates
//!   the source — the publish/validate handshake is ordered by a SeqCst
//!   fence that pairs with the SeqCst fence in `scan`.
//! * Retired nodes go to a thread-local list; when it exceeds the paper's
//!   threshold `100 + 2·ΣKᵢ` (§4.2; `ΣKᵢ` = total hazard slots across all
//!   threads) the thread scans: it snapshots all published hazards, frees
//!   every retired node not found, and keeps the rest.
//!
//! The per-thread unreclaimed population is therefore Θ(total slots) — the
//! quadratic-in-threads behaviour the paper measures in App. A.2.
//!
//! Registry, slot count and orphan list are per-[`HpDomain`] (one per
//! [`crate::reclaim::Domain`]); the slots + retire list a thread uses are
//! its [`HpLocal`], cached by a [`crate::reclaim::LocalHandle`].

use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};

use super::domain::LocalCell;
use super::registry::{EntryRef, ThreadList};
use super::retire::{
    prepare_retire, AsRetireHeader, GlobalRetireList, Retired, RetireHeader, RetireList,
};
use super::{ConcurrentPtr, Domain, MarkedPtr, Node, Reclaimer};

/// Inline hazard slots per thread (covers the queue/list benchmarks; the
/// hash-map benchmark grows beyond them dynamically).
const K_STATIC: usize = 8;
/// Slots per dynamically added chunk.
const CHUNK_SLOTS: usize = 16;

/// Hazard pointers (Michael).
pub struct Hp;

/// Node header: retire metadata only.
#[derive(Default)]
#[repr(C)]
pub struct HpHeader {
    retire: RetireHeader,
}

impl AsRetireHeader for HpHeader {
    fn retire_header(&self) -> &RetireHeader {
        &self.retire
    }
}

/// Dynamically added block of hazard slots. Owned by the registry entry it
/// is chained from (freed when the entry — i.e. the domain's registry
/// arena — drops).
struct SlotChunk {
    slots: [AtomicUsize; CHUNK_SLOTS],
    next: AtomicPtr<SlotChunk>,
}

/// Per-thread shared state: the hazard slots other threads scan.
pub struct HpSlots {
    inline: [AtomicUsize; K_STATIC],
    extra: AtomicPtr<SlotChunk>,
}

impl Default for HpSlots {
    fn default() -> Self {
        Self {
            inline: [const { AtomicUsize::new(0) }; K_STATIC],
            extra: AtomicPtr::new(ptr::null_mut()),
        }
    }
}

impl Drop for HpSlots {
    fn drop(&mut self) {
        // Runs only when the registry arena drops with its domain: no
        // thread can still publish into (or hold a SlotRef to) these
        // chunks — holders keep the domain alive.
        let mut chunk = *self.extra.get_mut();
        while !chunk.is_null() {
            // SAFETY: chunks were allocated via Box::into_raw in
            // acquire_slot and are exclusively ours now.
            let mut c = unsafe { Box::from_raw(chunk) };
            chunk = *c.next.get_mut();
        }
    }
}

/// Copyable reference to one hazard slot (inline in a registry entry or in
/// a [`SlotChunk`]). Valid while the owning domain is alive — every holder
/// (an [`HpLocal`] free-list or a guard's [`HpGuardState`]) sits behind a
/// `LocalHandle` that keeps the domain, hence the slot arena, alive.
#[derive(Clone, Copy)]
pub struct SlotRef(std::ptr::NonNull<AtomicUsize>);

// SAFETY: a SlotRef is a shared reference to an AtomicUsize in disguise
// (see validity above); AtomicUsize is Send + Sync.
unsafe impl Send for SlotRef {}
unsafe impl Sync for SlotRef {}

impl SlotRef {
    fn new(slot: &AtomicUsize) -> Self {
        Self(std::ptr::NonNull::from(slot))
    }
}

impl std::ops::Deref for SlotRef {
    type Target = AtomicUsize;

    #[inline]
    fn deref(&self) -> &AtomicUsize {
        // SAFETY: validity contract in the type docs.
        unsafe { self.0.as_ref() }
    }
}

/// One hazard-pointer reclamation universe (the `DomainState` of [`Hp`]).
pub struct HpDomain {
    threads: ThreadList<HpSlots>,
    /// ΣKᵢ — total hazard slots ever allocated in this domain (inline +
    /// chunks), for the paper's scan threshold.
    total_slots: AtomicU64,
    orphans: GlobalRetireList,
    /// Base term of the scan threshold (paper §4.2: 100); runtime-tunable
    /// per domain for ablation bench A2.
    threshold_base: AtomicU64,
}

impl HpDomain {
    fn new() -> Self {
        Self {
            threads: ThreadList::new(),
            total_slots: AtomicU64::new(0),
            orphans: GlobalRetireList::new(),
            threshold_base: AtomicU64::new(100),
        }
    }

    /// Tune the scan-threshold base (paper value: 100).
    pub fn set_threshold_base(&self, n: usize) {
        self.threshold_base.store(n as u64, Ordering::Relaxed);
    }

    /// Total hazard slots across all threads of this domain (ΣKᵢ).
    pub fn total_slots(&self) -> u64 {
        self.total_slots.load(Ordering::Relaxed)
    }

    /// Current scan threshold `base + 2·ΣKᵢ` (diagnostics / ablations).
    pub fn current_threshold(&self) -> usize {
        self.threshold_base.load(Ordering::Relaxed) as usize
            + 2 * self.total_slots.load(Ordering::Relaxed) as usize
    }
}

/// Thread-local hazard-pointer state (the `LocalState` cached by a handle).
pub struct HpLocal {
    entry: EntryRef<HpSlots>,
    /// Currently unpublished slots available to guards.
    free_slots: Vec<SlotRef>,
    retired: RetireList,
}

impl HpLocal {
    fn register(domain: &HpDomain) -> Self {
        let mut fresh_entry = false;
        let entry = domain.threads.acquire(
            || {
                fresh_entry = true;
                HpSlots::default()
            },
            |_| {},
        );
        if fresh_entry {
            domain.total_slots.fetch_add(K_STATIC as u64, Ordering::Relaxed);
        }
        // Collect every slot of the entry (inline + previously grown
        // chunks) — all must be unpublished (previous owner's guards are
        // dropped before its handle is).
        let mut free_slots: Vec<SlotRef> = Vec::with_capacity(K_STATIC);
        for s in &entry.data().inline {
            debug_assert_eq!(s.load(Ordering::Relaxed), 0);
            free_slots.push(SlotRef::new(s));
        }
        let mut chunk = entry.data().extra.load(Ordering::Acquire);
        while !chunk.is_null() {
            // SAFETY: chunks live as long as their entry, i.e. the domain.
            let c = unsafe { &*chunk };
            for s in &c.slots {
                debug_assert_eq!(s.load(Ordering::Relaxed), 0);
                free_slots.push(SlotRef::new(s));
            }
            chunk = c.next.load(Ordering::Acquire);
        }
        Self { entry, free_slots, retired: RetireList::new() }
    }

    /// Take a free slot, growing the dynamic chunk chain if needed
    /// (Michael's extended scheme).
    fn acquire_slot(&mut self, domain: &HpDomain) -> SlotRef {
        if let Some(s) = self.free_slots.pop() {
            return s;
        }
        let chunk = Box::into_raw(Box::new(SlotChunk {
            slots: [const { AtomicUsize::new(0) }; CHUNK_SLOTS],
            next: AtomicPtr::new(ptr::null_mut()),
        }));
        domain.total_slots.fetch_add(CHUNK_SLOTS as u64, Ordering::Relaxed);
        // Prepend to the entry's chunk chain (publish with Release so
        // scanners see initialized slots). The entry owns the chunk from
        // the moment the CAS succeeds (freed in HpSlots::drop).
        // SAFETY: `chunk` is ours until published, then lives as long as
        // the entry.
        let chunk = unsafe { &*chunk };
        let extra = &self.entry.data().extra;
        let mut head = extra.load(Ordering::Relaxed);
        loop {
            chunk.next.store(head, Ordering::Relaxed);
            match extra.compare_exchange_weak(
                head,
                chunk as *const SlotChunk as *mut SlotChunk,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(h) => head = h,
            }
        }
        for s in chunk.slots.iter().skip(1) {
            self.free_slots.push(SlotRef::new(s));
        }
        SlotRef::new(&chunk.slots[0])
    }
}

/// Snapshot all published hazards of `domain` and reclaim every node in
/// `retired` that none of them protects. Also adopts orphaned retire lists.
fn scan_with(domain: &HpDomain, retired: &mut RetireList) {
    // Adopt orphans (stamps are unused by HP — push_back order is fine
    // because all stamps are 0).
    let mut orphan = domain.orphans.steal_all();
    while !orphan.is_null() {
        // SAFETY: stolen chains are exclusively ours.
        let next_list = unsafe { (*orphan).next_list() };
        let mut cur: Retired = orphan;
        while !cur.is_null() {
            let next = unsafe { (*cur).next_in_chain() };
            retired.push_back(cur);
            cur = next;
        }
        orphan = next_list;
    }

    // Pairs with the publication fences in protect().
    std::sync::atomic::fence(Ordering::SeqCst);
    let mut hazards: Vec<usize> = Vec::with_capacity(64);
    for entry in domain.threads.iter() {
        // Scan *all* entries (even inactive ones — a leaked guard keeps its
        // slot published and must still block reclamation).
        for s in &entry.data().inline {
            let v = s.load(Ordering::Acquire);
            if v != 0 {
                hazards.push(v);
            }
        }
        let mut chunk = entry.data().extra.load(Ordering::Acquire);
        while !chunk.is_null() {
            let c = unsafe { &*chunk };
            for s in &c.slots {
                let v = s.load(Ordering::Acquire);
                if v != 0 {
                    hazards.push(v);
                }
            }
            chunk = c.next.load(Ordering::Acquire);
        }
    }
    hazards.sort_unstable();
    hazards.dedup();

    // Partition: free unprotected nodes, keep protected ones.
    let (chain, _) = retired.take_chain();
    let mut cur = chain;
    while !cur.is_null() {
        // SAFETY: we own the detached chain.
        unsafe {
            let next = (*cur).next_in_chain();
            let node_addr = (*cur).node_addr();
            if hazards.binary_search(&node_addr).is_ok() {
                retired.push_back(cur);
            } else {
                super::retire::reclaim_one(cur);
            }
            cur = next;
        }
    }
}

/// Detach the local retire list, scan, and merge nested retires back —
/// reclaim runs user drops, so no [`LocalCell`] borrow spans the scan.
fn flush_impl(domain: &HpDomain, local: &LocalCell<HpLocal>) {
    let mut mine = local.with(|l| std::mem::take(&mut l.retired));
    scan_with(domain, &mut mine);
    local.with(|l| {
        let mut nested = std::mem::replace(&mut l.retired, mine);
        let (chain, _) = nested.take_chain();
        let mut cur = chain;
        while !cur.is_null() {
            // SAFETY: we own the detached nested chain.
            let next = unsafe { (*cur).next_in_chain() };
            l.retired.push_back(cur);
            cur = next;
        }
    });
}

/// Guard state: the hazard slot this guard owns (lazily acquired, returned
/// on guard drop).
#[derive(Default)]
pub struct HpGuardState {
    slot: Option<SlotRef>,
}

impl HpGuardState {
    fn slot(&mut self, domain: &HpDomain, local: &LocalCell<HpLocal>) -> SlotRef {
        if let Some(s) = self.slot {
            return s;
        }
        let s = local.with(|l| l.acquire_slot(domain));
        self.slot = Some(s);
        s
    }
}

// SAFETY: protect publishes the pointer in a hazard slot and re-validates
// the source; scan() snapshots all slots of the domain after a SeqCst fence
// and never frees a published node — Michael's classic argument. A node is
// retired only after being unlinked, so post-scan publications can no
// longer validate successfully against any source.
unsafe impl Reclaimer for Hp {
    const NAME: &'static str = "HPR";
    type Header = HpHeader;
    type GuardState = HpGuardState;
    type DomainState = HpDomain;
    type LocalState = HpLocal;

    fn new_domain_state() -> Self::DomainState {
        HpDomain::new()
    }

    crate::reclaim::domain::impl_domain_statics!(Hp);

    fn register(domain: &Self::DomainState) -> Self::LocalState {
        HpLocal::register(domain)
    }

    fn unregister(domain: &Self::DomainState, local: &mut Self::LocalState) {
        // Final scan, then orphan the remainder (it will be picked up by
        // other threads' scans or by domain teardown).
        scan_with(domain, &mut local.retired);
        let (chain, _) = local.retired.take_chain();
        domain.orphans.push_sublist(chain);
        domain.threads.release(&local.entry);
    }

    fn protect<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
    ) -> MarkedPtr<T, Self> {
        let slot = state.slot(domain, local);
        loop {
            let p = src.load(Ordering::Acquire);
            if p.is_null() {
                slot.store(0, Ordering::Release);
                return p;
            }
            // Publish, fence, re-validate: the SeqCst fence pairs with the
            // one in scan(), so either the scanner sees our hazard or we see
            // the unlink (and retry).
            slot.store(p.get() as usize, Ordering::Release);
            std::sync::atomic::fence(Ordering::SeqCst);
            if src.load(Ordering::Acquire) == p {
                return p;
            }
        }
    }

    fn protect_if_equal<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
        expected: MarkedPtr<T, Self>,
    ) -> bool {
        if expected.is_null() {
            return src.load(Ordering::Acquire) == expected;
        }
        let slot = state.slot(domain, local);
        slot.store(expected.get() as usize, Ordering::Release);
        std::sync::atomic::fence(Ordering::SeqCst);
        if src.load(Ordering::Acquire) == expected {
            true
        } else {
            slot.store(0, Ordering::Release);
            false
        }
    }

    fn release<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        _ptr: MarkedPtr<T, Self>,
    ) {
        if let Some(slot) = state.slot {
            slot.store(0, Ordering::Release);
        }
    }

    fn drop_guard_state(
        _domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
    ) {
        if let Some(slot) = state.slot.take() {
            slot.store(0, Ordering::Release);
            // Return the slot for reuse (the slot stays owned by the
            // immortal registry entry either way).
            local.with(|l| l.free_slots.push(slot));
        }
    }

    unsafe fn retire<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        node: *mut Node<T, Self>,
    ) {
        let r = prepare_retire::<T, Self>(node, 0);
        let over_threshold = local.with(|l| {
            l.retired.push_back(r);
            l.retired.len() >= domain.current_threshold()
        });
        if over_threshold {
            flush_impl(domain, local);
        }
    }

    fn flush(domain: &Self::DomainState, local: &LocalCell<Self::LocalState>) {
        flush_impl(domain, local);
    }

    fn drain_domain(domain: &mut Self::DomainState) {
        // Exclusive access: no handles → no guards → no published hazards;
        // every orphan is reclaimable.
        // SAFETY: see above.
        unsafe {
            domain.orphans.reclaim_where(|_| true);
        }
    }
}

/// Tune the global domain's scan-threshold base (ablation compatibility;
/// owned domains use [`HpDomain::set_threshold_base`]).
pub fn set_threshold_base(n: usize) {
    Domain::<Hp>::global().state().set_threshold_base(n);
}

/// The global domain's current scan threshold.
pub fn current_threshold() -> usize {
    Domain::<Hp>::global().state().current_threshold()
}

/// Total hazard slots across all threads of the global domain (ΣKᵢ).
pub fn total_slots() -> u64 {
    Domain::<Hp>::global().state().total_slots()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;
    use crate::reclaim::DomainRef;

    #[test]
    fn basic_reclamation() {
        exercise_basic_reclamation::<Hp>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        exercise_guard_blocks_reclamation::<Hp>();
    }

    #[test]
    fn concurrent_smoke() {
        exercise_concurrent_smoke::<Hp>(4, 500);
    }

    #[test]
    fn dynamic_slots_grow_on_demand() {
        use crate::reclaim::{Atomic, Guard, Owned};
        // Own domain: the slot count assertion is exact, not raced by
        // sibling tests.
        let domain = DomainRef::<Hp>::new_owned();
        let h = domain.register();
        // Hold more guards than K_STATIC simultaneously: slots must grow.
        let drops = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let cells: Vec<Atomic<Payload, Hp>> = (0..K_STATIC * 2)
            .map(|i| Atomic::new(Owned::new(Payload::new(i as u64, &drops))))
            .collect();
        let nodes: Vec<MarkedPtr<Payload, Hp>> =
            cells.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let mut guards: Vec<Guard<'_, Payload, Hp>> = Vec::new();
        for c in &cells {
            let mut g = h.guard();
            assert!(g.protect(c).is_some());
            guards.push(g);
        }
        assert!(domain.domain().state().total_slots() >= (K_STATIC * 2) as u64);
        // All still guarded: retiring must not drop any.
        for (c, &n) in cells.iter().zip(&nodes) {
            c.store(MarkedPtr::null(), Ordering::Release);
            // SAFETY: unlinked above; retired exactly once, in-domain.
            unsafe { h.retire(n.get()) };
        }
        h.flush();
        assert_eq!(drops.load(Ordering::Relaxed), 0);
        drop(guards);
        h.flush();
        assert_eq!(drops.load(Ordering::Relaxed), K_STATIC * 2);
    }
}

//! HPR — hazard pointers (Michael 2004), with the *dynamic* extension the
//! paper needs for its HashMap benchmark ("we have to use the extended
//! hazard pointer scheme that supports a dynamic number of hazard pointers
//! as explained by Michael").
//!
//! * Each thread owns a registry entry with `K_STATIC` inline hazard slots
//!   plus a chain of overflow chunks, allocated on demand and never freed
//!   (immortal, like the registry entries themselves).
//! * `protect` publishes the candidate pointer in a slot and re-validates
//!   the source — the publish/validate handshake is ordered by a SeqCst
//!   fence that pairs with the SeqCst fence in `scan`.
//! * Retired nodes go to a thread-local list; when it exceeds the paper's
//!   threshold `100 + 2·ΣKᵢ` (§4.2; `ΣKᵢ` = total hazard slots across all
//!   threads) the thread scans: it snapshots all published hazards, frees
//!   every retired node not found, and keeps the rest.
//!
//! The per-thread unreclaimed population is therefore Θ(total slots) — the
//! quadratic-in-threads behaviour the paper measures in App. A.2.

use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};

use super::registry::{ThreadEntry, ThreadList};
use super::retire::{prepare_retire, AsRetireHeader, GlobalRetireList, Retired, RetireHeader, RetireList};
use super::{ConcurrentPtr, MarkedPtr, Node, Reclaimer};
use std::cell::RefCell;

/// Inline hazard slots per thread (covers the queue/list benchmarks; the
/// hash-map benchmark grows beyond them dynamically).
const K_STATIC: usize = 8;
/// Slots per dynamically added chunk.
const CHUNK_SLOTS: usize = 16;
/// Base term of the scan threshold (paper §4.2); runtime-tunable for
/// ablation bench A2.
static THRESHOLD_BASE: AtomicU64 = AtomicU64::new(100);

/// Tune the scan-threshold base (paper value: 100).
pub fn set_threshold_base(n: usize) {
    THRESHOLD_BASE.store(n as u64, Ordering::Relaxed);
}

/// Hazard pointers (Michael).
pub struct Hp;

/// Node header: retire metadata only.
#[derive(Default)]
#[repr(C)]
pub struct HpHeader {
    retire: RetireHeader,
}

impl AsRetireHeader for HpHeader {
    fn retire_header(&self) -> &RetireHeader {
        &self.retire
    }
}

/// Dynamically added block of hazard slots (immortal once published).
struct SlotChunk {
    slots: [AtomicUsize; CHUNK_SLOTS],
    next: AtomicPtr<SlotChunk>,
}

/// Per-thread shared state: the hazard slots other threads scan.
pub struct HpSlots {
    inline: [AtomicUsize; K_STATIC],
    extra: AtomicPtr<SlotChunk>,
}

impl Default for HpSlots {
    fn default() -> Self {
        Self {
            inline: [const { AtomicUsize::new(0) }; K_STATIC],
            extra: AtomicPtr::new(ptr::null_mut()),
        }
    }
}

static THREADS: ThreadList<HpSlots> = ThreadList::new();
/// ΣKᵢ — total hazard slots ever allocated (inline + chunks), for the
/// paper's scan threshold.
static TOTAL_SLOTS: AtomicU64 = AtomicU64::new(0);
static ORPHANS: GlobalRetireList = GlobalRetireList::new();

/// Thread-local hazard-pointer state.
struct HpLocal {
    entry: &'static ThreadEntry<HpSlots>,
    /// Currently unpublished slots available to guards.
    free_slots: Vec<&'static AtomicUsize>,
    retired: RetireList,
}

impl HpLocal {
    fn new() -> Self {
        let mut fresh_entry = false;
        let entry = THREADS.acquire(
            || {
                fresh_entry = true;
                HpSlots::default()
            },
            |_| {},
        );
        if fresh_entry {
            TOTAL_SLOTS.fetch_add(K_STATIC as u64, Ordering::Relaxed);
        }
        // Collect every slot of the entry (inline + previously grown
        // chunks) — all must be unpublished (previous owner's guards are
        // dropped before thread exit).
        let mut free_slots: Vec<&'static AtomicUsize> = Vec::with_capacity(K_STATIC);
        for s in &entry.data().inline {
            debug_assert_eq!(s.load(Ordering::Relaxed), 0);
            // SAFETY: registry entries are immortal.
            free_slots.push(unsafe { &*(s as *const AtomicUsize) });
        }
        let mut chunk = entry.data().extra.load(Ordering::Acquire);
        while !chunk.is_null() {
            // SAFETY: chunks are immortal.
            let c = unsafe { &*chunk };
            for s in &c.slots {
                debug_assert_eq!(s.load(Ordering::Relaxed), 0);
                free_slots.push(unsafe { &*(s as *const AtomicUsize) });
            }
            chunk = c.next.load(Ordering::Acquire);
        }
        Self { entry, free_slots, retired: RetireList::new() }
    }

    /// Take a free slot, growing the dynamic chunk chain if needed
    /// (Michael's extended scheme).
    fn acquire_slot(&mut self) -> &'static AtomicUsize {
        if let Some(s) = self.free_slots.pop() {
            return s;
        }
        let chunk = Box::leak(Box::new(SlotChunk {
            slots: [const { AtomicUsize::new(0) }; CHUNK_SLOTS],
            next: AtomicPtr::new(ptr::null_mut()),
        }));
        TOTAL_SLOTS.fetch_add(CHUNK_SLOTS as u64, Ordering::Relaxed);
        // Prepend to the entry's chunk chain (publish with Release so
        // scanners see initialized slots).
        let extra = &self.entry.data().extra;
        let mut head = extra.load(Ordering::Relaxed);
        loop {
            chunk.next.store(head, Ordering::Relaxed);
            match extra.compare_exchange_weak(
                head,
                chunk as *mut _,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(h) => head = h,
            }
        }
        for s in chunk.slots.iter().skip(1) {
            self.free_slots.push(unsafe { &*(s as *const AtomicUsize) });
        }
        unsafe { &*(&chunk.slots[0] as *const AtomicUsize) }
    }

    fn threshold() -> usize {
        THRESHOLD_BASE.load(Ordering::Relaxed) as usize
            + 2 * TOTAL_SLOTS.load(Ordering::Relaxed) as usize
    }
}

impl Drop for HpLocal {
    fn drop(&mut self) {
        // Final scan, then orphan the remainder (it will be picked up by
        // other threads' scans).
        scan_with(&mut self.retired);
        let (chain, _) = self.retired.take_chain();
        ORPHANS.push_sublist(chain);
        THREADS.release(self.entry);
    }
}

thread_local! {
    static HP_LOCAL: RefCell<HpLocal> = RefCell::new(HpLocal::new());
}

/// Snapshot all published hazards and reclaim every node in `retired` that
/// none of them protects. Also adopts orphaned retire lists.
fn scan_with(retired: &mut RetireList) {
    // Adopt orphans (stamps are unused by HP — push_back order is fine
    // because all stamps are 0).
    let mut orphan = ORPHANS.steal_all();
    while !orphan.is_null() {
        // SAFETY: stolen chains are exclusively ours.
        let next_list = unsafe { (*orphan).next_list() };
        let mut cur: Retired = orphan;
        while !cur.is_null() {
            let next = unsafe { (*cur).next_in_chain() };
            retired.push_back(cur);
            cur = next;
        }
        orphan = next_list;
    }

    // Pairs with the publication fences in protect().
    std::sync::atomic::fence(Ordering::SeqCst);
    let mut hazards: Vec<usize> = Vec::with_capacity(64);
    for entry in THREADS.iter() {
        // Scan *all* entries (even inactive ones — a leaked guard keeps its
        // slot published and must still block reclamation).
        for s in &entry.data().inline {
            let v = s.load(Ordering::Acquire);
            if v != 0 {
                hazards.push(v);
            }
        }
        let mut chunk = entry.data().extra.load(Ordering::Acquire);
        while !chunk.is_null() {
            let c = unsafe { &*chunk };
            for s in &c.slots {
                let v = s.load(Ordering::Acquire);
                if v != 0 {
                    hazards.push(v);
                }
            }
            chunk = c.next.load(Ordering::Acquire);
        }
    }
    hazards.sort_unstable();
    hazards.dedup();

    // Partition: free unprotected nodes, keep protected ones.
    let (chain, _) = retired.take_chain();
    let mut cur = chain;
    while !cur.is_null() {
        // SAFETY: we own the detached chain.
        unsafe {
            let next = (*cur).next_in_chain();
            let node_addr = (*cur).node_addr();
            if hazards.binary_search(&node_addr).is_ok() {
                retired.push_back(cur);
            } else {
                super::retire::reclaim_one(cur);
            }
            cur = next;
        }
    }
}

/// Guard state: the hazard slot this guard owns (lazily acquired, returned
/// on guard drop).
#[derive(Default)]
pub struct HpGuardState {
    slot: Option<&'static AtomicUsize>,
}

impl HpGuardState {
    fn slot(&mut self) -> &'static AtomicUsize {
        if let Some(s) = self.slot {
            return s;
        }
        let s = HP_LOCAL.with(|l| l.borrow_mut().acquire_slot());
        self.slot = Some(s);
        s
    }
}

// SAFETY: protect publishes the pointer in a hazard slot and re-validates
// the source; scan() snapshots all slots after a SeqCst fence and never
// frees a published node — Michael's classic argument. A node is retired
// only after being unlinked, so post-scan publications can no longer
// validate successfully against any source.
unsafe impl Reclaimer for Hp {
    const NAME: &'static str = "HPR";
    type Header = HpHeader;
    type GuardState = HpGuardState;
    type Region = ();

    fn enter_region() -> Self::Region {}

    fn protect<T: Send + Sync + 'static>(
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
    ) -> MarkedPtr<T, Self> {
        let slot = state.slot();
        loop {
            let p = src.load(Ordering::Acquire);
            if p.is_null() {
                slot.store(0, Ordering::Release);
                return p;
            }
            // Publish, fence, re-validate: the SeqCst fence pairs with the
            // one in scan(), so either the scanner sees our hazard or we see
            // the unlink (and retry).
            slot.store(p.get() as usize, Ordering::Release);
            std::sync::atomic::fence(Ordering::SeqCst);
            if src.load(Ordering::Acquire) == p {
                return p;
            }
        }
    }

    fn protect_if_equal<T: Send + Sync + 'static>(
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
        expected: MarkedPtr<T, Self>,
    ) -> bool {
        if expected.is_null() {
            return src.load(Ordering::Acquire) == expected;
        }
        let slot = state.slot();
        slot.store(expected.get() as usize, Ordering::Release);
        std::sync::atomic::fence(Ordering::SeqCst);
        if src.load(Ordering::Acquire) == expected {
            true
        } else {
            slot.store(0, Ordering::Release);
            false
        }
    }

    fn release<T: Send + Sync + 'static>(
        state: &mut Self::GuardState,
        _ptr: MarkedPtr<T, Self>,
    ) {
        if let Some(slot) = state.slot {
            slot.store(0, Ordering::Release);
        }
    }

    fn drop_guard_state(state: &mut Self::GuardState) {
        if let Some(slot) = state.slot.take() {
            slot.store(0, Ordering::Release);
            // Return the slot for reuse; during thread teardown just leave
            // it unpublished (slot stays owned by the immortal entry).
            let _ = HP_LOCAL.try_with(|l| l.borrow_mut().free_slots.push(slot));
        }
    }

    unsafe fn retire<T: Send + Sync + 'static>(node: *mut Node<T, Self>) {
        let r = prepare_retire::<T, Self>(node, 0);
        let over_threshold = HP_LOCAL
            .try_with(|l| {
                let mut l = l.borrow_mut();
                l.retired.push_back(r);
                l.retired.len() >= HpLocal::threshold()
            })
            .unwrap_or_else(|_| {
                // Thread teardown: orphan immediately.
                ORPHANS.push_sublist(r);
                false
            });
        if over_threshold {
            Self::flush();
        }
    }

    fn flush() {
        // Detach the retire list before scanning: reclaim runs user drops,
        // which may re-enter (see epoch_core's reentrancy discipline).
        let mut mine = match HP_LOCAL.try_with(|l| std::mem::take(&mut l.borrow_mut().retired)) {
            Ok(m) => m,
            Err(_) => return,
        };
        scan_with(&mut mine);
        let _ = HP_LOCAL.try_with(|l| {
            let mut l = l.borrow_mut();
            let nested = std::mem::replace(&mut l.retired, mine);
            let (chain, _) = {
                let mut n = nested;
                n.take_chain()
            };
            let mut cur = chain;
            while !cur.is_null() {
                // SAFETY: we own the detached nested chain.
                let next = unsafe { (*cur).next_in_chain() };
                l.retired.push_back(cur);
                cur = next;
            }
        });
    }
}

/// Current scan threshold (diagnostics / ablation benches).
pub fn current_threshold() -> usize {
    HpLocal::threshold()
}

/// Total hazard slots across all threads (ΣKᵢ).
pub fn total_slots() -> u64 {
    TOTAL_SLOTS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;

    #[test]
    fn basic_reclamation() {
        exercise_basic_reclamation::<Hp>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        exercise_guard_blocks_reclamation::<Hp>();
    }

    #[test]
    fn concurrent_smoke() {
        exercise_concurrent_smoke::<Hp>(4, 500);
    }

    #[test]
    fn dynamic_slots_grow_on_demand() {
        use crate::reclaim::{alloc_node, GuardPtr};
        // Hold more guards than K_STATIC simultaneously: slots must grow.
        let drops = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let nodes: Vec<_> =
            (0..K_STATIC * 2).map(|i| alloc_node::<Payload, Hp>(Payload::new(i as u64, &drops))).collect();
        let cells: Vec<ConcurrentPtr<Payload, Hp>> =
            nodes.iter().map(|&n| ConcurrentPtr::new(MarkedPtr::new(n, 0))).collect();
        let mut guards: Vec<GuardPtr<Payload, Hp>> = Vec::new();
        for c in &cells {
            let mut g = GuardPtr::new();
            g.acquire(c);
            assert!(!g.is_null());
            guards.push(g);
        }
        assert!(total_slots() >= (K_STATIC * 2) as u64);
        // All still guarded: retiring must not drop any.
        for (c, &n) in cells.iter().zip(&nodes) {
            c.store(MarkedPtr::null(), Ordering::Release);
            unsafe { Hp::retire(n) };
        }
        Hp::flush();
        assert_eq!(drops.load(Ordering::Relaxed), 0);
        drop(guards);
        Hp::flush();
        assert_eq!(drops.load(Ordering::Relaxed), K_STATIC * 2);
    }
}

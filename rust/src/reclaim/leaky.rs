//! Leaky baseline: never reclaims anything.
//!
//! Protection is trivially satisfied (nodes are immortal), making this the
//! zero-overhead upper bound for per-operation cost and the scaffold for
//! testing data-structure logic in isolation from reclamation. Its domain
//! and local state are empty (`()`): domains exist only for interface
//! uniformity. Excluded from the paper-figure scheme set (the paper has no
//! such baseline), but available to benchmarks via `--schemes leaky,...`.

use super::domain::LocalCell;
use super::retire::{AsRetireHeader, RetireHeader};
use super::{ConcurrentPtr, MarkedPtr, Node, Reclaimer};
use std::sync::atomic::Ordering;

/// The leaky (no-op) reclamation scheme.
pub struct Leaky;

/// Leaky node header: just the retire header slot (unused apart from the
/// pool flag).
#[derive(Default)]
#[repr(C)]
pub struct LeakyHeader {
    retire: RetireHeader,
}

impl AsRetireHeader for LeakyHeader {
    fn retire_header(&self) -> &RetireHeader {
        &self.retire
    }
}

// SAFETY: nodes are never reclaimed, so every protection contract holds
// vacuously.
unsafe impl Reclaimer for Leaky {
    const NAME: &'static str = "Leaky";
    type Header = LeakyHeader;
    type GuardState = ();
    type DomainState = ();
    type LocalState = ();

    fn new_domain_state() -> Self::DomainState {}

    crate::reclaim::domain::impl_domain_statics!(Leaky);

    fn register(_domain: &Self::DomainState) -> Self::LocalState {}

    fn unregister(_domain: &Self::DomainState, _local: &mut Self::LocalState) {}

    #[inline]
    fn protect<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
    ) -> MarkedPtr<T, Self> {
        // Acquire: the load synchronizes with the Release publication of the
        // node so its payload is visible.
        src.load(Ordering::Acquire)
    }

    #[inline]
    fn protect_if_equal<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
        expected: MarkedPtr<T, Self>,
    ) -> bool {
        src.load(Ordering::Acquire) == expected
    }

    #[inline]
    fn release<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        _ptr: MarkedPtr<T, Self>,
    ) {
    }

    #[inline]
    unsafe fn retire<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _node: *mut Node<T, Self>,
    ) {
        // Intentionally leaked. The allocation counters keep counting, so
        // the efficiency benchmark honestly reports an ever-growing
        // unreclaimed population for this baseline.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::{Atomic, DomainRef, Guard, Owned, Stale};

    #[test]
    fn guard_roundtrip() {
        let h = DomainRef::<Leaky>::new_owned().register();
        let c: Atomic<u64, Leaky> = Atomic::new(Owned::new(42));
        let node = c.load(Ordering::Relaxed);
        let mut g: Guard<u64, Leaky> = h.guard();
        let p = g.protect(&c).expect("non-null");
        assert!(p.ptr_eq(node));
        assert_eq!(*p, 42);
        g.reset();
        assert!(g.is_empty());
        assert!(g.shared().is_none());
        // Leaky never reclaims; free the node directly (it is private
        // again: no guard holds it and the cell is test-local).
        unsafe { crate::reclaim::free_node(node.get()) };
    }

    #[test]
    fn try_protect_checks_value() {
        let h = DomainRef::<Leaky>::new_owned().register();
        let c: Atomic<u64, Leaky> = Atomic::new(Owned::new(1));
        let node = c.load(Ordering::Relaxed);
        let mut g: Guard<u64, Leaky> = h.guard();
        assert_eq!(g.try_protect(&c, node), Ok(()));
        assert_eq!(g.try_protect(&c, MarkedPtr::null()), Err(Stale));
        assert!(g.is_empty(), "failed try_protect leaves the shield empty");
        unsafe { crate::reclaim::free_node(node.get()) };
    }

    #[test]
    fn swap_moves_protection_between_shields() {
        // `save = std::move(cur)` from the paper's Listing 1, spelled as a
        // plain mem::swap of facade shields.
        let h = DomainRef::<Leaky>::new_owned().register();
        let c: Atomic<u64, Leaky> = Atomic::new(Owned::new(9));
        let node = c.load(Ordering::Relaxed);
        let mut cur: Guard<u64, Leaky> = h.guard();
        let mut save: Guard<u64, Leaky> = h.guard();
        cur.protect(&c);
        std::mem::swap(&mut save, &mut cur);
        cur.reset();
        assert!(cur.is_empty());
        assert_eq!(save.shared().map(|s| *s.get()), Some(9));
        unsafe { crate::reclaim::free_node(node.get()) };
    }
}

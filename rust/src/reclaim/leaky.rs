//! Leaky baseline: never reclaims anything.
//!
//! Protection is trivially satisfied (nodes are immortal), making this the
//! zero-overhead upper bound for per-operation cost and the scaffold for
//! testing data-structure logic in isolation from reclamation. Its domain
//! and local state are empty (`()`): domains exist only for interface
//! uniformity. Excluded from the paper-figure scheme set (the paper has no
//! such baseline), but available to benchmarks via `--schemes leaky,...`.

use super::domain::LocalCell;
use super::retire::{AsRetireHeader, RetireHeader};
use super::{ConcurrentPtr, MarkedPtr, Node, Reclaimer};
use std::sync::atomic::Ordering;

/// The leaky (no-op) reclamation scheme.
pub struct Leaky;

/// Leaky node header: just the retire header slot (unused apart from the
/// pool flag).
#[derive(Default)]
#[repr(C)]
pub struct LeakyHeader {
    retire: RetireHeader,
}

impl AsRetireHeader for LeakyHeader {
    fn retire_header(&self) -> &RetireHeader {
        &self.retire
    }
}

// SAFETY: nodes are never reclaimed, so every protection contract holds
// vacuously.
unsafe impl Reclaimer for Leaky {
    const NAME: &'static str = "Leaky";
    type Header = LeakyHeader;
    type GuardState = ();
    type DomainState = ();
    type LocalState = ();

    fn new_domain_state() -> Self::DomainState {}

    crate::reclaim::domain::impl_domain_statics!(Leaky);

    fn register(_domain: &Self::DomainState) -> Self::LocalState {}

    fn unregister(_domain: &Self::DomainState, _local: &mut Self::LocalState) {}

    #[inline]
    fn protect<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
    ) -> MarkedPtr<T, Self> {
        // Acquire: the load synchronizes with the Release publication of the
        // node so its payload is visible.
        src.load(Ordering::Acquire)
    }

    #[inline]
    fn protect_if_equal<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
        expected: MarkedPtr<T, Self>,
    ) -> bool {
        src.load(Ordering::Acquire) == expected
    }

    #[inline]
    fn release<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        _ptr: MarkedPtr<T, Self>,
    ) {
    }

    #[inline]
    unsafe fn retire<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _node: *mut Node<T, Self>,
    ) {
        // Intentionally leaked. The allocation counters keep counting, so
        // the efficiency benchmark honestly reports an ever-growing
        // unreclaimed population for this baseline.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::{alloc_node, DomainRef, GuardPtr};

    #[test]
    fn guard_roundtrip() {
        let h = DomainRef::<Leaky>::new_owned().register();
        let node = alloc_node::<u64, Leaky>(42);
        let c = ConcurrentPtr::new(MarkedPtr::new(node, 0));
        let mut g: GuardPtr<u64, Leaky> = h.guard();
        let p = g.acquire(&c);
        assert_eq!(p.get(), node);
        assert_eq!(g.as_ref(), Some(&42));
        g.reset();
        assert!(g.is_null());
        assert_eq!(g.as_ref(), None);
        unsafe { crate::reclaim::free_node(node) };
    }

    #[test]
    fn acquire_if_equal_checks_value() {
        let h = DomainRef::<Leaky>::new_owned().register();
        let node = alloc_node::<u64, Leaky>(1);
        let c = ConcurrentPtr::new(MarkedPtr::new(node, 0));
        let mut g: GuardPtr<u64, Leaky> = h.guard();
        assert!(g.acquire_if_equal(&c, MarkedPtr::new(node, 0)));
        assert!(!g.acquire_if_equal(&c, MarkedPtr::null()));
        assert!(g.is_null(), "failed acquire leaves the guard empty");
        unsafe { crate::reclaim::free_node(node) };
    }

    #[test]
    fn take_moves_ownership() {
        let h = DomainRef::<Leaky>::new_owned().register();
        let node = alloc_node::<u64, Leaky>(9);
        let c = ConcurrentPtr::new(MarkedPtr::new(node, 0));
        let mut g: GuardPtr<u64, Leaky> = h.guard();
        g.acquire(&c);
        let t = g.take();
        assert!(g.is_null());
        assert_eq!(t.as_ref(), Some(&9));
        unsafe { crate::reclaim::free_node(node) };
    }
}

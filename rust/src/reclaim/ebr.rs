//! ER — epoch-based reclamation (Fraser 2004), as configured in the paper's
//! comparison (§4.2): critical regions are *per guard* (every operation
//! pays region entry/exit — no application-level amortization), and an
//! epoch-advance attempt runs every 100 critical-region entries.
//!
//! The `Region` type still exists (the interface requires it) but entering
//! one deliberately amortizes nothing beyond nesting — that behaviour is
//! NER's distinguishing feature, see [`super::nebr`].

use super::epoch_core::{epoch_reclaimer_impl, EpochConfig, EpochDomain};
use super::Domain;

/// Epoch-based reclamation (Fraser).
pub struct Ebr;

epoch_reclaimer_impl!(
    Ebr,
    "ER",
    EpochConfig {
        // paper §4.2: "ER/NER try to advance the epoch every 100 critical
        // region entries"
        advance_every: 100,
        debra_check_every: None,
        quiescent_at_exit: false,
    }
);

/// The global domain's epoch state (benchmark diagnostics / ablations;
/// per-instance state lives in each [`Domain`]).
pub fn domain() -> &'static EpochDomain {
    Domain::<Ebr>::global().state()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;

    #[test]
    fn nodes_reclaimed_after_epoch_advances() {
        exercise_basic_reclamation::<Ebr>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        exercise_guard_blocks_reclamation::<Ebr>();
    }

    #[test]
    fn concurrent_smoke() {
        exercise_concurrent_smoke::<Ebr>(4, 500);
    }
}

//! **Stamp-it** — the paper's contribution (§3): lock-less memory
//! reclamation with amortized constant-time (thread-count-independent)
//! reclamation overhead.
//!
//! * On region entry the thread pushes its control block into the
//!   [`pool::StampPool`], receiving a strictly increasing stamp — the total
//!   order of region entries.
//! * `retire` stamps the node with the pool's **highest** stamp and appends
//!   it to the thread's ordered local retire-list.
//! * On region exit the thread removes its block and reclaims every local
//!   node whose stamp is below the pool's **lowest** stamp (Proposition 1:
//!   all threads currently in regions entered after the node was retired).
//!   The scan touches only the reclaimable prefix — "no time is wasted on
//!   nodes that cannot yet be reclaimed" (Proposition 2).
//! * If the thread was *not* the last one and its list exceeds the
//!   threshold (20, the paper's empirical choice), the remainder moves to
//!   the global retire-list as an ordered sublist. The thread whose
//!   `remove` returned `true` — the one holding the lowest stamp — owns
//!   reclamation of the global list, rechecking the lowest stamp and
//!   restarting if it moved (this is what rescues the end-of-run race the
//!   other schemes suffer, §4.4).

pub mod pool;

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::retire::{prepare_retire, GlobalRetireList, RetireList};
use super::{ConcurrentPtr, MarkedPtr, Node, Reclaimer};
use once_cell::sync::Lazy;
use pool::StampPool;

/// Stamp-it (Pöter & Träff 2018).
pub struct StampIt;

/// Maximum simultaneously registered threads (blocks recycle on exit).
const POOL_CAPACITY: usize = 4096;

/// Paper §3: "we use a static threshold with an empirical value of 20".
/// Runtime-tunable for the ablation bench (`abl_threshold`).
static THRESHOLD: AtomicUsize = AtomicUsize::new(20);

static POOL: Lazy<StampPool> = Lazy::new(|| StampPool::new(POOL_CAPACITY));
static GLOBAL_RETIRED: GlobalRetireList = GlobalRetireList::new();

/// The global Stamp Pool (diagnostics, micro-benches).
pub fn stamp_pool() -> &'static StampPool {
    &POOL
}

/// Set the local-retire-list threshold (ablation bench A1).
pub fn set_threshold(t: usize) {
    THRESHOLD.store(t, Ordering::Relaxed);
}

/// Current threshold.
pub fn threshold() -> usize {
    THRESHOLD.load(Ordering::Relaxed)
}

/// Per-thread Stamp-it state.
struct StampLocal {
    block: u32,
    nesting: u32,
    retired: RetireList,
}

impl StampLocal {
    fn new() -> Self {
        Self { block: POOL.alloc_block(), nesting: 0, retired: RetireList::new() }
    }
}

impl Drop for StampLocal {
    fn drop(&mut self) {
        debug_assert_eq!(self.nesting, 0, "thread exiting inside a critical region");
        // Hand any unreclaimed nodes to the global list (ordered sublist);
        // the next "last thread" reclaims them — Stamp-it's answer to the
        // end-of-run race (§4.4).
        let (chain, _) = self.retired.take_chain();
        GLOBAL_RETIRED.push_sublist(chain);
        POOL.free_block(self.block);
    }
}

thread_local! {
    static STAMP_LOCAL: RefCell<StampLocal> = RefCell::new(StampLocal::new());
}

/// Region exit: remove from the pool, reclaim local prefix, then either
/// hand the surplus to the global list or (as the last thread) reclaim the
/// global list. Runs user drops — called with **no** RefCell borrow held.
fn leave_region() {
    // One TLS access covers the common case (nested exit, or outermost
    // with an empty retire list and nothing global to do) — §Perf: this
    // fused check cut the region cycle from ~74 ns to the pool-op cost.
    let (was_last, retired_empty) = {
        let state = STAMP_LOCAL.with(|l| {
            let mut l = l.borrow_mut();
            debug_assert!(l.nesting > 0);
            l.nesting -= 1;
            if l.nesting > 0 {
                return None;
            }
            Some((POOL.remove(l.block), l.retired.is_empty()))
        });
        let Some(state) = state else { return };
        state
    };
    if retired_empty && !(was_last && !GLOBAL_RETIRED.is_empty()) {
        return;
    }

    reclaim_local();

    if was_last {
        reclaim_global();
    } else {
        // Over threshold? Move the (ordered) remainder to the global list.
        let chain = STAMP_LOCAL.with(|l| {
            let mut l = l.borrow_mut();
            if l.retired.len() > THRESHOLD.load(Ordering::Relaxed) {
                Some(l.retired.take_chain().0)
            } else {
                None
            }
        });
        if let Some(chain) = chain {
            GLOBAL_RETIRED.push_sublist(chain);
        }
    }
}

/// Reclaim the local retire-list prefix with stamps below the pool's lowest
/// stamp. Borrow-free while running user drops (nested retires are merged
/// back, cf. `epoch_core`'s reentrancy discipline).
fn reclaim_local() -> usize {
    let empty = STAMP_LOCAL.with(|l| l.borrow().retired.is_empty());
    if empty {
        return 0;
    }
    let mut mine = STAMP_LOCAL.with(|l| std::mem::take(&mut l.borrow_mut().retired));
    let lowest = POOL.lowest_stamp();
    // SAFETY: Proposition 1 — stamp < lowest implies every thread currently
    // in a region entered after the node was retired.
    let freed = unsafe { mine.reclaim_prefix(|s| s < lowest) };
    STAMP_LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let nested = std::mem::replace(&mut l.retired, mine);
        let (chain, _) = {
            let mut n = nested;
            n.take_chain()
        };
        let mut cur = chain;
        while !cur.is_null() {
            // SAFETY: we own the detached nested chain; nested stamps are
            // ≥ everything already in the list (highest-stamp stamping).
            let next = unsafe { (*cur).next_in_chain() };
            l.retired.push_back(cur);
            cur = next;
        }
    });
    freed
}

/// Last-thread duty: reclaim the global list of ordered sublists,
/// restarting while the lowest stamp keeps moving (paper §4.4).
fn reclaim_global() -> usize {
    let mut total = 0;
    loop {
        if GLOBAL_RETIRED.is_empty() {
            return total;
        }
        let lowest = POOL.lowest_stamp();
        // SAFETY: Proposition 1, as in reclaim_local.
        total += unsafe { GLOBAL_RETIRED.reclaim_where(|s| s < lowest) };
        if POOL.lowest_stamp() == lowest {
            return total;
        }
        // The stamp advanced while we scanned: restart with the new bound.
    }
}

/// RAII region token.
pub struct StampRegion {
    _not_send: std::marker::PhantomData<*const ()>,
}

impl Drop for StampRegion {
    fn drop(&mut self) {
        if STAMP_LOCAL.try_with(|_| ()).is_ok() {
            leave_region();
        }
    }
}

fn enter_region_impl() {
    STAMP_LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        l.nesting += 1;
        if l.nesting == 1 {
            POOL.push(l.block);
        }
    });
}

/// Guard token: whether this guard entered a region it must exit on drop.
#[derive(Default)]
pub struct StampGuardToken {
    entered: bool,
}

// SAFETY: Propositions 1–3 of the paper, transcribed in the module and
// pool docs: a node is reclaimed only when its stamp is below the lowest
// stamp of any thread inside a critical region, and guards keep their
// thread inside a region.
unsafe impl Reclaimer for StampIt {
    const NAME: &'static str = "Stamp-it";
    type Header = super::epoch_core::EpochHeader;
    type GuardState = StampGuardToken;
    type Region = StampRegion;

    fn enter_region() -> Self::Region {
        enter_region_impl();
        StampRegion { _not_send: std::marker::PhantomData }
    }

    #[inline]
    fn protect<T: Send + Sync + 'static>(
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
    ) -> MarkedPtr<T, Self> {
        if !state.entered {
            state.entered = true;
            enter_region_impl();
        }
        // Acquire pairs with the Release publication of the node.
        src.load(Ordering::Acquire)
    }

    #[inline]
    fn protect_if_equal<T: Send + Sync + 'static>(
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
        expected: MarkedPtr<T, Self>,
    ) -> bool {
        if !state.entered {
            state.entered = true;
            enter_region_impl();
        }
        src.load(Ordering::Acquire) == expected
    }

    #[inline]
    fn release<T: Send + Sync + 'static>(
        _state: &mut Self::GuardState,
        _ptr: MarkedPtr<T, Self>,
    ) {
        // Protection is region-scoped (left on guard drop).
    }

    fn drop_guard_state(state: &mut Self::GuardState) {
        if state.entered {
            state.entered = false;
            if STAMP_LOCAL.try_with(|_| ()).is_ok() {
                leave_region();
            }
        }
    }

    unsafe fn retire<T: Send + Sync + 'static>(node: *mut Node<T, Self>) {
        // Stamp with the highest stamp assigned so far (§3): every thread
        // that might reference the node is ordered before this stamp.
        let stamp = POOL.highest_stamp();
        let r = prepare_retire::<T, Self>(node, stamp);
        let pushed = STAMP_LOCAL
            .try_with(|l| {
                l.borrow_mut().retired.push_back(r);
            })
            .is_ok();
        if !pushed {
            // Thread teardown: single-node ordered sublist to the global
            // list.
            GLOBAL_RETIRED.push_sublist(r);
        }
    }

    fn flush() {
        // Cycle a region: the push/remove pair advances tail.stamp past
        // every stamp assigned before, making prior retires reclaimable
        // (when no other thread sits in an older region).
        {
            let _r = Self::enter_region();
        }
        reclaim_local();
        reclaim_global();
    }
}

/// Nodes currently parked on the global retire-list (diagnostics).
pub fn global_retired_count() -> usize {
    GLOBAL_RETIRED.count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;

    // Stamp-it's tests share one global pool; region-timing-sensitive
    // assertions serialize on the crate test lock.

    #[test]
    fn basic_reclamation() {
        let _l = serial_lock();
        exercise_basic_reclamation::<StampIt>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        let _l = serial_lock();
        exercise_guard_blocks_reclamation::<StampIt>();
    }

    #[test]
    fn region_guard_amortizes_and_protects() {
        let _l = serial_lock();
        exercise_region_guard::<StampIt>();
    }

    #[test]
    fn concurrent_smoke() {
        let _l = serial_lock();
        exercise_concurrent_smoke::<StampIt>(4, 500);
    }

    #[test]
    fn reclaim_is_prompt_after_region_cycle() {
        use crate::reclaim::alloc_node;
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        let _l = serial_lock();
        // Stamp-it's efficiency claim in miniature: retire inside a region,
        // and one region cycle later the node is gone — no epoch lag.
        let drops = Arc::new(AtomicUsize::new(0));
        {
            let _r = crate::reclaim::Region::<StampIt>::enter();
            let node = alloc_node::<Payload, StampIt>(Payload::new(1, &drops));
            unsafe { StampIt::retire(node) };
        } // region exit reclaims: we are the last thread
        assert_eq!(drops.load(Ordering::Relaxed), 1, "retire must resolve at region exit");
    }

    #[test]
    fn threshold_pushes_surplus_to_global_list() {
        use crate::reclaim::alloc_node;
        use std::sync::atomic::AtomicUsize;
        use std::sync::{Arc, Barrier};
        let _l = serial_lock();
        let drops = Arc::new(AtomicUsize::new(0));
        let gate = Arc::new(Barrier::new(2));
        let gate2 = gate.clone();
        // A second thread parks inside a region so our exit is NOT last.
        let parked = std::thread::spawn(move || {
            let _r = crate::reclaim::Region::<StampIt>::enter();
            gate2.wait(); // region open
            gate2.wait(); // main thread done retiring
        });
        gate.wait();
        let n = threshold() + 8;
        {
            let _r = crate::reclaim::Region::<StampIt>::enter();
            for i in 0..n {
                let node = alloc_node::<Payload, StampIt>(Payload::new(i as u64, &drops));
                unsafe { StampIt::retire(node) };
            }
        }
        // Not last (parked thread holds an older stamp): nothing reclaimed;
        // the surplus went to the global list.
        assert_eq!(drops.load(Ordering::Relaxed), 0);
        gate.wait();
        parked.join().unwrap();
        for _ in 0..100 {
            if drops.load(Ordering::Relaxed) == n {
                break;
            }
            StampIt::flush();
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(drops.load(Ordering::Relaxed), n);
    }
}

//! **Stamp-it** — the paper's contribution (§3): lock-less memory
//! reclamation with amortized constant-time (thread-count-independent)
//! reclamation overhead.
//!
//! * On region entry the thread pushes its control block into the
//!   [`pool::StampPool`], receiving a strictly increasing stamp — the total
//!   order of region entries.
//! * `retire` stamps the node with the pool's **highest** stamp and appends
//!   it to the thread's ordered local retire-list.
//! * On region exit the thread removes its block and reclaims every local
//!   node whose stamp is below the pool's **lowest** stamp (Proposition 1:
//!   all threads currently in regions entered after the node was retired).
//!   The scan touches only the reclaimable prefix — "no time is wasted on
//!   nodes that cannot yet be reclaimed" (Proposition 2).
//! * If the thread was *not* the last one and its list exceeds the
//!   threshold (20, the paper's empirical choice), the remainder moves to
//!   the domain's global retire-list as an ordered sublist. The thread
//!   whose `remove` returned `true` — the one holding the lowest stamp —
//!   owns reclamation of the global list, rechecking the lowest stamp and
//!   restarting if it moved (this is what rescues the end-of-run race the
//!   other schemes suffer, §4.4).
//!
//! All of this state (pool, global retire-list, threshold) lives in a
//! [`StampDomain`] — one per [`crate::reclaim::Domain`]; the thread's
//! control-block index and local retire-list are the [`StampLocal`] a
//! [`crate::reclaim::LocalHandle`] caches, so region enter/exit touches
//! neither TLS nor `RefCell` (§Perf: the seed's fused-TLS path measured
//! ~74 ns per cycle; the cached handle removes the lookup entirely).

pub mod pool;

use std::sync::atomic::{AtomicUsize, Ordering};

use super::domain::LocalCell;
use super::retire::{prepare_retire, GlobalRetireList, RetireList};
use super::{ConcurrentPtr, Domain, MarkedPtr, Node, Reclaimer};
use pool::StampPool;

/// Stamp-it (Pöter & Träff 2018).
pub struct StampIt;

/// Maximum simultaneously registered threads per domain (blocks recycle on
/// handle drop).
const POOL_CAPACITY: usize = 4096;

/// One Stamp-it reclamation universe: the Stamp Pool, the global list of
/// ordered retire sublists, and the local-list threshold. The `DomainState`
/// of [`StampIt`].
pub struct StampDomain {
    pool: StampPool,
    global_retired: GlobalRetireList,
    /// Paper §3: "we use a static threshold with an empirical value of 20".
    /// Runtime-tunable per domain for the ablation bench (`abl_threshold`).
    threshold: AtomicUsize,
}

impl StampDomain {
    fn new() -> Self {
        Self {
            pool: StampPool::new(POOL_CAPACITY),
            global_retired: GlobalRetireList::new(),
            threshold: AtomicUsize::new(20),
        }
    }

    /// The domain's Stamp Pool (diagnostics, micro-benches).
    pub fn pool(&self) -> &StampPool {
        &self.pool
    }

    /// Set the local-retire-list threshold (ablation bench A1).
    pub fn set_threshold(&self, t: usize) {
        self.threshold.store(t, Ordering::Relaxed);
    }

    /// Current threshold.
    pub fn threshold(&self) -> usize {
        self.threshold.load(Ordering::Relaxed)
    }

    /// Nodes currently parked on the domain's global retire-list
    /// (diagnostics).
    pub fn global_retired_count(&self) -> usize {
        self.global_retired.count()
    }
}

/// Per-thread Stamp-it state (the `LocalState` cached by a handle).
pub struct StampLocal {
    block: u32,
    nesting: u32,
    retired: RetireList,
}

/// Region exit: remove from the pool, reclaim local prefix, then either
/// hand the surplus to the global list or (as the last thread) reclaim the
/// global list. Runs user drops — called with **no** [`LocalCell`] borrow
/// held.
fn leave_region(domain: &StampDomain, local: &LocalCell<StampLocal>) {
    // One borrow covers the common case (nested exit, or outermost with an
    // empty retire list and nothing global to do) — §Perf: this fused
    // check cut the region cycle to the pool-op cost.
    let state = local.with(|l| {
        debug_assert!(l.nesting > 0);
        l.nesting -= 1;
        if l.nesting > 0 {
            None
        } else {
            Some((domain.pool.remove(l.block), l.retired.is_empty()))
        }
    });
    let Some((was_last, retired_empty)) = state else { return };
    if retired_empty && !(was_last && !domain.global_retired.is_empty()) {
        return;
    }

    reclaim_local(domain, local);

    if was_last {
        reclaim_global(domain);
    } else {
        // Over threshold? Move the (ordered) remainder to the global list.
        let chain = local.with(|l| {
            if l.retired.len() > domain.threshold() {
                Some(l.retired.take_chain().0)
            } else {
                None
            }
        });
        if let Some(chain) = chain {
            domain.global_retired.push_sublist(chain);
        }
    }
}

/// Reclaim the local retire-list prefix with stamps below the pool's lowest
/// stamp. Borrow-free while running user drops (nested retires are merged
/// back, cf. `epoch_core`'s reentrancy discipline).
fn reclaim_local(domain: &StampDomain, local: &LocalCell<StampLocal>) -> usize {
    if local.with(|l| l.retired.is_empty()) {
        return 0;
    }
    let mut mine = local.with(|l| std::mem::take(&mut l.retired));
    let lowest = domain.pool.lowest_stamp();
    // SAFETY: Proposition 1 — stamp < lowest implies every thread currently
    // in a region entered after the node was retired.
    let freed = unsafe { mine.reclaim_prefix(|s| s < lowest) };
    local.with(|l| {
        let mut nested = std::mem::replace(&mut l.retired, mine);
        let (chain, _) = nested.take_chain();
        let mut cur = chain;
        while !cur.is_null() {
            // SAFETY: we own the detached nested chain; nested stamps are
            // ≥ everything already in the list (highest-stamp stamping).
            let next = unsafe { (*cur).next_in_chain() };
            l.retired.push_back(cur);
            cur = next;
        }
    });
    freed
}

/// Last-thread duty: reclaim the global list of ordered sublists,
/// restarting while the lowest stamp keeps moving (paper §4.4).
fn reclaim_global(domain: &StampDomain) -> usize {
    let mut total = 0;
    loop {
        if domain.global_retired.is_empty() {
            return total;
        }
        let lowest = domain.pool.lowest_stamp();
        // SAFETY: Proposition 1, as in reclaim_local.
        total += unsafe { domain.global_retired.reclaim_where(|s| s < lowest) };
        if domain.pool.lowest_stamp() == lowest {
            return total;
        }
        // The stamp advanced while we scanned: restart with the new bound.
    }
}

fn enter_region_impl(domain: &StampDomain, local: &LocalCell<StampLocal>) {
    local.with(|l| {
        l.nesting += 1;
        if l.nesting == 1 {
            domain.pool.push(l.block);
        }
    });
}

/// Guard token: whether this guard entered a region it must exit on drop.
#[derive(Default)]
pub struct StampGuardToken {
    entered: bool,
}

// SAFETY: Propositions 1–3 of the paper, transcribed in the module and
// pool docs: a node is reclaimed only when its stamp is below the lowest
// stamp of any thread inside a critical region of the same domain, and
// guards keep their thread inside a region.
unsafe impl Reclaimer for StampIt {
    const NAME: &'static str = "Stamp-it";
    type Header = super::epoch_core::EpochHeader;
    type GuardState = StampGuardToken;
    type DomainState = StampDomain;
    type LocalState = StampLocal;

    fn new_domain_state() -> Self::DomainState {
        StampDomain::new()
    }

    crate::reclaim::domain::impl_domain_statics!(StampIt);

    fn register(domain: &Self::DomainState) -> Self::LocalState {
        StampLocal { block: domain.pool.alloc_block(), nesting: 0, retired: RetireList::new() }
    }

    fn unregister(domain: &Self::DomainState, local: &mut Self::LocalState) {
        debug_assert_eq!(local.nesting, 0, "handle dropped inside a critical region");
        // Hand any unreclaimed nodes to the global list (ordered sublist);
        // the next "last thread" reclaims them — Stamp-it's answer to the
        // end-of-run race (§4.4).
        let (chain, _) = local.retired.take_chain();
        domain.global_retired.push_sublist(chain);
        domain.pool.free_block(local.block);
    }

    fn enter_region(domain: &Self::DomainState, local: &LocalCell<Self::LocalState>) {
        enter_region_impl(domain, local);
    }

    fn exit_region(domain: &Self::DomainState, local: &LocalCell<Self::LocalState>) {
        leave_region(domain, local);
    }

    #[inline]
    fn protect<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
    ) -> MarkedPtr<T, Self> {
        if !state.entered {
            state.entered = true;
            enter_region_impl(domain, local);
        }
        // Acquire pairs with the Release publication of the node.
        src.load(Ordering::Acquire)
    }

    #[inline]
    fn protect_if_equal<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
        expected: MarkedPtr<T, Self>,
    ) -> bool {
        if !state.entered {
            state.entered = true;
            enter_region_impl(domain, local);
        }
        src.load(Ordering::Acquire) == expected
    }

    #[inline]
    fn release<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        _ptr: MarkedPtr<T, Self>,
    ) {
        // Protection is region-scoped (left on guard drop).
    }

    fn drop_guard_state(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
    ) {
        if state.entered {
            state.entered = false;
            leave_region(domain, local);
        }
    }

    unsafe fn retire<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        node: *mut Node<T, Self>,
    ) {
        // Stamp with the highest stamp assigned so far (§3): every thread
        // that might reference the node is ordered before this stamp.
        let stamp = domain.pool.highest_stamp();
        let r = prepare_retire::<T, Self>(node, stamp);
        local.with(|l| l.retired.push_back(r));
    }

    fn flush(domain: &Self::DomainState, local: &LocalCell<Self::LocalState>) {
        // Cycle a region: the push/remove pair advances tail.stamp past
        // every stamp assigned before, making prior retires reclaimable
        // (when no other thread sits in an older region).
        enter_region_impl(domain, local);
        leave_region(domain, local);
        reclaim_local(domain, local);
        reclaim_global(domain);
    }

    fn drain_domain(domain: &mut Self::DomainState) {
        // Exclusive access: no handles → no regions → everything parked on
        // the global list is reclaimable.
        // SAFETY: see above.
        unsafe {
            domain.global_retired.reclaim_where(|_| true);
        }
    }
}

/// The global domain's Stamp Pool (diagnostics, micro-benches).
pub fn stamp_pool() -> &'static StampPool {
    Domain::<StampIt>::global().state().pool()
}

/// Set the global domain's threshold (ablation compatibility; owned domains
/// use [`StampDomain::set_threshold`]).
pub fn set_threshold(t: usize) {
    Domain::<StampIt>::global().state().set_threshold(t);
}

/// The global domain's current threshold.
pub fn threshold() -> usize {
    Domain::<StampIt>::global().state().threshold()
}

/// Nodes currently parked on the global domain's retire-list (diagnostics).
pub fn global_retired_count() -> usize {
    Domain::<StampIt>::global().state().global_retired_count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;
    use crate::reclaim::{DomainRef, Region};

    // Each test runs in its own domain — no cross-test retire-list or
    // region traffic, no serialization lock needed.

    #[test]
    fn basic_reclamation() {
        exercise_basic_reclamation::<StampIt>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        exercise_guard_blocks_reclamation::<StampIt>();
    }

    #[test]
    fn region_guard_amortizes_and_protects() {
        exercise_region_guard::<StampIt>();
    }

    #[test]
    fn concurrent_smoke() {
        exercise_concurrent_smoke::<StampIt>(4, 500);
    }

    #[test]
    fn reclaim_is_prompt_after_region_cycle() {
        use crate::reclaim::alloc_node;
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        // Stamp-it's efficiency claim in miniature: retire inside a region,
        // and one region cycle later the node is gone — no epoch lag.
        let domain = DomainRef::<StampIt>::new_owned();
        let h = domain.register();
        let drops = Arc::new(AtomicUsize::new(0));
        {
            let _r = Region::enter(&h);
            let node = alloc_node::<Payload, StampIt>(Payload::new(1, &drops));
            unsafe { h.retire(node) };
        } // region exit reclaims: we are the last thread in this domain
        assert_eq!(drops.load(Ordering::Relaxed), 1, "retire must resolve at region exit");
    }

    #[test]
    fn threshold_pushes_surplus_to_global_list() {
        use crate::reclaim::alloc_node;
        use std::sync::atomic::AtomicUsize;
        use std::sync::{Arc, Barrier};
        let domain = DomainRef::<StampIt>::new_owned();
        let h = domain.register();
        let drops = Arc::new(AtomicUsize::new(0));
        let gate = Arc::new(Barrier::new(2));
        let gate2 = gate.clone();
        let domain2 = domain.clone();
        // A second thread parks inside a region so our exit is NOT last.
        let parked = std::thread::spawn(move || {
            let h2 = domain2.register();
            let _r = Region::enter(&h2);
            gate2.wait(); // region open
            gate2.wait(); // main thread done retiring
        });
        gate.wait();
        let n = domain.domain().state().threshold() + 8;
        {
            let _r = Region::enter(&h);
            for i in 0..n {
                let node = alloc_node::<Payload, StampIt>(Payload::new(i as u64, &drops));
                unsafe { h.retire(node) };
            }
        }
        // Not last (parked thread holds an older stamp): nothing reclaimed;
        // the surplus went to the global list.
        assert_eq!(drops.load(Ordering::Relaxed), 0);
        gate.wait();
        parked.join().unwrap();
        flush_until(&h, || drops.load(Ordering::Relaxed) == n);
        assert_eq!(drops.load(Ordering::Relaxed), n);
    }

    #[test]
    fn owned_domain_drains_on_drop() {
        use crate::reclaim::alloc_node;
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        let drops = Arc::new(AtomicUsize::new(0));
        {
            let domain = DomainRef::<StampIt>::new_owned();
            let h = domain.register();
            // Retire without ever cycling a region: nothing is reclaimable
            // while the handle lives (no "last thread" event).
            let node = alloc_node::<Payload, StampIt>(Payload::new(9, &drops));
            unsafe { h.retire(node) };
            drop(h); // hands the node to the domain's global list
        } // last DomainRef drops → drain_domain reclaims everything
        assert_eq!(drops.load(Ordering::Relaxed), 1, "domain drop must drain parked nodes");
    }
}

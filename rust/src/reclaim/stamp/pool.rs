//! The **Stamp Pool** (paper §3.1–§3.2): a lock-free doubly-linked list of
//! thread control blocks built on Sundell & Tsigas' design with the paper's
//! twist — the `prev` direction (head → tail) is kept *consistent* while
//! `next` pointers are only hints, the reverse of the original.
//!
//! Supported operations (paper §3):
//!  1. [`StampPool::push`] — add a block, assigning a strictly increasing
//!     stamp (via FAA on `head.stamp`).
//!  2. [`StampPool::remove`] — remove a specific block from any position;
//!     returns `true` iff it held the lowest stamp (the "last thread").
//!  3. [`StampPool::highest_stamp`] — last stamp assigned (read off `head`).
//!  4. [`StampPool::lowest_stamp`] — lowest stamp of any pooled block
//!     (read off `tail`, maintained by `update_tail_stamp`).
//!
//! ## Link-word representation (§Deviation in DESIGN.md)
//!
//! The paper borrows 17 version-tag bits + 1 delete-mark bit *inside* each
//! 64-bit pointer. Portable Rust has no spare pointer bits to borrow, so
//! blocks live in an arena and a link word packs
//! `{ tag:31 | mark:1 | index:32 }` — same ABA discipline, wider tags
//! (strictly fewer undetectable wrap-arounds than the paper's 2^17), and
//! identical block-reuse semantics (blocks are recycled through a free-list
//! exactly like the paper's reused `thread_control_block`s).
//!
//! ## Stamp-word layout (paper §3.1)
//!
//! Bit 0 = `PendingPush`, bit 1 = `NotInList`, stamps grow by
//! `STAMP_INC = 4`. A pending block carries `final − STAMP_INC +
//! PendingPush` until its push completes (Listing 4), so its stamp sorts
//! *below* its final position while it is not yet reliably in the list.

use crate::util::cache_pad::CachePadded;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// `PendingPush` flag (paper §3.1).
pub const PENDING_PUSH: u64 = 1;
/// `NotInList` flag (paper §3.1).
pub const NOT_IN_LIST: u64 = 2;
/// Stamp increment: stamps live above the two flag bits.
pub const STAMP_INC: u64 = 4;

/// Arena index of the `head` dummy block.
pub const HEAD: u32 = 0;
/// Arena index of the `tail` dummy block.
pub const TAIL: u32 = 1;

const MARK_BIT: u64 = 1 << 32;
const TAG_SHIFT: u32 = 33;
const TAG_MASK: u64 = (1 << 31) - 1;

/// Build a link word.
#[inline]
fn lw(idx: u32, mark: bool, tag: u64) -> u64 {
    ((tag & TAG_MASK) << TAG_SHIFT) | ((mark as u64) * MARK_BIT) | idx as u64
}

/// Target block index of a link word.
#[inline]
pub fn lw_idx(w: u64) -> u32 {
    w as u32
}

/// Delete mark of a link word.
#[inline]
pub fn lw_mark(w: u64) -> bool {
    w & MARK_BIT != 0
}

/// Version tag of a link word.
#[inline]
fn lw_tag(w: u64) -> u64 {
    w >> TAG_SHIFT
}

/// The word that replaces `expected` when retargeting a link: new index and
/// mark, tag bumped — every modification increments the tag (ABA guard).
#[inline]
fn bump(expected: u64, idx: u32, mark: bool) -> u64 {
    lw(idx, mark, lw_tag(expected).wrapping_add(1))
}

/// One thread control block (paper: `thread_control_block`).
#[derive(Default)]
pub struct Block {
    /// Consistent direction head → tail (always a correct list).
    prev: AtomicU64,
    /// Hint direction tail → head (may lag behind).
    next: AtomicU64,
    /// Stamp + flag bits.
    stamp: AtomicU64,
}

/// The Stamp Pool.
pub struct StampPool {
    blocks: Box<[CachePadded<Block>]>,
    /// Treiber free-list of recycled block indices: `{tag:32 | idx+1:32}`,
    /// 0 = empty. ABA-safe by tag (same discipline as the link words).
    free_head: AtomicU64,
    free_next: Box<[AtomicU32]>,
    /// Next never-used block index.
    next_fresh: AtomicU32,
}

// SAFETY: all state is atomics.
unsafe impl Send for StampPool {}
unsafe impl Sync for StampPool {}

impl StampPool {
    /// A pool with capacity for `capacity` simultaneously registered
    /// threads (blocks are recycled; this bounds *peak* concurrency).
    pub fn new(capacity: usize) -> Self {
        let blocks: Box<[CachePadded<Block>]> =
            (0..capacity + 2).map(|_| CachePadded::new(Block::default())).collect();
        // head.prev -> tail: the empty list. tail.next -> head: hint.
        blocks[HEAD as usize].prev.store(lw(TAIL, false, 0), Ordering::Relaxed);
        blocks[HEAD as usize].next.store(lw(TAIL, false, 0), Ordering::Relaxed);
        blocks[TAIL as usize].prev.store(lw(TAIL, false, 0), Ordering::Relaxed);
        blocks[TAIL as usize].next.store(lw(HEAD, false, 0), Ordering::Relaxed);
        // head.stamp = highest assigned so far (none yet). tail.stamp =
        // lowest pooled; starts above head so an empty pool reclaims all.
        blocks[HEAD as usize].stamp.store(0, Ordering::Relaxed);
        blocks[TAIL as usize].stamp.store(STAMP_INC, Ordering::Relaxed);
        let free_next = (0..capacity + 2).map(|_| AtomicU32::new(0)).collect();
        Self { blocks, free_head: AtomicU64::new(0), free_next, next_fresh: AtomicU32::new(2) }
    }

    #[inline]
    fn b(&self, idx: u32) -> &Block {
        &self.blocks[idx as usize]
    }

    // ---- block lifecycle ----------------------------------------------

    /// Claim a block for a thread (fresh or recycled). Tags and stamp of a
    /// recycled block are *not* reset — continuity is what makes reuse
    /// ABA-safe.
    pub fn alloc_block(&self) -> u32 {
        loop {
            let head = self.free_head.load(Ordering::Acquire);
            let idx_plus1 = head as u32;
            if idx_plus1 != 0 {
                let idx = idx_plus1 - 1;
                let next = self.free_next[idx as usize].load(Ordering::Relaxed);
                let new = ((head >> 32).wrapping_add(1) << 32) | next as u64;
                if self
                    .free_head
                    .compare_exchange_weak(head, new, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    return idx;
                }
                continue;
            }
            let idx = self.next_fresh.fetch_add(1, Ordering::Relaxed);
            assert!(
                (idx as usize) < self.blocks.len(),
                "stamp pool exhausted: more than {} concurrent threads",
                self.blocks.len() - 2
            );
            // Fresh blocks start fully removed (NotInList), like recycled
            // ones — uniform lifecycle for free_block.
            self.b(idx).stamp.store(NOT_IN_LIST, Ordering::Relaxed);
            return idx;
        }
    }

    /// Return a block to the free-list (thread exit). The block must be
    /// fully removed (`NotInList` set).
    pub fn free_block(&self, idx: u32) {
        debug_assert!(self.b(idx).stamp.load(Ordering::Relaxed) & NOT_IN_LIST != 0);
        loop {
            let head = self.free_head.load(Ordering::Acquire);
            self.free_next[idx as usize].store(head as u32, Ordering::Relaxed);
            let new = ((head >> 32).wrapping_add(1) << 32) | (idx + 1) as u64;
            if self
                .free_head
                .compare_exchange_weak(head, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
        }
    }

    // ---- stamp queries --------------------------------------------------

    /// Highest stamp assigned so far (paper op 3; read off `head`).
    #[inline]
    pub fn highest_stamp(&self) -> u64 {
        self.b(HEAD).stamp.load(Ordering::Acquire)
    }

    /// Lowest stamp of all pooled blocks (paper op 4; read off `tail`).
    /// Everything retired with a stamp strictly below this is reclaimable.
    #[inline]
    pub fn lowest_stamp(&self) -> u64 {
        self.b(TAIL).stamp.load(Ordering::Acquire)
    }

    // ---- push (paper Listing 4) ----------------------------------------

    /// Insert `b_idx` right after `head`, assigning and returning its new
    /// stamp. Lock-free: a failed CAS implies another push/remove made
    /// progress.
    pub fn push(&self, b_idx: u32) -> u64 {
        let blk = self.b(b_idx);
        // Reset next to head; this also clears next's delete mark. Plain
        // bump-store: the block is private until the insertion CAS.
        let old_next = blk.next.load(Ordering::Relaxed);
        blk.next.store(bump(old_next, HEAD, false), Ordering::Relaxed);

        let head = self.b(HEAD);
        let mut head_prev = head.prev.load(Ordering::Acquire);
        let stamp;
        let my_prev;
        loop {
            let head_prev2 = head.prev.load(Ordering::Acquire);
            if head_prev != head_prev2 {
                head_prev = head_prev2;
                continue;
            }
            // FAA on head.stamp: head always holds the highest stamp; ours
            // is the new value (strictly increasing, not consecutive on
            // retry). SeqCst: the stamp order is the paper's total order on
            // region entries.
            let s = head.stamp.fetch_add(STAMP_INC, Ordering::SeqCst) + STAMP_INC;
            // Pending encoding (Listing 4): final − STAMP_INC + PendingPush.
            blk.stamp.store(s - STAMP_INC + PENDING_PUSH, Ordering::SeqCst);
            if head.prev.load(Ordering::Acquire) != head_prev {
                head_prev = head.prev.load(Ordering::Acquire);
                continue;
            }
            // b.prev := head's current successor (tag-bumped plain store —
            // still private).
            let old_prev = blk.prev.load(Ordering::Relaxed);
            let new_prev = bump(old_prev, lw_idx(head_prev), false);
            blk.prev.store(new_prev, Ordering::Relaxed);
            // Publication CAS: AcqRel — releases the block's initialization
            // to traversers.
            if head
                .prev
                .compare_exchange(
                    head_prev,
                    bump(head_prev, b_idx, false),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                stamp = s;
                my_prev = new_prev;
                break;
            }
            head_prev = head.prev.load(Ordering::Acquire);
        }
        // In the prev list: clear PendingPush (helpers may have raced us
        // with the same final value via move_next — identical store).
        blk.stamp.store(stamp, Ordering::SeqCst);

        // Final step: set our successor's next hint to us (CAS loop,
        // Listing 4 lines 17-25). Give up if the successor got marked, its
        // next already points at us, or our prev moved on.
        let succ = self.b(lw_idx(my_prev));
        loop {
            let link = succ.next.load(Ordering::Acquire);
            if lw_idx(link) == b_idx
                || lw_mark(link)
                || blk.prev.load(Ordering::Acquire) != my_prev
            {
                break;
            }
            if succ
                .next
                .compare_exchange(
                    link,
                    bump(link, b_idx, false),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                break;
            }
        }
        stamp
    }

    // ---- remove (paper Listing 5) ---------------------------------------

    /// Remove `b_idx` from the pool. Returns `true` iff this block was the
    /// one with the lowest stamp ("last thread", who then owns global
    /// reclamation).
    pub fn remove(&self, b_idx: u32) -> bool {
        let blk = self.b(b_idx);
        // Mark both own pointers: signals removal and freezes them against
        // CAS updates from threads that saw the mark.
        let mut prev = self.set_mark(&blk.prev);
        let mut next = self.set_mark(&blk.next);

        let fully_removed = self.remove_from_prev_list(&mut prev, b_idx, &mut next);
        if !fully_removed {
            self.remove_from_next_list(prev, b_idx, next);
        }

        // Fully removed: set NotInList (stamp's low bits are flag space).
        let stamp = blk.stamp.load(Ordering::Relaxed);
        debug_assert_eq!(stamp & (PENDING_PUSH | NOT_IN_LIST), 0);
        blk.stamp.store(stamp | NOT_IN_LIST, Ordering::SeqCst);

        // Were we the last (lowest-stamp) block? Then tail's stamp must
        // advance to the new minimum.
        let was_last = lw_idx(blk.prev.load(Ordering::Acquire)) == TAIL;
        if was_last {
            self.update_tail_stamp(stamp + STAMP_INC);
        }
        was_last
    }

    /// Set the delete mark on a link (bumping the tag); returns the marked
    /// word.
    fn set_mark(&self, link: &AtomicU64) -> u64 {
        let mut w = link.load(Ordering::Acquire);
        loop {
            if lw_mark(w) {
                return w;
            }
            let marked = bump(w, lw_idx(w), true);
            match link.compare_exchange_weak(w, marked, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return marked,
                Err(cur) => w = cur,
            }
        }
    }

    /// Try to set the delete mark on `idx`'s next pointer while its stamp
    /// still equals `stamp` (Listing 7). Returns false iff the stamp
    /// changed — i.e. the block was removed (and possibly reused), which
    /// lets callers conclude their own block is gone too.
    fn mark_next(&self, idx: u32, stamp: u64) -> bool {
        let blk = self.b(idx);
        loop {
            let link = blk.next.load(Ordering::Acquire);
            if blk.stamp.load(Ordering::Acquire) != stamp {
                return false;
            }
            if lw_mark(link) {
                return true;
            }
            if blk
                .next
                .compare_exchange_weak(
                    link,
                    bump(link, lw_idx(link), true),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return true;
            }
        }
    }

    /// Move `next` one step along the prev direction (Listing 3), helping a
    /// lingering `PendingPush` block finish its push first (required for
    /// lock-freedom — otherwise the very next iteration would bounce the
    /// caller back in the next direction forever).
    fn move_next(&self, next_prev: u64, next: &mut u64, last: &mut Option<u64>) {
        let cand = self.b(lw_idx(next_prev));
        let s = cand.stamp.load(Ordering::Acquire);
        if s & PENDING_PUSH != 0 {
            // The candidate is in the prev list (we reached it through a
            // prev pointer) but its push is unfinished: help reset the flag
            // (pending encoding → final value, Listing 4's final store).
            let fin = s - PENDING_PUSH + STAMP_INC;
            let _ = cand.stamp.compare_exchange(s, fin, Ordering::AcqRel, Ordering::Relaxed);
        }
        *last = Some(*next);
        *next = next_prev;
    }

    /// If `next` is marked, remove it from the prev list (when `last`, its
    /// supposed predecessor, is known) or step back along the next
    /// direction (Listing 8). Returns true if it changed anything (caller
    /// restarts its loop).
    fn remove_or_skip_marked_block(
        &self,
        next: &mut u64,
        last: &mut Option<u64>,
        next_prev: u64,
        next_stamp: u64,
    ) -> bool {
        if !lw_mark(next_prev) {
            return false;
        }
        // `next` is marked for deletion.
        if let Some(l) = last.take() {
            // Help remove it: freeze its next, then splice it out of the
            // prev list by retargeting last.prev from next to next's prev.
            self.mark_next(lw_idx(*next), next_stamp);
            let last_blk = self.b(lw_idx(l));
            let last_prev = last_blk.prev.load(Ordering::Acquire);
            if lw_idx(last_prev) == lw_idx(*next) && !lw_mark(last_prev) {
                let _ = last_blk.prev.compare_exchange(
                    last_prev,
                    bump(last_prev, lw_idx(next_prev), false),
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                );
            }
            *next = l;
        } else {
            // No predecessor known: fall back along the next direction.
            *next = self.b(lw_idx(*next)).next.load(Ordering::Acquire);
        }
        true
    }

    /// Remove `b_idx` from the (consistent) prev list — paper Listing 2.
    /// Returns true iff the block turned out to be already fully removed
    /// from *both* lists.
    fn remove_from_prev_list(&self, prev: &mut u64, b_idx: u32, next: &mut u64) -> bool {
        let my_stamp = self.b(b_idx).stamp.load(Ordering::Acquire);
        let mut last: Option<u64> = None;
        loop {
            // (7) prev caught up with next: b is out of the prev list.
            if lw_idx(*next) == lw_idx(*prev) {
                *next = self.b(b_idx).next.load(Ordering::Acquire);
                return false;
            }
            let prev_blk = self.b(lw_idx(*prev));
            let prev_prev = prev_blk.prev.load(Ordering::Acquire);
            let prev_stamp = prev_blk.stamp.load(Ordering::Acquire);
            // (12) prev was removed (together with b): higher stamp means it
            // was reinserted, NotInList means it is gone — either way every
            // block between it and b (all marked) is out, including b.
            if prev_stamp > my_stamp || prev_stamp & NOT_IN_LIST != 0 {
                return true;
            }
            // (14) prev itself is marked: help freeze it, then step towards
            // tail.
            if lw_mark(prev_prev) {
                if !self.mark_next(lw_idx(*prev), prev_stamp) {
                    return true; // stamp changed → prev (and b) removed
                }
                *prev = prev_blk.prev.load(Ordering::Acquire);
                continue;
            }
            // (18) consistent (prev, stamp) snapshot of next.
            let next_blk = self.b(lw_idx(*next));
            let next_prev = next_blk.prev.load(Ordering::Acquire);
            let next_stamp = next_blk.stamp.load(Ordering::Acquire);
            if next_prev != next_blk.prev.load(Ordering::Acquire) {
                continue;
            }
            // (21) next sank below b in stamp order: b is out of the prev
            // list.
            if next_stamp < my_stamp {
                *next = self.b(b_idx).next.load(Ordering::Acquire);
                return false;
            }
            // (24) next is not reliably in the prev list: back off along
            // the next direction (or to last).
            if next_stamp & (NOT_IN_LIST | PENDING_PUSH) != 0 {
                if let Some(l) = last.take() {
                    *next = l;
                } else {
                    *next = next_blk.next.load(Ordering::Acquire);
                }
                continue;
            }
            // (30) next marked: remove or skip it.
            if self.remove_or_skip_marked_block(next, &mut last, next_prev, next_stamp) {
                continue;
            }
            // (33) next is not b's direct predecessor yet: advance.
            if lw_idx(next_prev) != b_idx {
                self.move_next(next_prev, next, &mut last);
                continue;
            }
            // (37) found the predecessor: splice b out of the prev list.
            if next_blk
                .prev
                .compare_exchange(
                    next_prev,
                    bump(next_prev, lw_idx(*prev), false),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_ok()
            {
                return false;
            }
        }
    }

    /// Remove `b_idx` from the (hint) next list — paper Listing 6. `prev`
    /// and `next` continue from where `remove_from_prev_list` left off.
    fn remove_from_next_list(&self, mut prev: u64, b_idx: u32, mut next: u64) {
        let my_stamp = self.b(b_idx).stamp.load(Ordering::Acquire);
        let mut last: Option<u64> = None;
        loop {
            // Consistent snapshot of next.
            let next_blk = self.b(lw_idx(next));
            let next_prev = next_blk.prev.load(Ordering::Acquire);
            let next_stamp = next_blk.stamp.load(Ordering::Acquire);
            if next_prev != next_blk.prev.load(Ordering::Acquire) {
                continue;
            }
            // next is not reliably in the prev list: back off.
            if next_stamp & (NOT_IN_LIST | PENDING_PUSH) != 0 {
                if let Some(l) = last.take() {
                    next = l;
                } else {
                    next = next_blk.next.load(Ordering::Acquire);
                }
                continue;
            }
            let prev_blk = self.b(lw_idx(prev));
            let prev_next = prev_blk.next.load(Ordering::Acquire);
            let prev_stamp = prev_blk.stamp.load(Ordering::Acquire);
            // prev removed (and so are we, from the next list's view).
            if prev_stamp > my_stamp || prev_stamp & NOT_IN_LIST != 0 {
                return;
            }
            // prev's next is frozen: prev is being removed — step towards
            // tail and help from there.
            if lw_mark(prev_next) {
                prev = prev_blk.prev.load(Ordering::Acquire);
                continue;
            }
            if lw_idx(next) == lw_idx(prev) {
                return;
            }
            if self.remove_or_skip_marked_block(&mut next, &mut last, next_prev, next_stamp) {
                continue;
            }
            // next must sit directly above prev in the prev direction.
            if lw_idx(next_prev) != lw_idx(prev) {
                self.move_next(next_prev, &mut next, &mut last);
                continue;
            }
            // b already invisible in the next list?
            if next_stamp <= my_stamp || lw_idx(prev_next) == lw_idx(next) {
                return;
            }
            // Retarget prev.next to skip b; re-validate next's membership
            // and bail out only if next stayed unmarked (else keep helping).
            if next_blk.prev.load(Ordering::Acquire) == next_prev
                && prev_blk
                    .next
                    .compare_exchange(
                        prev_next,
                        bump(prev_next, lw_idx(next), false),
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                && !lw_mark(next_blk.next.load(Ordering::Acquire))
            {
                return;
            }
        }
    }

    /// After removing the last (lowest-stamp) block, advance `tail.stamp`
    /// to the new minimum — paper Listing 9. `fallback` (= our stamp +
    /// STAMP_INC) is the "next best guess": safe because stamps are
    /// strictly increasing, so every remaining block's stamp is ≥ it.
    fn update_tail_stamp(&self, fallback: u64) {
        let tail = self.b(TAIL);
        let mut new_stamp = fallback;
        // Try to identify tail's actual predecessor through the next hint.
        let hint = tail.next.load(Ordering::Acquire);
        let cand_idx = lw_idx(hint);
        if cand_idx != HEAD {
            let cand = self.b(cand_idx);
            let s = cand.stamp.load(Ordering::Acquire);
            let cand_prev = cand.prev.load(Ordering::Acquire);
            // Only trust the candidate if it is demonstrably the current
            // last block: unflagged, unmarked, prev pointing at tail, and
            // the hint did not move under us.
            if s & (PENDING_PUSH | NOT_IN_LIST) == 0
                && !lw_mark(cand_prev)
                && lw_idx(cand_prev) == TAIL
                && tail.next.load(Ordering::Acquire) == hint
                && s > new_stamp
            {
                new_stamp = s;
            }
        }
        // Monotonic max CAS loop (Listing 9 lines 21-25).
        let mut cur = tail.stamp.load(Ordering::Acquire);
        while cur < new_stamp {
            match tail.stamp.compare_exchange_weak(
                cur,
                new_stamp,
                Ordering::SeqCst,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    // ---- diagnostics -----------------------------------------------------

    /// Number of blocks currently linked in the prev direction (O(n),
    /// single-threaded diagnostics/tests only — concurrent mutation makes
    /// the count approximate).
    pub fn len_prev_list(&self) -> usize {
        let mut n = 0;
        let mut cur = lw_idx(self.b(HEAD).prev.load(Ordering::Acquire));
        while cur != TAIL {
            n += 1;
            assert!(n <= self.blocks.len(), "prev list cycle");
            cur = lw_idx(self.b(cur).prev.load(Ordering::Acquire));
        }
        n
    }

    /// Stamps along the prev direction, head → tail (diagnostics).
    pub fn stamps_prev_list(&self) -> Vec<u64> {
        let mut v = Vec::new();
        let mut cur = lw_idx(self.b(HEAD).prev.load(Ordering::Acquire));
        while cur != TAIL {
            v.push(self.b(cur).stamp.load(Ordering::Acquire));
            cur = lw_idx(self.b(cur).prev.load(Ordering::Acquire));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as A64;
    use std::sync::Arc;

    #[test]
    fn sequential_push_remove_single() {
        let pool = StampPool::new(8);
        let b = pool.alloc_block();
        let s = pool.push(b);
        assert_eq!(s, STAMP_INC);
        assert_eq!(pool.highest_stamp(), s);
        assert_eq!(pool.len_prev_list(), 1);
        // Only block ⇒ it is the "last thread".
        assert!(pool.remove(b));
        assert_eq!(pool.len_prev_list(), 0);
        // Tail advanced past our stamp: everything retired before is free.
        assert!(pool.lowest_stamp() > s);
        pool.free_block(b);
    }

    #[test]
    fn stamps_strictly_increase_and_order_prev_list() {
        let pool = StampPool::new(8);
        let blocks: Vec<u32> = (0..4).map(|_| pool.alloc_block()).collect();
        let mut prev_stamp = 0;
        for &b in &blocks {
            let s = pool.push(b);
            assert!(s > prev_stamp, "stamps must strictly increase");
            prev_stamp = s;
        }
        // prev direction = decreasing stamps (head side is newest).
        let stamps = pool.stamps_prev_list();
        assert_eq!(stamps.len(), 4);
        assert!(stamps.windows(2).all(|w| w[0] > w[1]), "{stamps:?}");
        // FIFO removal: each oldest is "last".
        for &b in &blocks {
            assert!(pool.remove(b), "oldest block must be the last thread");
            pool.free_block(b);
        }
        assert_eq!(pool.len_prev_list(), 0);
    }

    #[test]
    fn remove_from_middle_is_not_last() {
        let pool = StampPool::new(8);
        let b1 = pool.alloc_block();
        let b2 = pool.alloc_block();
        let b3 = pool.alloc_block();
        let s1 = pool.push(b1);
        let _s2 = pool.push(b2);
        let _s3 = pool.push(b3);
        // Middle and newest are not last.
        assert!(!pool.remove(b2));
        assert!(!pool.remove(b3));
        assert_eq!(pool.len_prev_list(), 1);
        // Tail stamp must still protect b1's stamp.
        assert!(pool.lowest_stamp() <= s1);
        assert!(pool.remove(b1));
        assert!(pool.lowest_stamp() > s1);
        for b in [b1, b2, b3] {
            pool.free_block(b);
        }
    }

    #[test]
    fn lowest_stamp_never_exceeds_live_minimum() {
        // The core safety invariant: tail.stamp ≤ min(stamp of any pooled
        // block), checked continuously under concurrency.
        let pool = Arc::new(StampPool::new(64));
        let min_live = Arc::new(A64::new(u64::MAX));
        let threads = 4;
        let iters = 300;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let pool = pool.clone();
                let min_live = min_live.clone();
                std::thread::spawn(move || {
                    let b = pool.alloc_block();
                    for i in 0..iters {
                        let s = pool.push(b);
                        // Track a conservative lower bound of live stamps.
                        min_live.fetch_min(s, Ordering::SeqCst);
                        let low = pool.lowest_stamp();
                        assert!(
                            low <= s,
                            "tail stamp {low} overtook live stamp {s}"
                        );
                        if i % 8 == 0 {
                            std::thread::yield_now();
                        }
                        pool.remove(b);
                        min_live.store(u64::MAX, Ordering::SeqCst);
                    }
                    pool.free_block(b);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.len_prev_list(), 0);
    }

    #[test]
    fn concurrent_churn_leaves_empty_pool() {
        let pool = Arc::new(StampPool::new(64));
        let handles: Vec<_> = (0..6)
            .map(|t| {
                let pool = pool.clone();
                std::thread::spawn(move || {
                    let b = pool.alloc_block();
                    let mut lasts = 0usize;
                    for i in 0..400 {
                        let s = pool.push(b);
                        assert_eq!(s & 3, 0, "stamps are multiples of STAMP_INC");
                        if (i + t) % 4 == 0 {
                            std::thread::yield_now();
                        }
                        if pool.remove(b) {
                            lasts += 1;
                        }
                    }
                    pool.free_block(b);
                    lasts
                })
            })
            .collect();
        let total_lasts: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(pool.len_prev_list(), 0, "pool must drain completely");
        assert!(total_lasts > 0, "someone must have been last at least once");
        // tail.stamp may transiently lag after a concurrent drain (a
        // remover whose frozen prev pointer missed TAIL skips the tail
        // update — conservative, therefore safe). One idle cycle repairs
        // it: the new block's prev points at TAIL, so its removal is
        // "last" and publishes a stamp above everything assigned before.
        let high_before = pool.highest_stamp();
        let b = pool.alloc_block();
        pool.push(b);
        assert!(pool.remove(b), "sole block must be last");
        pool.free_block(b);
        assert!(
            pool.lowest_stamp() > high_before,
            "one cycle must advance tail past all prior stamps"
        );
    }

    #[test]
    fn block_reuse_after_free() {
        let pool = StampPool::new(4);
        let a = pool.alloc_block();
        pool.push(a);
        pool.remove(a);
        pool.free_block(a);
        let b = pool.alloc_block();
        assert_eq!(a, b, "freed block must be recycled");
        let s = pool.push(b);
        assert!(s > 0);
        assert!(pool.remove(b));
        pool.free_block(b);
    }
}

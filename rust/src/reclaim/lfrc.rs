//! LFRC — lock-free reference counting (Valois 1995), the paper's
//! reclamation-efficiency "gold standard": a node is reclaimed the instant
//! its last reference is dropped ("there is no delay", §4.4).
//!
//! As the paper stresses, LFRC "is not a general reclamation scheme, since
//! the reclaimed nodes cannot be returned to the memory manager, but are
//! stored in a global free-list": a stale reader may CAS-increment the
//! refcount word of an already-recycled slot, which is only sound with
//! **type-stable memory**. Hence [`Reclaimer::FORCE_POOL`]: LFRC node
//! memory always comes from [`crate::alloc::pool`], whose slots are never
//! unmapped and whose free-lists never touch the first slot word (where the
//! refcount lives).
//!
//! All state is per-node (the refcount word), so LFRC's domain and local
//! state are empty (`()`): every domain trivially provides the same
//! guarantees, and the handle exists only for interface uniformity.
//!
//! ## Protocol
//!
//! The node's first word packs `{RETIRED:1 | count:63}`:
//!
//! * `protect`: read the source, CAS-increment the count (failing fast if
//!   `RETIRED` is set), then *re-validate the source* — a successful
//!   re-read proves the address still names the node we meant; a failed
//!   one means we may have incremented a recycled slot, so we decrement
//!   and retry. Transient "ghost" increments on an unrelated node are
//!   benign: they bracket to ±0, and the erased destructor recorded at
//!   allocation time keeps any freeing they trigger type-correct.
//! * `retire`: `fetch_or(RETIRED)`; if the count was already zero, free.
//! * `release`: `fetch_sub(1)`; whoever transitions the word to exactly
//!   `RETIRED|0` frees — the single atomic word serializes retire/release
//!   races so exactly one party frees.
//!
//! Freeing drops the payload but leaves the slot word at `RETIRED|0` while
//! it sits in the pool free-list, so stale increments keep failing.

use std::sync::atomic::{AtomicU64, Ordering};

use super::domain::LocalCell;
use super::retire::{prepare_retire, reclaim_one, AsRetireHeader, RetireHeader};
use super::{ConcurrentPtr, MarkedPtr, Node, Reclaimer};

const RETIRED: u64 = 1 << 63;

/// Lock-free reference counting (Valois).
pub struct Lfrc;

/// LFRC node header. `refs` **must** be the node's first word — the pool
/// preserves word 0 across free/reuse (see [`crate::alloc::pool`]).
#[repr(C)]
pub struct LfrcHeader {
    refs: AtomicU64,
    retire: RetireHeader,
}

impl Default for LfrcHeader {
    fn default() -> Self {
        // Born RETIRED: the word only becomes live (0) via the atomic store
        // in `on_alloc`, after the erased destructor is in place. This also
        // means the non-atomic header initialization writes the same bit
        // pattern a recycled slot already holds, keeping the (theoretical)
        // init race on reused slots value-identical.
        Self { refs: AtomicU64::new(RETIRED), retire: RetireHeader::default() }
    }
}

impl AsRetireHeader for LfrcHeader {
    fn retire_header(&self) -> &RetireHeader {
        &self.retire
    }
}

/// The refcount word of a (possibly recycled) node address.
///
/// # Safety
/// `addr` must point into pool memory that once held an LFRC node — the
/// pool's type-stability guarantees the first word is always a valid
/// `AtomicU64` refcount.
#[inline]
unsafe fn refs_of<'a, T: Send + Sync + 'static>(node: *mut Node<T, Lfrc>) -> &'a AtomicU64 {
    &(*(node as *mut LfrcHeader)).refs
}

/// Free a node whose refcount word just transitioned to `RETIRED|0`.
///
/// # Safety
/// Exactly one caller may observe that transition.
unsafe fn destroy<T: Send + Sync + 'static>(node: *mut Node<T, Lfrc>) {
    // Use the erased destructor recorded at allocation: the node reachable
    // through this address may not be of the caller's `T` (ghost release on
    // a recycled slot) — the recorded fn is always type-correct.
    reclaim_one((*node).header().retire_header() as *const RetireHeader as *mut RetireHeader);
}

/// Decrement; free on the `RETIRED|0` transition.
///
/// # Safety
/// The caller must hold one counted reference to the slot at `node`.
unsafe fn release_ref<T: Send + Sync + 'static>(node: *mut Node<T, Lfrc>) {
    // Release: all our reads of the payload happen-before the free.
    let old = refs_of(node).fetch_sub(1, Ordering::Release);
    debug_assert!(old & !RETIRED != 0, "refcount underflow");
    if old == RETIRED | 1 {
        // Acquire pairs with other releasers' decrements.
        std::sync::atomic::fence(Ordering::Acquire);
        destroy(node);
    }
}

/// Try to take a counted reference. Fails if the slot is RETIRED.
///
/// # Safety
/// `node` must be a pool address that held an LFRC node at some point.
unsafe fn try_acquire_ref<T: Send + Sync + 'static>(node: *mut Node<T, Lfrc>) -> bool {
    let refs = refs_of(node);
    let mut cur = refs.load(Ordering::Relaxed);
    loop {
        if cur & RETIRED != 0 {
            return false;
        }
        // Acquire on success: the payload writes published before the node
        // became reachable are visible to us.
        match refs.compare_exchange_weak(cur, cur + 1, Ordering::Acquire, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(c) => cur = c,
        }
    }
}

// SAFETY: a node is freed only when its count is zero *and* it is retired;
// protect holds a counted reference validated against the source, so no
// guard can outlive its node (module docs give the full argument including
// slot reuse).
unsafe impl Reclaimer for Lfrc {
    const NAME: &'static str = "LFRC";
    const FORCE_POOL: bool = true;
    type Header = LfrcHeader;
    type GuardState = ();
    type DomainState = ();
    type LocalState = ();

    fn new_domain_state() -> Self::DomainState {}

    crate::reclaim::domain::impl_domain_statics!(Lfrc);

    fn register(_domain: &Self::DomainState) -> Self::LocalState {}

    fn unregister(_domain: &Self::DomainState, _local: &mut Self::LocalState) {}

    unsafe fn on_alloc<T: Send + Sync + 'static>(node: *mut Node<T, Self>) {
        // Record the type-erased destructor *before* arming the refcount:
        // once refs leaves RETIRED, any thread may end up freeing the node.
        prepare_retire::<T, Self>(node, 0);
        refs_of(node).store(0, Ordering::Release);
    }

    fn protect<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
    ) -> MarkedPtr<T, Self> {
        loop {
            let p = src.load(Ordering::Acquire);
            if p.is_null() {
                return p;
            }
            // SAFETY: p names pool memory (LFRC nodes are pool-forced);
            // even if the node was recycled, the word is a valid refcount.
            unsafe {
                if !try_acquire_ref(p.get()) {
                    // Slot is RETIRED: the source can no longer equal p
                    // (nodes are unlinked before retire) — re-read will see
                    // a new value.
                    std::hint::spin_loop();
                    continue;
                }
                // Re-validate: src still naming p proves p is the node we
                // meant (and our count blocks its reclamation).
                if src.load(Ordering::Acquire) == p {
                    return p;
                }
                release_ref(p.get());
            }
        }
    }

    fn protect_if_equal<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        src: &ConcurrentPtr<T, Self>,
        expected: MarkedPtr<T, Self>,
    ) -> bool {
        if expected.is_null() {
            return src.load(Ordering::Acquire) == expected;
        }
        // SAFETY: as in protect.
        unsafe {
            if !try_acquire_ref(expected.get()) {
                return false;
            }
            if src.load(Ordering::Acquire) == expected {
                true
            } else {
                release_ref(expected.get());
                false
            }
        }
    }

    fn release<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        ptr: MarkedPtr<T, Self>,
    ) {
        // SAFETY: the guard holds a counted reference from protect.
        unsafe { release_ref(ptr.get()) };
    }

    unsafe fn retire<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        node: *mut Node<T, Self>,
    ) {
        // AcqRel: the unlink happens-before the (possible) free, and we see
        // all prior increments.
        let old = refs_of(node).fetch_or(RETIRED, Ordering::AcqRel);
        debug_assert_eq!(old & RETIRED, 0, "double retire");
        if old == 0 {
            destroy(node);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;
    use crate::reclaim::{Atomic, DomainRef, Guard, Owned, Stale};
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn basic_reclamation_is_immediate() {
        let h = DomainRef::<Lfrc>::new_owned().register();
        let drops = Arc::new(AtomicUsize::new(0));
        // No guards: retire frees immediately — the "no delay" property.
        h.retire_owned(Owned::new(Payload::new(1, &drops)));
        assert_eq!(drops.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn basic_reclamation() {
        exercise_basic_reclamation::<Lfrc>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        exercise_guard_blocks_reclamation::<Lfrc>();
    }

    #[test]
    fn concurrent_smoke() {
        exercise_concurrent_smoke::<Lfrc>(4, 500);
    }

    #[test]
    fn try_protect_fails_on_retired_slot() {
        let h = DomainRef::<Lfrc>::new_owned().register();
        let drops = Arc::new(AtomicUsize::new(0));
        let cell: Atomic<Payload, Lfrc> = Atomic::new(Owned::new(Payload::new(2, &drops)));
        let stale = cell.load(Ordering::Acquire);
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked above; retired exactly once, in-domain.
        unsafe { h.retire(stale.get()) };
        assert_eq!(drops.load(Ordering::Relaxed), 1);
        // A stale try_protect against the retired slot must fail cleanly
        // (the slot word is RETIRED in the pool free-list).
        let mut g: Guard<Payload, Lfrc> = h.guard();
        assert_eq!(g.try_protect(&cell, stale), Err(Stale));
        assert!(g.is_empty());
    }

    #[test]
    fn many_guards_one_node() {
        let h = DomainRef::<Lfrc>::new_owned().register();
        let drops = Arc::new(AtomicUsize::new(0));
        let cell: Atomic<Payload, Lfrc> = Atomic::new(Owned::new(Payload::new(3, &drops)));
        let node = cell.load(Ordering::Acquire);
        let mut guards: Vec<Guard<'_, Payload, Lfrc>> = (0..32)
            .map(|_| {
                let mut g = h.guard();
                assert!(g.protect(&cell).is_some());
                g
            })
            .collect();
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked above; retired exactly once, in-domain.
        unsafe { h.retire(node.get()) };
        // Drop guards one by one; only the very last drop frees.
        while guards.len() > 1 {
            drop(guards.pop());
            assert_eq!(drops.load(Ordering::Relaxed), 0);
        }
        drop(guards.pop());
        assert_eq!(drops.load(Ordering::Relaxed), 1);
    }
}

//! Per-domain thread registries.
//!
//! Every reclamation domain keeps a lock-free list of per-thread entries
//! (hazard-pointer records, epoch records, ...). Entries are never freed —
//! they are marked inactive when a thread's handle drops and recycled by
//! later threads, so the list length is bounded by the *peak* number of
//! concurrently registered threads
//! (the paper's schemes reuse their `thread_control_block`s the same way,
//! and the implementation "works with arbitrary numbers of threads that can
//! be started and stopped arbitrarily").
//!
//! Iteration is wait-free and never observes dangling entries (entries are
//! immortal); schemes must tolerate entries flipping between active and
//! inactive concurrently with a scan.

use std::sync::atomic::{AtomicBool, AtomicPtr, Ordering};

/// One immortal per-thread entry carrying scheme state `E`.
pub struct ThreadEntry<E> {
    next: *const ThreadEntry<E>,
    active: AtomicBool,
    data: E,
}

impl<E> ThreadEntry<E> {
    /// The scheme state. Shared: the owning thread mutates it through
    /// atomics/cells inside `E`; scanners only read.
    pub fn data(&self) -> &E {
        &self.data
    }

    /// Whether a thread currently owns this entry.
    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Acquire)
    }
}

/// Global lock-free list of [`ThreadEntry`]s with inactive-entry reuse.
pub struct ThreadList<E: Send + Sync + 'static> {
    head: AtomicPtr<ThreadEntry<E>>,
}

impl<E: Send + Sync + 'static> ThreadList<E> {
    pub const fn new() -> Self {
        Self { head: AtomicPtr::new(std::ptr::null_mut()) }
    }

    /// Acquire an entry for the calling thread: recycle an inactive one or
    /// allocate and publish a new one. `fresh` builds the state for a brand
    /// new entry; `recycle` resets the state of a reused entry.
    pub fn acquire(
        &self,
        fresh: impl FnOnce() -> E,
        recycle: impl FnOnce(&E),
    ) -> &'static ThreadEntry<E> {
        // Try to recycle an inactive entry.
        let mut cur = self.head.load(Ordering::Acquire);
        while !cur.is_null() {
            // SAFETY: entries are immortal.
            let entry = unsafe { &*cur };
            if !entry.is_active()
                && entry
                    .active
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                recycle(&entry.data);
                // SAFETY: immortal entry — 'static is accurate.
                return unsafe { &*(entry as *const ThreadEntry<E>) };
            }
            cur = entry.next as *mut ThreadEntry<E>;
        }
        // Allocate a new entry and push it (entries are immortal; the leak
        // is intentional and bounded by the peak thread count).
        let entry = Box::leak(Box::new(ThreadEntry {
            next: std::ptr::null(),
            active: AtomicBool::new(true),
            data: fresh(),
        }));
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            entry.next = head;
            match self.head.compare_exchange_weak(
                head,
                entry as *mut _,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(h) => head = h,
            }
        }
        entry
    }

    /// Mark an entry reusable (thread exit). The caller must have flushed
    /// any scheme state that would confuse the next owner.
    pub fn release(&self, entry: &ThreadEntry<E>) {
        entry.active.store(false, Ordering::Release);
    }

    /// Iterate over all entries ever registered (active and inactive).
    pub fn iter(&self) -> ThreadIter<'_, E> {
        ThreadIter { cur: self.head.load(Ordering::Acquire), _list: self }
    }

    /// Number of entries (active + recyclable). O(n), diagnostics.
    pub fn len(&self) -> usize {
        self.iter().count()
    }

    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Acquire).is_null()
    }
}

/// Iterator over thread entries.
pub struct ThreadIter<'a, E: Send + Sync + 'static> {
    cur: *const ThreadEntry<E>,
    _list: &'a ThreadList<E>,
}

impl<'a, E: Send + Sync + 'static> Iterator for ThreadIter<'a, E> {
    type Item = &'a ThreadEntry<E>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.cur.is_null() {
            return None;
        }
        // SAFETY: entries are immortal and published with Release.
        let entry = unsafe { &*self.cur };
        self.cur = entry.next;
        Some(entry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::{Arc, Barrier};

    #[test]
    fn acquire_release_recycles() {
        static LIST: ThreadList<AtomicUsize> = ThreadList::new();
        let a = LIST.acquire(|| AtomicUsize::new(1), |_| {});
        let a_ptr = a as *const _;
        assert!(a.is_active());
        LIST.release(a);
        assert!(!a.is_active());
        let recycled = Arc::new(AtomicUsize::new(0));
        let r2 = recycled.clone();
        let b = LIST.acquire(
            || AtomicUsize::new(2),
            move |_| {
                r2.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(b as *const _, a_ptr, "inactive entry must be recycled");
        assert_eq!(recycled.load(Ordering::Relaxed), 1);
        LIST.release(b);
    }

    #[test]
    fn concurrent_acquire_is_exclusive() {
        static LIST: ThreadList<usize> = ThreadList::new();
        let n = 8;
        let barrier = Arc::new(Barrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let e = LIST.acquire(|| i, |_| {});
                    let p = e as *const _ as usize;
                    std::thread::yield_now();
                    LIST.release(e);
                    p
                })
            })
            .collect();
        let ptrs: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // All entries end inactive; the list never exceeds the peak
        // concurrency level.
        assert!(LIST.iter().all(|e| !e.is_active()));
        assert!(LIST.len() <= n);
        assert!(!ptrs.is_empty());
    }

    #[test]
    fn recycled_entry_state_is_reset_before_reuse() {
        // Satellite of the domain refactor: a recycled entry must come back
        // with fully reset state — the `recycle` hook runs after the claim
        // CAS and before the entry is handed to the new owner, so the owner
        // never observes the previous thread's residue.
        static LIST: ThreadList<AtomicUsize> = ThreadList::new();
        let a = LIST.acquire(|| AtomicUsize::new(0), |_| {});
        a.data().store(0xDEAD, Ordering::Relaxed); // previous owner's residue
        LIST.release(a);
        let b = LIST.acquire(
            || AtomicUsize::new(0),
            |slot| slot.store(0, Ordering::Relaxed),
        );
        assert_eq!(b as *const _, a as *const _, "must recycle, not grow");
        assert_eq!(b.data().load(Ordering::Relaxed), 0, "residue must be reset");
        assert!(b.is_active());
        LIST.release(b);
    }

    #[test]
    fn churn_recycles_with_reset_under_concurrency() {
        // Waves of short-lived owners: every acquire must observe reset
        // state (the recycle hook zeroes it; owners poison it before
        // release). Also bounds the list by peak concurrency.
        static LIST: ThreadList<AtomicUsize> = ThreadList::new();
        let waves = 8;
        let per_wave = 4;
        for _ in 0..waves {
            let barrier = Arc::new(Barrier::new(per_wave));
            let handles: Vec<_> = (0..per_wave)
                .map(|_| {
                    let barrier = barrier.clone();
                    std::thread::spawn(move || {
                        barrier.wait();
                        let e = LIST.acquire(
                            || AtomicUsize::new(0),
                            |slot| slot.store(0, Ordering::Relaxed),
                        );
                        assert_eq!(
                            e.data().load(Ordering::Relaxed),
                            0,
                            "stale state handed to a recycled owner"
                        );
                        e.data().store(0xBAD, Ordering::Relaxed);
                        std::thread::yield_now();
                        e.data().store(0xBAD, Ordering::Relaxed);
                        LIST.release(e);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        }
        assert!(LIST.len() <= per_wave, "list must be bounded by peak concurrency");
        assert!(LIST.iter().all(|e| !e.is_active()));
    }

    #[test]
    fn iter_sees_published_entries() {
        static LIST: ThreadList<u32> = ThreadList::new();
        let e1 = LIST.acquire(|| 10, |_| {});
        let e2 = LIST.acquire(|| 20, |_| {});
        let values: Vec<u32> = LIST.iter().map(|e| *e.data()).collect();
        assert!(values.contains(&10) && values.contains(&20));
        LIST.release(e1);
        LIST.release(e2);
    }
}

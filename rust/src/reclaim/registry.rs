//! Per-domain thread registries.
//!
//! Every reclamation domain keeps a lock-free list of per-thread entries
//! (hazard-pointer records, epoch records, ...). Entries are **arena-owned
//! by the list**: they are never freed while the list lives — they are
//! marked inactive when a thread's handle drops and recycled by later
//! threads, so the list length is bounded by the *peak* number of
//! concurrently registered threads (the paper's schemes reuse their
//! `thread_control_block`s the same way, and the implementation "works with
//! arbitrary numbers of threads that can be started and stopped
//! arbitrarily"). When the list itself drops — which happens exactly when
//! its owning [`crate::reclaim::Domain`] drops — every entry is returned to
//! the allocator, so per-domain registries no longer cost `domains × peak
//! threads` leaked entries (the ROADMAP's "registry entry reclamation"
//! item).
//!
//! Iteration is wait-free and never observes dangling entries (entries live
//! as long as the list being iterated); schemes must tolerate entries
//! flipping between active and inactive concurrently with a scan.

use std::ptr::NonNull;
use std::sync::atomic::{AtomicBool, AtomicPtr, Ordering};

/// One arena-owned per-thread entry carrying scheme state `E`.
pub struct ThreadEntry<E> {
    next: *const ThreadEntry<E>,
    active: AtomicBool,
    data: E,
}

impl<E> ThreadEntry<E> {
    /// The scheme state. Shared: the owning thread mutates it through
    /// atomics/cells inside `E`; scanners only read.
    pub fn data(&self) -> &E {
        &self.data
    }

    /// Whether a thread currently owns this entry.
    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Acquire)
    }
}

/// A copyable reference to a [`ThreadEntry`] owned by some [`ThreadList`]
/// arena. This is what per-thread scheme state ([`crate::reclaim::Domain`]
/// local states) stores instead of a lifetime-infected borrow.
///
/// # Validity
///
/// An `EntryRef` is valid for exactly as long as its owning `ThreadList`
/// (i.e. the domain that owns the list) is alive. Every holder upholds
/// this structurally: local states live inside a
/// [`crate::reclaim::LocalHandle`], which owns a `DomainRef` that keeps the
/// domain — and hence the list and all its entries — alive; `Domain::drop`
/// (which frees the entries) cannot run while any handle exists.
pub struct EntryRef<E>(NonNull<ThreadEntry<E>>);

impl<E> Clone for EntryRef<E> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<E> Copy for EntryRef<E> {}

impl<E> EntryRef<E> {
    /// Identity of the underlying entry (tests / diagnostics).
    pub fn as_ptr(&self) -> *const ThreadEntry<E> {
        self.0.as_ptr()
    }
}

impl<E> std::ops::Deref for EntryRef<E> {
    type Target = ThreadEntry<E>;

    #[inline]
    fn deref(&self) -> &ThreadEntry<E> {
        // SAFETY: the validity contract in the type docs — the holder keeps
        // the owning list (domain) alive, and entries are never freed
        // individually.
        unsafe { self.0.as_ref() }
    }
}

/// Lock-free list of [`ThreadEntry`]s with inactive-entry reuse. The list
/// owns its entries (arena): they are freed in `Drop`, not before.
pub struct ThreadList<E: Send + Sync + 'static> {
    head: AtomicPtr<ThreadEntry<E>>,
}

impl<E: Send + Sync + 'static> ThreadList<E> {
    pub const fn new() -> Self {
        Self { head: AtomicPtr::new(std::ptr::null_mut()) }
    }

    /// Acquire an entry for the calling thread: recycle an inactive one or
    /// allocate and publish a new one. `fresh` builds the state for a brand
    /// new entry; `recycle` resets the state of a reused entry.
    pub fn acquire(&self, fresh: impl FnOnce() -> E, recycle: impl FnOnce(&E)) -> EntryRef<E> {
        // Try to recycle an inactive entry.
        let mut cur = self.head.load(Ordering::Acquire);
        while !cur.is_null() {
            // SAFETY: published entries live as long as the list.
            let entry = unsafe { &*cur };
            if !entry.is_active()
                && entry
                    .active
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                recycle(&entry.data);
                // SAFETY: cur is non-null (loop invariant).
                return EntryRef(unsafe { NonNull::new_unchecked(cur) });
            }
            cur = entry.next as *mut ThreadEntry<E>;
        }
        // Allocate a new entry and push it. The list owns it from the
        // moment the publishing CAS succeeds; it is freed when the list
        // (its domain) drops.
        let entry = Box::into_raw(Box::new(ThreadEntry {
            next: std::ptr::null(),
            active: AtomicBool::new(true),
            data: fresh(),
        }));
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            // SAFETY: we exclusively own the unpublished entry.
            unsafe { (*entry).next = head };
            match self.head.compare_exchange_weak(
                head,
                entry,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(h) => head = h,
            }
        }
        // SAFETY: Box::into_raw never returns null.
        EntryRef(unsafe { NonNull::new_unchecked(entry) })
    }

    /// Mark an entry reusable (thread exit). The caller must have flushed
    /// any scheme state that would confuse the next owner.
    pub fn release(&self, entry: &ThreadEntry<E>) {
        entry.active.store(false, Ordering::Release);
    }

    /// Iterate over all entries ever registered (active and inactive).
    pub fn iter(&self) -> ThreadIter<'_, E> {
        ThreadIter { cur: self.head.load(Ordering::Acquire), _list: self }
    }

    /// Number of entries (active + recyclable). O(n), diagnostics.
    pub fn len(&self) -> usize {
        self.iter().count()
    }

    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Acquire).is_null()
    }
}

impl<E: Send + Sync + 'static> Drop for ThreadList<E> {
    fn drop(&mut self) {
        // Exclusive access: no thread can hold an `EntryRef` into this list
        // anymore (holders keep the owning domain — and hence this list —
        // alive). Return every arena entry to the allocator.
        let mut cur = *self.head.get_mut();
        while !cur.is_null() {
            // SAFETY: entries were allocated via Box::into_raw in acquire()
            // and are exclusively ours now.
            let entry = unsafe { Box::from_raw(cur) };
            cur = entry.next as *mut ThreadEntry<E>;
        }
    }
}

/// Iterator over thread entries.
pub struct ThreadIter<'a, E: Send + Sync + 'static> {
    cur: *const ThreadEntry<E>,
    _list: &'a ThreadList<E>,
}

impl<'a, E: Send + Sync + 'static> Iterator for ThreadIter<'a, E> {
    type Item = &'a ThreadEntry<E>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.cur.is_null() {
            return None;
        }
        // SAFETY: entries live as long as the list borrowed by `'a` and are
        // published with Release.
        let entry = unsafe { &*self.cur };
        self.cur = entry.next;
        Some(entry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::{Arc, Barrier};

    #[test]
    fn acquire_release_recycles() {
        static LIST: ThreadList<AtomicUsize> = ThreadList::new();
        let a = LIST.acquire(|| AtomicUsize::new(1), |_| {});
        let a_ptr = a.as_ptr();
        assert!(a.is_active());
        LIST.release(&a);
        assert!(!a.is_active());
        let recycled = Arc::new(AtomicUsize::new(0));
        let r2 = recycled.clone();
        let b = LIST.acquire(
            || AtomicUsize::new(2),
            move |_| {
                r2.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(b.as_ptr(), a_ptr, "inactive entry must be recycled");
        assert_eq!(recycled.load(Ordering::Relaxed), 1);
        LIST.release(&b);
    }

    #[test]
    fn concurrent_acquire_is_exclusive() {
        static LIST: ThreadList<usize> = ThreadList::new();
        let n = 8;
        let barrier = Arc::new(Barrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let e = LIST.acquire(|| i, |_| {});
                    let p = e.as_ptr() as usize;
                    std::thread::yield_now();
                    LIST.release(&e);
                    p
                })
            })
            .collect();
        let ptrs: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // All entries end inactive; the list never exceeds the peak
        // concurrency level.
        assert!(LIST.iter().all(|e| !e.is_active()));
        assert!(LIST.len() <= n);
        assert!(!ptrs.is_empty());
    }

    #[test]
    fn recycled_entry_state_is_reset_before_reuse() {
        // Satellite of the domain refactor: a recycled entry must come back
        // with fully reset state — the `recycle` hook runs after the claim
        // CAS and before the entry is handed to the new owner, so the owner
        // never observes the previous thread's residue.
        static LIST: ThreadList<AtomicUsize> = ThreadList::new();
        let a = LIST.acquire(|| AtomicUsize::new(0), |_| {});
        a.data().store(0xDEAD, Ordering::Relaxed); // previous owner's residue
        LIST.release(&a);
        let b = LIST.acquire(
            || AtomicUsize::new(0),
            |slot| slot.store(0, Ordering::Relaxed),
        );
        assert_eq!(b.as_ptr(), a.as_ptr(), "must recycle, not grow");
        assert_eq!(b.data().load(Ordering::Relaxed), 0, "residue must be reset");
        assert!(b.is_active());
        LIST.release(&b);
    }

    #[test]
    fn churn_recycles_with_reset_under_concurrency() {
        // Waves of short-lived owners: every acquire must observe reset
        // state (the recycle hook zeroes it; owners poison it before
        // release). Also bounds the list by peak concurrency.
        static LIST: ThreadList<AtomicUsize> = ThreadList::new();
        let waves = 8;
        let per_wave = 4;
        for _ in 0..waves {
            let barrier = Arc::new(Barrier::new(per_wave));
            let handles: Vec<_> = (0..per_wave)
                .map(|_| {
                    let barrier = barrier.clone();
                    std::thread::spawn(move || {
                        barrier.wait();
                        let e = LIST.acquire(
                            || AtomicUsize::new(0),
                            |slot| slot.store(0, Ordering::Relaxed),
                        );
                        assert_eq!(
                            e.data().load(Ordering::Relaxed),
                            0,
                            "stale state handed to a recycled owner"
                        );
                        e.data().store(0xBAD, Ordering::Relaxed);
                        std::thread::yield_now();
                        e.data().store(0xBAD, Ordering::Relaxed);
                        LIST.release(&e);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        }
        assert!(LIST.len() <= per_wave, "list must be bounded by peak concurrency");
        assert!(LIST.iter().all(|e| !e.is_active()));
    }

    #[test]
    fn iter_sees_published_entries() {
        static LIST: ThreadList<u32> = ThreadList::new();
        let e1 = LIST.acquire(|| 10, |_| {});
        let e2 = LIST.acquire(|| 20, |_| {});
        let values: Vec<u32> = LIST.iter().map(|e| *e.data()).collect();
        assert!(values.contains(&10) && values.contains(&20));
        LIST.release(&e1);
        LIST.release(&e2);
    }

    #[test]
    fn dropping_the_list_frees_every_entry() {
        // The arena property (ROADMAP "registry entry reclamation"): entry
        // state drops — and its memory returns — when the list drops, not
        // at process exit.
        struct CountsDrop(Arc<AtomicUsize>);
        impl Drop for CountsDrop {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::Relaxed);
            }
        }
        // SAFETY-of-test: no EntryRef outlives the list below.
        let drops = Arc::new(AtomicUsize::new(0));
        {
            let list: ThreadList<CountsDrop> = ThreadList::new();
            for _ in 0..3 {
                // Fresh entries each time: previous ones stay active.
                let _ = list.acquire(|| CountsDrop(drops.clone()), |_| {});
            }
            assert_eq!(list.len(), 3);
            assert_eq!(drops.load(Ordering::Relaxed), 0, "alive while the list is");
        }
        assert_eq!(drops.load(Ordering::Relaxed), 3, "arena freed on list drop");
    }
}

//! Reclamation **domains** and cached per-thread **handles** — the
//! instance layer of the [`Reclaimer`] interface.
//!
//! The paper's schemes are usually presented (and were first implemented
//! here) as process-global singletons: one Stamp Pool, one epoch domain and
//! one hazard registry per scheme, reached through `thread_local!` lookups
//! on every operation. This module replaces that shape with two explicit
//! objects, following the paper's own `thread_control_block` discipline
//! (§3) and the per-instance handle model of hazptr-rewrite / Hyaline:
//!
//! * [`Domain<R>`] owns **all** of a scheme's shared state (stamp pool,
//!   epoch counter + registry, hazard registry, global retire lists). Every
//!   former `static` is a field. [`Domain::global()`] is the process-wide
//!   default instance; independent domains (one per shard, per test, per
//!   benchmark trial) never observe each other's retired nodes.
//! * [`LocalHandle<R>`] caches the calling thread's registry entry and
//!   retire list for one domain. Guard acquire/release and region
//!   enter/exit through a handle touch **no TLS and no `RefCell`** — the
//!   thread-control-block access the paper's fast path assumes.
//!
//! ## Borrow discipline ([`LocalCell`])
//!
//! Reclamation runs user `Drop` code, which may re-enter the same scheme on
//! the same thread (a dropped payload retiring further nodes). Handles are
//! single-threaded (`!Send`/`!Sync` via `Rc`), so per-thread state needs no
//! synchronization — but it must never be *mutably aliased* across such a
//! re-entry. [`LocalCell`] enforces the crate-wide rule
//!
//! > scheme code takes short exclusive borrows and **never** runs user
//! > drops while one is active (detach state → release the borrow →
//! > reclaim → merge back)
//!
//! with zero release-mode cost: a plain `UnsafeCell` plus a
//! `debug_assertions`-only borrow flag that turns a violation into a loud
//! panic in debug builds (the role `RefCell` used to play on the hot path).

use std::cell::UnsafeCell;
use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::facade::{Guard, Owned};
use super::retire::AsRetireHeader;
use super::{Node, Reclaimer};

/// Debug-checked, zero-release-cost exclusive access to per-thread scheme
/// state. See the module docs for the discipline it encodes.
pub struct LocalCell<S> {
    state: UnsafeCell<S>,
    #[cfg(debug_assertions)]
    borrowed: std::cell::Cell<bool>,
}

#[cfg(debug_assertions)]
struct BorrowReset<'a>(&'a std::cell::Cell<bool>);

#[cfg(debug_assertions)]
impl Drop for BorrowReset<'_> {
    fn drop(&mut self) {
        self.0.set(false);
    }
}

impl<S> LocalCell<S> {
    pub(crate) fn new(state: S) -> Self {
        Self {
            state: UnsafeCell::new(state),
            #[cfg(debug_assertions)]
            borrowed: std::cell::Cell::new(false),
        }
    }

    /// Run `f` with exclusive access to the state. `f` must not run user
    /// code (drops) that could re-enter this cell — debug builds panic on
    /// violation, release builds rely on the crate-wide discipline.
    #[inline]
    pub fn with<O>(&self, f: impl FnOnce(&mut S) -> O) -> O {
        #[cfg(debug_assertions)]
        let _reset = {
            assert!(
                !self.borrowed.replace(true),
                "LocalCell re-entered: scheme code ran user drops under an active borrow"
            );
            BorrowReset(&self.borrowed)
        };
        // SAFETY: handles are single-threaded (`!Send`/`!Sync`), and the
        // no-user-code-under-borrow discipline (debug-checked above) rules
        // out re-entrant aliasing on this thread.
        f(unsafe { &mut *self.state.get() })
    }

    /// Exclusive access through `&mut self` (handle teardown).
    pub(crate) fn get_mut(&mut self) -> &mut S {
        self.state.get_mut()
    }
}

/// A reclamation domain: one instance of a scheme's shared state.
///
/// Data structures, tests and benchmark trials that use different domains
/// are fully isolated: nodes retired into one domain are reclaimed using
/// only that domain's regions/hazards, and two domains never exchange
/// retired nodes.
pub struct Domain<R: Reclaimer> {
    state: R::DomainState,
    /// Number of TLS handle-cache entries (across all threads) currently
    /// holding a `DomainRef` to this domain. Compared against the `Arc`
    /// strong count to decide eviction: when every remaining owner is a
    /// cache entry, each thread's next sweep drops its own (see
    /// `impl_domain_statics!`).
    cache_pins: AtomicUsize,
    /// Nodes retired into this domain and not yet reclaimed — the paper's
    /// reclamation-efficiency metric, **per domain** (the process-wide
    /// analogue is [`crate::alloc::unreclaimed`]). Incremented by the
    /// handle/guard retire wrappers; decremented by
    /// [`super::retire::reclaim_one`] through the counter pointer stamped
    /// into each retired node's header.
    pending_retires: crate::util::cache_pad::CachePadded<std::sync::atomic::AtomicU64>,
    /// Stall high-water mark: when `pending_retires` crosses this value
    /// upward, an `smr.stall` flight-recorder event fires — the signature
    /// of a stalled reader stranding the retire stream (E19). `0` disables.
    stall_hwm: std::sync::atomic::AtomicU64,
}

/// Default stall high-water mark for fresh domains (see
/// [`Domain::set_stall_watermark`]); `0` disables the event.
static DEFAULT_STALL_HWM: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(100_000);

/// Set the process-wide default stall high-water mark applied to domains
/// created afterwards. `0` disables the `smr.stall` event by default.
pub fn set_default_stall_watermark(hwm: u64) {
    DEFAULT_STALL_HWM.store(hwm, Ordering::Relaxed);
}

impl<R: Reclaimer> Domain<R> {
    /// A fresh, empty domain.
    pub fn new() -> Self {
        Self {
            state: R::new_domain_state(),
            cache_pins: AtomicUsize::new(0),
            pending_retires: crate::util::cache_pad::CachePadded::new(
                std::sync::atomic::AtomicU64::new(0),
            ),
            stall_hwm: std::sync::atomic::AtomicU64::new(DEFAULT_STALL_HWM.load(Ordering::Relaxed)),
        }
    }

    /// The process-wide default domain (what `Queue::new()` &c. use).
    pub fn global() -> &'static Domain<R> {
        R::global()
    }

    /// The scheme's state (stamp pool / epoch domain / hazard registry).
    pub fn state(&self) -> &R::DomainState {
        &self.state
    }

    /// Nodes retired into this domain that have not been reclaimed yet.
    ///
    /// Per-domain view of the paper's reclamation-efficiency metric: with N
    /// isolated domains in one process (one per shard), each reports only
    /// its own parked population, while [`crate::alloc::unreclaimed`] keeps
    /// the process-wide total (which additionally counts live, never-retired
    /// nodes).
    pub fn unreclaimed(&self) -> u64 {
        self.pending_retires.load(Ordering::Relaxed)
    }

    /// Account one retire into this domain and stamp the node's header with
    /// the pending counter so the eventual reclaim decrements it. Called by
    /// the wrapper retire sites ([`LocalHandle::retire`], `GuardPtr::reclaim`)
    /// right before the scheme's `retire` runs.
    pub(crate) fn track_retire(&self, hdr: &super::retire::RetireHeader) {
        crate::trace::event!("smr.retire");
        hdr.set_pending_counter(&self.pending_retires);
        let now = self.pending_retires.fetch_add(1, Ordering::Relaxed) + 1;
        // Fires once per upward crossing (re-arms when the backlog drains
        // below the mark and climbs back over it).
        let hwm = self.stall_hwm.load(Ordering::Relaxed);
        if hwm != 0 && now == hwm {
            crate::trace::event!("smr.stall", now.min(u32::MAX as u64) as u32);
        }
    }

    /// Set this domain's stall high-water mark: crossing it upward emits an
    /// `smr.stall` trace event. `0` disables. Fresh domains inherit the
    /// process default ([`set_default_stall_watermark`]).
    pub fn set_stall_watermark(&self, hwm: u64) {
        self.stall_hwm.store(hwm, Ordering::Relaxed);
    }
}

impl<R: Reclaimer> Default for Domain<R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<R: Reclaimer> Drop for Domain<R> {
    fn drop(&mut self) {
        // `&mut self` proves no handles (they hold `DomainRef`s) and hence
        // no guards or regions exist: every parked retired node is
        // unreachable and safe to reclaim. Never runs for `global()`
        // (statics don't drop).
        R::drain_domain(&mut self.state);
    }
}

enum DomainRefInner<R: Reclaimer> {
    Global,
    Owned(Arc<Domain<R>>),
}

/// A shareable reference to a [`Domain`]: either the process-wide global
/// one or a counted owned instance. This is what data structures store.
pub struct DomainRef<R: Reclaimer>(DomainRefInner<R>);

impl<R: Reclaimer> Clone for DomainRef<R> {
    fn clone(&self) -> Self {
        Self(match &self.0 {
            DomainRefInner::Global => DomainRefInner::Global,
            DomainRefInner::Owned(a) => DomainRefInner::Owned(a.clone()),
        })
    }
}

impl<R: Reclaimer> Default for DomainRef<R> {
    fn default() -> Self {
        Self::global()
    }
}

impl<R: Reclaimer> std::fmt::Debug for DomainRef<R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            DomainRefInner::Global => write!(f, "DomainRef::<{}>::global", R::NAME),
            DomainRefInner::Owned(a) => {
                write!(f, "DomainRef::<{}>({:p})", R::NAME, Arc::as_ptr(a))
            }
        }
    }
}

impl<R: Reclaimer> DomainRef<R> {
    /// The process-wide default domain.
    pub const fn global() -> Self {
        Self(DomainRefInner::Global)
    }

    /// A fresh, isolated domain (one per shard / test / trial).
    pub fn new_owned() -> Self {
        Self(DomainRefInner::Owned(Arc::new(Domain::new())))
    }

    /// Share an existing owned domain.
    pub fn from_arc(domain: Arc<Domain<R>>) -> Self {
        Self(DomainRefInner::Owned(domain))
    }

    /// The referenced domain.
    pub fn domain(&self) -> &Domain<R> {
        match &self.0 {
            DomainRefInner::Global => Domain::global(),
            DomainRefInner::Owned(a) => a,
        }
    }

    /// Stable identity of the referenced domain (TLS handle-cache key;
    /// cached handles keep the `Arc` alive, so the address cannot be
    /// recycled while a cache entry uses it).
    pub(crate) fn key(&self) -> usize {
        self.domain() as *const Domain<R> as usize
    }

    /// Is this owned domain kept alive *only* by TLS handle-cache entries
    /// (every structure, explicit handle and other external `DomainRef`
    /// gone)? Drives cache eviction: each cache entry owns exactly one
    /// `DomainRef` and registers itself in [`Domain::cache_pins`], so
    /// "strong count ≤ pin count" means only caches remain — every
    /// thread's next sweep then drops its own entry (the last one drops,
    /// and drains, the domain). The two counters are read racily, but a
    /// torn reading only defers or triggers an eviction; evicting a cache
    /// entry is always safe (it is a cache — live users hold their own
    /// `DomainRef`s/handles, which keep the strong count above the pins).
    pub(crate) fn only_cache_owned(&self) -> bool {
        match &self.0 {
            DomainRefInner::Global => false,
            DomainRefInner::Owned(a) => {
                Arc::strong_count(a) <= a.cache_pins.load(Ordering::Relaxed)
            }
        }
    }

    /// Register the calling thread with this domain, returning an explicit
    /// handle. The fast-path API: every guard/region/retire through the
    /// handle is TLS-free.
    pub fn register(&self) -> LocalHandle<R> {
        let local = R::register(self.domain().state());
        LocalHandle {
            inner: Rc::new(HandleInner { domain: self.clone(), local: LocalCell::new(local) }),
        }
    }

    /// Run `f` with the calling thread's cached handle for this domain,
    /// registering on first use (one TLS lookup; the convenience path the
    /// [`super::facade::Cached`] handle source uses). Falls back to an
    /// ephemeral registration during thread teardown, when the TLS cache
    /// is gone.
    ///
    /// Cache lifetime: cache misses (and periodically, hits) sweep the
    /// calling thread's cache and drop cached handles whose owned domain
    /// is kept alive *only* by cache entries — on this or any other
    /// thread (see [`CachePin`]) — so long-lived threads no longer pin
    /// short-lived domains until thread exit. A domain that must drop
    /// (and drain) at a deterministic point should still use explicit
    /// [`Self::register`] handles.
    pub fn with_handle<O>(&self, f: impl FnOnce(&LocalHandle<R>) -> O) -> O {
        match R::cached_handle(self) {
            Some(h) => f(&h),
            None => f(&self.register()),
        }
    }
}

// DomainRef is Send + Sync by auto-derivation: `DomainState` is bounded
// `Send + Sync`, so `Arc<Domain<R>>` (and the Global unit variant) already
// carry both. No manual unsafe impls — the compiler revokes the auto traits
// if a non-thread-safe field is ever added.

/// Shared interior of a [`LocalHandle`] (also what attached guards and
/// [`Region`]s keep alive).
pub struct HandleInner<R: Reclaimer> {
    domain: DomainRef<R>,
    local: LocalCell<R::LocalState>,
}

impl<R: Reclaimer> HandleInner<R> {
    #[inline]
    pub(crate) fn domain_state(&self) -> &R::DomainState {
        self.domain.domain().state()
    }

    #[inline]
    pub(crate) fn local(&self) -> &LocalCell<R::LocalState> {
        &self.local
    }
}

impl<R: Reclaimer> Drop for HandleInner<R> {
    fn drop(&mut self) {
        // Thread (or last guard) done with this domain: hand unreclaimed
        // nodes to the domain's shared lists and release the registry entry
        // for reuse. Disjoint field borrows: shared `domain`, `&mut local`.
        R::unregister(self.domain.domain().state(), self.local.get_mut());
        // Unregister may have reclaimed nodes into this thread's magazine
        // rack; push them to the shared depots so a thread that stops using
        // reclamation (handle drop, cache eviction, thread exit) strands no
        // slots. No-op when magazines are off or the rack is empty.
        crate::alloc::flush_magazines();
    }
}

/// A thread's cached attachment to one [`Domain`]: the scheme's
/// thread-control-block (registry entry, hazard slots, retire list) resolved
/// once, then reused by every guard/region/retire without TLS.
///
/// Cheap to clone (`Rc`); not `Send`/`Sync` — each thread registers its own.
pub struct LocalHandle<R: Reclaimer> {
    inner: Rc<HandleInner<R>>,
}

impl<R: Reclaimer> Clone for LocalHandle<R> {
    fn clone(&self) -> Self {
        Self { inner: self.inner.clone() }
    }
}

impl<R: Reclaimer> LocalHandle<R> {
    /// The domain this handle is registered with.
    pub fn domain(&self) -> &Domain<R> {
        self.inner.domain.domain()
    }

    /// A [`DomainRef`] to this handle's domain.
    pub fn domain_ref(&self) -> DomainRef<R> {
        self.inner.domain.clone()
    }

    #[inline]
    pub(crate) fn domain_state(&self) -> &R::DomainState {
        self.inner.domain_state()
    }

    #[inline]
    pub(crate) fn local(&self) -> &LocalCell<R::LocalState> {
        self.inner.local()
    }

    /// An empty protection shield attached to this handle (alias for
    /// [`Guard::new`]; the shield cannot outlive the handle).
    pub fn guard<T: Send + Sync + 'static>(&self) -> Guard<'_, T, R> {
        Guard::new(self)
    }

    /// Enter a critical region scoped to the returned RAII token.
    pub fn region(&self) -> Region<R> {
        Region::enter(self)
    }

    /// Retire a node into this handle's domain.
    ///
    /// # Safety
    /// See [`Reclaimer::retire`]: the node must be unlinked, retired exactly
    /// once, and have been allocated by [`super::alloc_node`] for `R`.
    pub unsafe fn retire<T: Send + Sync + 'static>(&self, node: *mut Node<T, R>) {
        // Per-domain accounting (incl. stamping the node with the pending
        // counter) must precede the scheme retire: LFRC may free inline.
        self.domain().track_retire((*node).header().retire_header());
        R::retire(self.domain_state(), self.local(), node)
    }

    /// Retire an **unpublished** node — safe, because an [`Owned`] is
    /// trivially unlinked (it was never reachable from any `Atomic`), is
    /// consumed by value (retired exactly once) and was allocated for `R`.
    pub fn retire_owned<T: Send + Sync + 'static>(&self, node: Owned<T, R>) {
        // SAFETY: see above — every obligation of `Reclaimer::retire` is
        // discharged by the `Owned` invariants.
        unsafe { self.retire(node.into_raw()) }
    }

    /// Is this handle's owned domain kept alive only by TLS cache entries
    /// (no outside `DomainRef` left)? TLS-cache eviction predicate.
    pub(crate) fn evictable(&self) -> bool {
        self.inner.domain.only_cache_owned()
    }

    /// Best-effort: reclaim everything currently reclaimable in this
    /// domain (bench/test hook; e.g. forces an epoch-advance attempt or an
    /// HP scan).
    pub fn flush(&self) {
        R::flush(self.domain_state(), self.local())
    }
}

/// A TLS handle-cache entry: a cached [`LocalHandle`] registered in its
/// domain's [`Domain::cache_pins`] counter for the eviction policy. The
/// pin is released in `Drop` — which covers both an eviction sweep and
/// the thread-exit TLS destructor — *before* the handle itself drops, so
/// a torn (pins low / count high) reading can only defer an eviction.
pub(crate) struct CachePin<R: Reclaimer>(LocalHandle<R>);

impl<R: Reclaimer> CachePin<R> {
    pub(crate) fn new(handle: LocalHandle<R>) -> Self {
        handle.domain().cache_pins.fetch_add(1, Ordering::Relaxed);
        Self(handle)
    }

    pub(crate) fn handle(&self) -> &LocalHandle<R> {
        &self.0
    }
}

impl<R: Reclaimer> Drop for CachePin<R> {
    fn drop(&mut self) {
        self.0.domain().cache_pins.fetch_sub(1, Ordering::Relaxed);
    }
}

/// RAII `region_guard` (paper §2): amortizes critical-region entry across
/// many guard acquisitions for region-based schemes (NER, QSR, Stamp-it).
pub struct Region<R: Reclaimer> {
    handle: LocalHandle<R>,
}

impl<R: Reclaimer> Region<R> {
    /// Enter a critical region through `handle` (reentrant; guards nest
    /// inside). TLS-free.
    pub fn enter(handle: &LocalHandle<R>) -> Self {
        R::enter_region(handle.domain_state(), handle.local());
        Self { handle: handle.clone() }
    }

    /// Convenience: enter a region on the global domain through the
    /// thread's cached handle (one TLS lookup).
    pub fn enter_global() -> Self {
        DomainRef::<R>::global().with_handle(Region::enter)
    }
}

impl<R: Reclaimer> Drop for Region<R> {
    fn drop(&mut self) {
        R::exit_region(self.handle.domain_state(), self.handle.local());
    }
}

/// Generates the two per-scheme statics the instance model still needs —
/// the `Domain::global()` singleton and the thread-local handle cache —
/// for a concrete scheme type. Statics cannot be generic in Rust, so each
/// scheme instantiates this inside its `Reclaimer` impl.
macro_rules! impl_domain_statics {
    ($scheme:ty) => {
        fn global() -> &'static $crate::reclaim::Domain<Self> {
            // The only `static` scheme state left: the default Domain.
            static GLOBAL: std::sync::OnceLock<$crate::reclaim::Domain<$scheme>> =
                std::sync::OnceLock::new();
            GLOBAL.get_or_init($crate::reclaim::Domain::new)
        }

        fn cached_handle(
            domain: &$crate::reclaim::DomainRef<Self>,
        ) -> Option<$crate::reclaim::LocalHandle<Self>> {
            use $crate::reclaim::domain::CachePin;
            thread_local! {
                static HANDLES: std::cell::RefCell<Vec<(usize, CachePin<$scheme>)>> =
                    const { std::cell::RefCell::new(Vec::new()) };
                static SWEEP_TICK: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
            }
            let key = domain.key();
            // Amortize the eviction scan off the hot hit path: misses
            // (which mutate the cache anyway) always sweep, hits sweep
            // only every 64th resolution. `1` (never 0) on TLS teardown.
            let tick = SWEEP_TICK
                .try_with(|t| {
                    let v = t.get().wrapping_add(1);
                    t.set(v);
                    v
                })
                .unwrap_or(1);
            HANDLES
                .try_with(|cache| {
                    // Evicted entries are collected here and dropped only
                    // after the cache borrow is released: dropping a
                    // handle runs `unregister` (and possibly the domain's
                    // drain), which may run user drops that re-enter this
                    // cache.
                    let mut evicted: Vec<(usize, CachePin<$scheme>)> = Vec::new();
                    let found = {
                        // Handles are cloned out before use so the cache
                        // borrow never spans user code (re-entrant lookups
                        // just miss).
                        let mut cache = cache.try_borrow_mut().ok()?;
                        let is_miss = !cache.iter().any(|(k, _)| *k == key);
                        // Eviction sweep: drop cached handles whose owned
                        // domain is kept alive only by cache entries (on
                        // any thread), so long-lived threads don't pin
                        // dead domains until thread exit.
                        if is_miss || tick % 64 == 0 {
                            let mut i = 0;
                            while i < cache.len() {
                                if cache[i].1.handle().evictable() {
                                    evicted.push(cache.swap_remove(i));
                                } else {
                                    i += 1;
                                }
                            }
                        }
                        if let Some((_, p)) = cache.iter().find(|(k, _)| *k == key) {
                            Some(p.handle().clone())
                        } else {
                            let h = domain.register();
                            cache.push((key, CachePin::new(h.clone())));
                            Some(h)
                        }
                    };
                    drop(evicted);
                    found
                })
                .ok()
                .flatten()
        }
    };
}
pub(crate) use impl_domain_statics;

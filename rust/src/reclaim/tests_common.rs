//! Shared scheme-conformance exercises: every [`Reclaimer`] must pass the
//! same battery. Used by the per-scheme unit tests and re-exported
//! (`#[doc(hidden)]`) for the integration suites under `rust/tests/`.
//!
//! Every exercise runs in its **own** [`DomainRef::new_owned`] domain:
//! tests never share retire lists, epochs, stamps or hazard registries, so
//! they neither race each other's reclamation decisions nor need a
//! serialization lock (the cross-talk the global-singleton design forced).
//!
//! Since the facade redesign the exercises are written against the safe
//! surface ([`Atomic`] / [`Guard`] / [`Shared`](super::Shared) /
//! [`Owned`]): the only remaining `unsafe` is the raw
//! [`LocalHandle::retire`] at unlink sites — the same boundary the data
//! structures keep.

use super::facade::{Atomic, Guard, Owned};
use super::{DomainRef, LocalHandle, MarkedPtr, Reclaimer, Region};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Crate-wide test lock for the few tests that exercise the **global**
/// domain (the TLS convenience path); per-domain tests don't need it.
pub fn serial_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Poll `done` with flushes until it returns true or ~2 s elapse.
///
/// Flushes both `h` and the calling thread's *cached* handle for the same
/// domain: nodes retired through the cached-handle path sit in the cached
/// handle's local retire list, which `h` alone cannot drain.
pub fn flush_until<R: Reclaimer>(h: &LocalHandle<R>, mut done: impl FnMut() -> bool) -> bool {
    let domain = h.domain_ref();
    for _ in 0..2000 {
        if done() {
            return true;
        }
        h.flush();
        domain.with_handle(|cached| cached.flush());
        std::thread::yield_now();
        std::thread::sleep(std::time::Duration::from_micros(500));
    }
    done()
}

/// Magic value a live payload must carry; `Drop` poisons it so a
/// use-after-reclaim is loudly detectable.
const MAGIC: u64 = 0xC0FF_EE00_DEAD_10CC;
const POISON: u64 = 0xBAAD_F00D_BAAD_F00D;

/// Drop-counting, self-poisoning payload.
pub struct Payload {
    magic: u64,
    value: u64,
    drops: Arc<AtomicUsize>,
}

impl Payload {
    pub fn new(value: u64, drops: &Arc<AtomicUsize>) -> Self {
        Self { magic: MAGIC, value, drops: drops.clone() }
    }

    /// Read the value, asserting the payload has not been reclaimed.
    pub fn read(&self) -> u64 {
        let m = self.magic;
        assert_eq!(m, MAGIC, "use-after-reclaim: magic={m:#x}");
        self.value
    }
}

impl Drop for Payload {
    fn drop(&mut self) {
        assert_eq!(self.magic, MAGIC, "double reclamation detected");
        self.magic = POISON;
        self.drops.fetch_add(1, Ordering::Relaxed);
    }
}

/// Retire a batch of unpublished nodes (safe: [`LocalHandle::retire_owned`]);
/// after flushing, all of them must have been dropped exactly once.
pub fn exercise_basic_reclamation<R: Reclaimer>() {
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let drops = Arc::new(AtomicUsize::new(0));
    const N: usize = 64;
    for i in 0..N {
        h.retire_owned(Owned::<Payload, R>::new(Payload::new(i as u64, &drops)));
    }
    // Flush until everything is reclaimed (epoch schemes need a few
    // advances; guard-free, so progress is guaranteed).
    flush_until(&h, || drops.load(Ordering::Relaxed) == N);
    assert_eq!(drops.load(Ordering::Relaxed), N, "{} leaked retired nodes", R::NAME);
}

/// A guarded node must survive `retire` + aggressive flushing until the
/// guard is dropped.
pub fn exercise_guard_blocks_reclamation<R: Reclaimer>() {
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let drops = Arc::new(AtomicUsize::new(0));
    let cell: Atomic<Payload, R> = Atomic::new(Owned::new(Payload::new(7, &drops)));
    let node = cell.load(Ordering::Relaxed);

    let mut guard: Guard<Payload, R> = h.guard();
    assert!(guard.protect(&cell).expect("non-null").ptr_eq(node));

    // Unlink, then retire while still guarded.
    cell.store(MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked above; retired exactly once, into the domain whose
    // guard protects it.
    unsafe { h.retire(node.get()) };

    // The reclaimer may try as hard as it wants — the guard must hold.
    // (Retirer == guard holder, the strictest single-thread case.)
    h.flush();
    assert_eq!(drops.load(Ordering::Relaxed), 0, "{}: reclaimed under a live guard", R::NAME);
    assert_eq!(guard.shared().expect("still guarded").read(), 7);

    drop(guard);
    flush_until(&h, || drops.load(Ordering::Relaxed) == 1);
    assert_eq!(drops.load(Ordering::Relaxed), 1, "{}: leak after guard drop", R::NAME);
}

/// Guards created inside an explicit region must be protected and cheap;
/// the region must not leak protection after it ends.
pub fn exercise_region_guard<R: Reclaimer>() {
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let drops = Arc::new(AtomicUsize::new(0));
    let cell: Atomic<Payload, R> = Atomic::new(Owned::new(Payload::new(3, &drops)));
    let node = cell.load(Ordering::Relaxed);
    {
        let _region: Region<R> = Region::enter(&h);
        let mut g: Guard<Payload, R> = h.guard();
        for _ in 0..100 {
            assert_eq!(g.protect(&cell).expect("non-null").read(), 3);
            g.reset();
        }
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked above; retired once, in-domain.
        unsafe { h.retire(node.get()) };
    }
    flush_until(&h, || drops.load(Ordering::Relaxed) == 1);
    assert_eq!(drops.load(Ordering::Relaxed), 1, "{}: leak after region end", R::NAME);
}

/// The facade roundtrip every scheme must support: `Owned` disposal,
/// publish via CAS, branded `Shared` reads, retire-through-guard, and the
/// safe `retire_owned` path. (Leaky runs the structural half only — it
/// never reclaims; see the leaky matrix module.)
pub fn exercise_facade<R: Reclaimer>() {
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let drops = Arc::new(AtomicUsize::new(0));

    // 1. Dropping an unpublished Owned frees it immediately.
    drop(Owned::<Payload, R>::new(Payload::new(1, &drops)));
    assert_eq!(drops.load(Ordering::Relaxed), 1, "{}: Owned drop must free", R::NAME);

    // 2. Publish → protect → read through the branded Shared.
    let cell: Atomic<Payload, R> = Atomic::new(Owned::new(Payload::new(2, &drops)));
    let mut g: Guard<Payload, R> = h.guard();
    let old = {
        let s = g.protect(&cell).expect("non-null");
        assert_eq!(s.read(), 2);
        assert_eq!(s.mark(), 0);
        s.as_marked()
    };

    // 3. Swap in a replacement; the loser is retired through the guard.
    let replacement = Owned::new(Payload::new(3, &drops));
    assert!(cell.cas_publish(old, replacement, Ordering::AcqRel, Ordering::Acquire).is_ok());
    // SAFETY: the CAS above unlinked the node `g` protects; we are the
    // sole retirer, and its readers are protected through this domain.
    unsafe { g.retire() };
    // Region-based schemes hold their critical region until the shield
    // drops — release it so the retired node becomes reclaimable.
    drop(g);
    flush_until(&h, || drops.load(Ordering::Relaxed) == 2);
    assert_eq!(drops.load(Ordering::Relaxed), 2, "{}: guard-retire leak", R::NAME);

    // 4. retire_owned: the safe retire path for unpublished nodes.
    h.retire_owned(Owned::<Payload, R>::new(Payload::new(4, &drops)));
    flush_until(&h, || drops.load(Ordering::Relaxed) == 3);
    assert_eq!(drops.load(Ordering::Relaxed), 3, "{}: retire_owned leak", R::NAME);

    // 5. Drain the cell so the owned domain shuts down clean.
    let last = cell.load(Ordering::Acquire);
    cell.store(MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked above; sole retirer; no shield protects it.
    unsafe { h.retire(last.get()) };
    flush_until(&h, || drops.load(Ordering::Relaxed) == 4);
    assert_eq!(drops.load(Ordering::Relaxed), 4, "{}: final drain leak", R::NAME);
}

/// Multi-threaded swap storm over one shared cell: all nodes funneled
/// through `retire` must be dropped exactly once, and no reader may observe
/// a poisoned payload. Each thread registers its own handle with the shared
/// domain — the TLS-free fast path.
pub fn exercise_concurrent_smoke<R: Reclaimer>(threads: usize, iters: usize) {
    let domain = DomainRef::<R>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));
    let allocated = Arc::new(AtomicUsize::new(0));
    let cell: Arc<Atomic<Payload, R>> = Arc::new(Atomic::null());

    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let domain = domain.clone();
            let drops = drops.clone();
            let allocated = allocated.clone();
            let cell = cell.clone();
            std::thread::spawn(move || {
                let h = domain.register();
                let mut g: Guard<Payload, R> = h.guard();
                for i in 0..iters {
                    let value = (t * iters + i) as u64;
                    let mut node = Owned::new(Payload::new(value, &drops));
                    allocated.fetch_add(1, Ordering::Relaxed);
                    loop {
                        let old = match g.protect(&cell) {
                            Some(s) => {
                                // Reading validates the guard: must not be
                                // poisoned.
                                s.read();
                                s.as_marked()
                            }
                            None => MarkedPtr::null(),
                        };
                        match cell.cas_publish(old, node, Ordering::AcqRel, Ordering::Acquire) {
                            Ok(_) => {
                                g.reset();
                                if !old.is_null() {
                                    // SAFETY: we unlinked `old` with the
                                    // CAS; only the successful CASer
                                    // retires it.
                                    unsafe { h.retire(old.get()) };
                                }
                                break;
                            }
                            Err((_, n)) => node = n,
                        }
                        if i % 16 == 0 {
                            std::thread::yield_now();
                        }
                    }
                }
            })
        })
        .collect();
    for t in handles {
        t.join().unwrap();
    }

    let h = domain.register();
    // Retire the final occupant.
    let last = cell.load(Ordering::Acquire);
    if !last.is_null() {
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: all writers joined; we own the last node.
        unsafe { h.retire(last.get()) };
    }

    flush_until(&h, || drops.load(Ordering::Relaxed) == allocated.load(Ordering::Relaxed));
    assert_eq!(
        drops.load(Ordering::Relaxed),
        allocated.load(Ordering::Relaxed),
        "{}: drops != allocations after flush",
        R::NAME
    );
}

/// Two domains of the same scheme must be fully isolated: aggressive
/// retiring + flushing in one may never reclaim a node whose only
/// protection is a guard registered with the *other*.
pub fn exercise_domain_isolation<R: Reclaimer>() {
    let domain_a = DomainRef::<R>::new_owned();
    let domain_b = DomainRef::<R>::new_owned();
    let ha = domain_a.register();
    let hb = domain_b.register();

    let drops_a = Arc::new(AtomicUsize::new(0));
    let drops_b = Arc::new(AtomicUsize::new(0));

    // Domain A: guard a node, then retire it — protected by A only.
    let cell_a: Atomic<Payload, R> = Atomic::new(Owned::new(Payload::new(0xA, &drops_a)));
    let node_a = cell_a.load(Ordering::Relaxed);
    let mut guard_a: Guard<Payload, R> = ha.guard();
    assert!(guard_a.protect(&cell_a).is_some());
    cell_a.store(MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked; retired once, into the domain whose guard holds it.
    unsafe { ha.retire(node_a.get()) };

    // Domain B: churn hard — lots of retires, lots of flushes. None of
    // B's activity (epoch advances, stamp cycles, hazard scans) may free
    // A's node.
    const N: usize = 128;
    for i in 0..N {
        hb.retire_owned(Owned::<Payload, R>::new(Payload::new(i as u64, &drops_b)));
        if i % 8 == 0 {
            hb.flush();
        }
    }
    flush_until(&hb, || drops_b.load(Ordering::Relaxed) == N);
    assert_eq!(drops_b.load(Ordering::Relaxed), N, "{}: domain B must reclaim its own", R::NAME);
    assert_eq!(
        drops_a.load(Ordering::Relaxed),
        0,
        "{}: domain B's reclamation defeated domain A's guard",
        R::NAME
    );
    assert_eq!(guard_a.shared().expect("still guarded").read(), 0xA);

    // Release A's guard: now A (and only A) reclaims its node.
    drop(guard_a);
    flush_until(&ha, || drops_a.load(Ordering::Relaxed) == 1);
    assert_eq!(drops_a.load(Ordering::Relaxed), 1, "{}: domain A leaked after guard drop", R::NAME);
}

//! Shared scheme-conformance exercises: every [`Reclaimer`] must pass the
//! same battery. Used by the per-scheme unit tests and re-exported
//! (`#[doc(hidden)]`) for the integration suites under `rust/tests/`.
//!
//! Every exercise runs in its **own** [`DomainRef::new_owned`] domain:
//! tests never share retire lists, epochs, stamps or hazard registries, so
//! they neither race each other's reclamation decisions nor need a
//! serialization lock (the cross-talk the global-singleton design forced).

use super::{
    alloc_node, ConcurrentPtr, DomainRef, GuardPtr, LocalHandle, MarkedPtr, Reclaimer, Region,
};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Crate-wide test lock for the few tests that exercise the **global**
/// domain (the TLS convenience path); per-domain tests don't need it.
pub fn serial_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Poll `done` with flushes until it returns true or ~2 s elapse.
///
/// Flushes both `h` and the calling thread's *cached* handle for the same
/// domain: nodes retired through the TLS convenience path sit in the cached
/// handle's local retire list, which `h` alone cannot drain.
pub fn flush_until<R: Reclaimer>(h: &LocalHandle<R>, mut done: impl FnMut() -> bool) -> bool {
    let domain = h.domain_ref();
    for _ in 0..2000 {
        if done() {
            return true;
        }
        h.flush();
        domain.with_handle(|cached| cached.flush());
        std::thread::yield_now();
        std::thread::sleep(std::time::Duration::from_micros(500));
    }
    done()
}

/// Magic value a live payload must carry; `Drop` poisons it so a
/// use-after-reclaim is loudly detectable.
const MAGIC: u64 = 0xC0FF_EE00_DEAD_10CC;
const POISON: u64 = 0xBAAD_F00D_BAAD_F00D;

/// Drop-counting, self-poisoning payload.
pub struct Payload {
    magic: u64,
    value: u64,
    drops: Arc<AtomicUsize>,
}

impl Payload {
    pub fn new(value: u64, drops: &Arc<AtomicUsize>) -> Self {
        Self { magic: MAGIC, value, drops: drops.clone() }
    }

    /// Read the value, asserting the payload has not been reclaimed.
    pub fn read(&self) -> u64 {
        let m = self.magic;
        assert_eq!(m, MAGIC, "use-after-reclaim: magic={m:#x}");
        self.value
    }
}

impl Drop for Payload {
    fn drop(&mut self) {
        assert_eq!(self.magic, MAGIC, "double reclamation detected");
        self.magic = POISON;
        self.drops.fetch_add(1, Ordering::Relaxed);
    }
}

/// Retire a batch of nodes with no guards around; after flushing, all of
/// them must have been dropped exactly once.
pub fn exercise_basic_reclamation<R: Reclaimer>() {
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let drops = Arc::new(AtomicUsize::new(0));
    const N: usize = 64;
    for i in 0..N {
        let node = alloc_node::<Payload, R>(Payload::new(i as u64, &drops));
        // SAFETY: never published, so trivially unlinked; retired once.
        unsafe { h.retire(node) };
    }
    // Flush until everything is reclaimed (epoch schemes need a few
    // advances; guard-free, so progress is guaranteed).
    flush_until(&h, || drops.load(Ordering::Relaxed) == N);
    assert_eq!(drops.load(Ordering::Relaxed), N, "{} leaked retired nodes", R::NAME);
}

/// A guarded node must survive `retire` + aggressive flushing until the
/// guard is dropped.
pub fn exercise_guard_blocks_reclamation<R: Reclaimer>() {
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let drops = Arc::new(AtomicUsize::new(0));
    let node = alloc_node::<Payload, R>(Payload::new(7, &drops));
    let cell: ConcurrentPtr<Payload, R> = ConcurrentPtr::new(MarkedPtr::new(node, 0));

    let mut guard: GuardPtr<Payload, R> = h.guard();
    let p = guard.acquire(&cell);
    assert_eq!(p.get(), node);

    // Unlink, then retire while still guarded.
    cell.store(MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked above; retired exactly once.
    unsafe { h.retire(node) };

    // The reclaimer may try as hard as it wants — the guard must hold.
    // (Retirer == guard holder, the strictest single-thread case.)
    h.flush();
    assert_eq!(drops.load(Ordering::Relaxed), 0, "{}: reclaimed under a live guard", R::NAME);
    assert_eq!(guard.as_ref().unwrap().read(), 7);

    drop(guard);
    flush_until(&h, || drops.load(Ordering::Relaxed) == 1);
    assert_eq!(drops.load(Ordering::Relaxed), 1, "{}: leak after guard drop", R::NAME);
}

/// Guards created inside an explicit region must be protected and cheap;
/// the region must not leak protection after it ends.
pub fn exercise_region_guard<R: Reclaimer>() {
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let drops = Arc::new(AtomicUsize::new(0));
    let node = alloc_node::<Payload, R>(Payload::new(3, &drops));
    let cell: ConcurrentPtr<Payload, R> = ConcurrentPtr::new(MarkedPtr::new(node, 0));
    {
        let _region: Region<R> = Region::enter(&h);
        let mut g: GuardPtr<Payload, R> = h.guard();
        for _ in 0..100 {
            g.acquire(&cell);
            assert_eq!(g.as_ref().unwrap().read(), 3);
            g.reset();
        }
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked; retired once.
        unsafe { h.retire(node) };
    }
    flush_until(&h, || drops.load(Ordering::Relaxed) == 1);
    assert_eq!(drops.load(Ordering::Relaxed), 1, "{}: leak after region end", R::NAME);
}

/// Multi-threaded swap storm over one shared cell: all nodes funneled
/// through `retire` must be dropped exactly once, and no reader may observe
/// a poisoned payload. Each thread registers its own handle with the shared
/// domain — the TLS-free fast path.
pub fn exercise_concurrent_smoke<R: Reclaimer>(threads: usize, iters: usize) {
    let domain = DomainRef::<R>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));
    let allocated = Arc::new(AtomicUsize::new(0));
    let cell: Arc<ConcurrentPtr<Payload, R>> = Arc::new(ConcurrentPtr::null());

    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let domain = domain.clone();
            let drops = drops.clone();
            let allocated = allocated.clone();
            let cell = cell.clone();
            std::thread::spawn(move || {
                let h = domain.register();
                let mut g: GuardPtr<Payload, R> = h.guard();
                for i in 0..iters {
                    let value = (t * iters + i) as u64;
                    let node = alloc_node::<Payload, R>(Payload::new(value, &drops));
                    allocated.fetch_add(1, Ordering::Relaxed);
                    loop {
                        let old = g.acquire(&cell);
                        if !old.is_null() {
                            // Reading validates the guard: must not be
                            // poisoned.
                            unsafe { old.deref_data().read() };
                        }
                        if cell
                            .compare_exchange(
                                old,
                                MarkedPtr::new(node, 0),
                                Ordering::AcqRel,
                                Ordering::Acquire,
                            )
                            .is_ok()
                        {
                            g.reset();
                            if !old.is_null() {
                                // SAFETY: we unlinked `old` with the CAS;
                                // only the successful CASer retires it.
                                unsafe { h.retire(old.get()) };
                            }
                            break;
                        }
                        if i % 16 == 0 {
                            std::thread::yield_now();
                        }
                    }
                }
            })
        })
        .collect();
    for t in handles {
        t.join().unwrap();
    }

    let h = domain.register();
    // Retire the final occupant.
    let last = cell.load(Ordering::Acquire);
    if !last.is_null() {
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: all writers joined; we own the last node.
        unsafe { h.retire(last.get()) };
    }

    flush_until(&h, || drops.load(Ordering::Relaxed) == allocated.load(Ordering::Relaxed));
    assert_eq!(
        drops.load(Ordering::Relaxed),
        allocated.load(Ordering::Relaxed),
        "{}: drops != allocations after flush",
        R::NAME
    );
}

/// Two domains of the same scheme must be fully isolated: aggressive
/// retiring + flushing in one may never reclaim a node whose only
/// protection is a guard registered with the *other*.
pub fn exercise_domain_isolation<R: Reclaimer>() {
    let domain_a = DomainRef::<R>::new_owned();
    let domain_b = DomainRef::<R>::new_owned();
    let ha = domain_a.register();
    let hb = domain_b.register();

    let drops_a = Arc::new(AtomicUsize::new(0));
    let drops_b = Arc::new(AtomicUsize::new(0));

    // Domain A: guard a node, then retire it — protected by A only.
    let node_a = alloc_node::<Payload, R>(Payload::new(0xA, &drops_a));
    let cell_a: ConcurrentPtr<Payload, R> = ConcurrentPtr::new(MarkedPtr::new(node_a, 0));
    let mut guard_a: GuardPtr<Payload, R> = ha.guard();
    guard_a.acquire(&cell_a);
    cell_a.store(MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked; retired once, into the domain whose guard holds it.
    unsafe { ha.retire(node_a) };

    // Domain B: churn hard — lots of retires, lots of flushes. None of
    // B's activity (epoch advances, stamp cycles, hazard scans) may free
    // A's node.
    const N: usize = 128;
    for i in 0..N {
        let node = alloc_node::<Payload, R>(Payload::new(i as u64, &drops_b));
        // SAFETY: never published.
        unsafe { hb.retire(node) };
        if i % 8 == 0 {
            hb.flush();
        }
    }
    flush_until(&hb, || drops_b.load(Ordering::Relaxed) == N);
    assert_eq!(drops_b.load(Ordering::Relaxed), N, "{}: domain B must reclaim its own", R::NAME);
    assert_eq!(
        drops_a.load(Ordering::Relaxed),
        0,
        "{}: domain B's reclamation defeated domain A's guard",
        R::NAME
    );
    assert_eq!(guard_a.as_ref().unwrap().read(), 0xA);

    // Release A's guard: now A (and only A) reclaims its node.
    drop(guard_a);
    flush_until(&ha, || drops_a.load(Ordering::Relaxed) == 1);
    assert_eq!(drops_a.load(Ordering::Relaxed), 1, "{}: domain A leaked after guard drop", R::NAME);
}

//! DEBRA — distributed epoch-based reclamation (Brown 2015).
//!
//! Epoch protocol as in ER, but the advance cost is *distributed*: instead
//! of scanning all p threads at once, each thread checks a single other
//! thread per check opportunity ("DEBRA checks the next thread every 20
//! critical region entries", paper §4.2), advancing the epoch when a full
//! pass over the registry succeeds. This bounds the per-operation overhead
//! but — as the paper's efficiency analysis shows (App. A.2) — "with a
//! large number of threads this significantly delays the update of the
//! global epoch, resulting in poor reclamation efficiency".

use super::epoch_core::{epoch_reclaimer_impl, EpochConfig, EpochDomain};
use super::Domain;

/// DEBRA (Brown 2015).
pub struct Debra;

epoch_reclaimer_impl!(
    Debra,
    "DEBRA",
    EpochConfig {
        advance_every: u32::MAX, // unused under DEBRA policy
        debra_check_every: Some(20), // paper §4.2
        quiescent_at_exit: false,
    }
);

/// The global domain's epoch state (benchmark diagnostics / ablations).
pub fn domain() -> &'static EpochDomain {
    Domain::<Debra>::global().state()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;

    #[test]
    fn nodes_reclaimed_after_epoch_advances() {
        exercise_basic_reclamation::<Debra>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        exercise_guard_blocks_reclamation::<Debra>();
    }

    #[test]
    fn concurrent_smoke() {
        exercise_concurrent_smoke::<Debra>(4, 500);
    }
}

//! `concurrent_ptr` (paper §2): an atomic [`MarkedPtr`] — the "weak" shared
//! pointer living inside lock-free data structures. Only a guard
//! (facade [`Guard`], wrapping the internal `guard_ptr`) acquired *from*
//! a `ConcurrentPtr` protects the target from deletion.
//!
//! [`Guard`]: super::facade::Guard

use super::marked_ptr::MarkedPtr;
use super::Reclaimer;
use std::fmt;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Atomic marked pointer to a `Node<T, R>`.
pub struct ConcurrentPtr<T, R: Reclaimer> {
    raw: AtomicUsize,
    _phantom: PhantomData<MarkedPtr<T, R>>,
}

impl<T, R: Reclaimer> ConcurrentPtr<T, R> {
    /// A null pointer.
    pub const fn null() -> Self {
        Self { raw: AtomicUsize::new(0), _phantom: PhantomData }
    }

    /// Initialize with a value (typically while the node is still private).
    pub fn new(value: MarkedPtr<T, R>) -> Self {
        Self { raw: AtomicUsize::new(value.into_raw()), _phantom: PhantomData }
    }

    #[inline]
    pub fn load(&self, order: Ordering) -> MarkedPtr<T, R> {
        MarkedPtr::from_raw(self.raw.load(order))
    }

    #[inline]
    pub fn store(&self, value: MarkedPtr<T, R>, order: Ordering) {
        self.raw.store(value.into_raw(), order)
    }

    /// Single-word CAS; returns the witness value on failure.
    #[inline]
    pub fn compare_exchange(
        &self,
        expected: MarkedPtr<T, R>,
        desired: MarkedPtr<T, R>,
        success: Ordering,
        failure: Ordering,
    ) -> Result<(), MarkedPtr<T, R>> {
        self.raw
            .compare_exchange(expected.into_raw(), desired.into_raw(), success, failure)
            .map(|_| ())
            .map_err(MarkedPtr::from_raw)
    }

    /// Weak CAS variant for retry loops.
    #[inline]
    pub fn compare_exchange_weak(
        &self,
        expected: MarkedPtr<T, R>,
        desired: MarkedPtr<T, R>,
        success: Ordering,
        failure: Ordering,
    ) -> Result<(), MarkedPtr<T, R>> {
        self.raw
            .compare_exchange_weak(expected.into_raw(), desired.into_raw(), success, failure)
            .map(|_| ())
            .map_err(MarkedPtr::from_raw)
    }

    /// Atomically set mark bits (fetch_or on the low bits), returning the
    /// previous value. Used to set Harris delete marks.
    #[inline]
    pub fn fetch_mark(&self, mark: usize, order: Ordering) -> MarkedPtr<T, R> {
        MarkedPtr::from_raw(self.raw.fetch_or(mark, order))
    }
}

impl<T, R: Reclaimer> Default for ConcurrentPtr<T, R> {
    fn default() -> Self {
        Self::null()
    }
}

impl<T, R: Reclaimer> fmt::Debug for ConcurrentPtr<T, R> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ConcurrentPtr({:?})", self.load(Ordering::Relaxed))
    }
}

// SAFETY: a ConcurrentPtr is just an atomic word; the pointees' thread
// safety is governed by the reclamation protocol (T: Send + Sync is
// enforced where nodes are created and dereferenced).
unsafe impl<T: Send + Sync, R: Reclaimer> Send for ConcurrentPtr<T, R> {}
unsafe impl<T: Send + Sync, R: Reclaimer> Sync for ConcurrentPtr<T, R> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::leaky::Leaky;
    use crate::reclaim::{alloc_node, free_node};

    #[test]
    fn load_store_cas() {
        let n1 = alloc_node::<u64, Leaky>(1);
        let n2 = alloc_node::<u64, Leaky>(2);
        let c: ConcurrentPtr<u64, Leaky> = ConcurrentPtr::null();
        assert!(c.load(Ordering::Relaxed).is_null());

        let p1 = MarkedPtr::new(n1, 0);
        let p2 = MarkedPtr::new(n2, 0);
        c.store(p1, Ordering::Release);
        assert_eq!(c.load(Ordering::Acquire), p1);

        assert_eq!(
            c.compare_exchange(p2, p1, Ordering::AcqRel, Ordering::Acquire),
            Err(p1),
            "CAS with wrong expected must fail and return the witness"
        );
        assert!(c.compare_exchange(p1, p2, Ordering::AcqRel, Ordering::Acquire).is_ok());
        assert_eq!(c.load(Ordering::Acquire), p2);

        unsafe {
            free_node(n1);
            free_node(n2);
        }
    }

    #[test]
    fn fetch_mark_sets_delete_bit() {
        let n = alloc_node::<u64, Leaky>(5);
        let c = ConcurrentPtr::new(MarkedPtr::new(n, 0));
        let prev = c.fetch_mark(1, Ordering::AcqRel);
        assert_eq!(prev.mark(), 0);
        assert_eq!(c.load(Ordering::Relaxed).mark(), 1);
        assert_eq!(c.load(Ordering::Relaxed).get(), n);
        unsafe { free_node(n) };
    }
}

//! Hyaline — robust, snapshot-free reclamation with per-batch reference
//! counts (Nikolaev & Ravindran, arXiv 1905.07903), the ninth scheme of the
//! matrix and the first from the *robust* family: a stalled reader strands
//! only the batches it could actually hold, never the global retire stream.
//!
//! ## Protocol
//!
//! Retired nodes accumulate in a thread-local **batch** (a plain chain
//! through the retire header). Once the batch is large enough it is
//! **sealed**: a [`BatchCtl`] with a reference counter is allocated, every
//! node is pointed at it, and one node of the batch is CAS-pushed onto the
//! **slot list** of every active reader (`HySlot::head`). Readers *enlist*
//! by activating their slot at outermost region entry; at outermost exit
//! they detach their slot list and decrement each listed batch's counter —
//! whoever moves a counter to zero reclaims the whole batch. Reclamation
//! work is therefore proportional to the number of retired nodes (amortized
//! constant per retire), and no scheme-wide snapshot or epoch exists to get
//! stuck.
//!
//! ## Robustness (the Hyaline-1R era gate)
//!
//! Every node records a **birth era** from a global monotone clock
//! ([`ERA`], advanced every [`ERA_FREQ`] allocations by [`on_alloc`]).
//! Readers announce the era they entered at (`HySlot::era`), and `protect`
//! re-validates it: a pointer snapshot only succeeds if the global era did
//! not move past the announced value (otherwise the announce is refreshed
//! and the load retried). This yields the invariant *birth(n) ≤ announced
//! era of any slot that can hold n*: the node is published before it can be
//! loaded, and era reads are coherence-ordered along that chain. A sealing
//! retirer may therefore **skip** any active slot whose era is older than
//! the batch's minimum birth era — the stalled reader entered before any
//! node of the batch existed, so it cannot hold one. That is the bounded-
//! growth property E19 measures: a parked task holding a guard pins only
//! batches born before its announce, while fresh churn keeps reclaiming.
//!
//! ## Memory ordering
//!
//! * Enlist vs seal is the classic Dekker pairing: readers store
//!   `era`/`head` (Release) then `fence(SeqCst)` before loading shared
//!   pointers; a sealer fences SeqCst (after all batch nodes were unlinked)
//!   before scanning slots. If the scan misses a reader, the reader's
//!   subsequent loads see the unlinks and — with the ds-level validation
//!   every scheme here already requires for HP — cannot acquire a batch
//!   node.
//! * Slot push/pop: push is a CAS loop (pure push — no ABA), detach is an
//!   unconditional `swap` to [`INACTIVE`]; the AcqRel swap acquires every
//!   push's Release so the traversal sees each node's `slot_link`/`batch`.
//! * The batch counter starts at 0 and is published with
//!   `fetch_add(inserts, AcqRel)` *after* the pushes; leaving readers
//!   `fetch_sub(1, AcqRel)`. The sum of all updates is 0 and each landing
//!   is unique, so exactly one operation observes the counter reaching 0
//!   and frees the batch (the Arc-style AcqRel makes all prior departures
//!   visible to the freer).
//!
//! ## Deviations from the paper's presentation
//!
//! * The era clock is **process-global** (`on_alloc` has no domain access);
//!   it is a pure monotone clock, so sharing it cannot couple two domains'
//!   reclamation decisions — batches are only ever inserted into slots of
//!   the domain they were retired into.
//! * Batches under `max(HY_BATCH_MIN, active readers)` nodes are withheld
//!   (there are not enough nodes to link into every slot); `flush` and
//!   handle drop hand them over (seal attempt / orphan list), so nothing is
//!   stranded.

use std::sync::atomic::{fence, AtomicIsize, AtomicU64, AtomicUsize, Ordering};

use super::domain::LocalCell;
use super::registry::{ThreadEntry, ThreadList};
use super::retire::{prepare_retire, reclaim_one, GlobalRetireList, Retired};
use super::{Node, Reclaimer};

/// Hyaline (robust variant, per-batch refcounts + birth-era gate).
pub struct Hyaline;

/// Slot-list sentinel: the owning thread is outside any critical region.
/// Distinct from every real pointer (nodes are ≥ 8-byte aligned) and from
/// null (= active with an empty list).
const INACTIVE: usize = 1;

/// Minimum batch size before a seal is attempted on the retire path.
const HY_BATCH_MIN: usize = 8;

/// `protect_if_equal` era-revalidation attempts before giving up (the
/// interface requires bounded loops here; returning `false` is always safe
/// — the caller restarts its snapshot).
const PROTECT_RETRIES: usize = 16;

/// Process-global birth-era clock (see module docs: monotone, shared across
/// domains by necessity, never couples their reclamation decisions).
static ERA: AtomicU64 = AtomicU64::new(1);
/// Allocation tick; every [`ERA_FREQ`]-th allocation advances [`ERA`].
static ALLOC_TICK: AtomicU64 = AtomicU64::new(0);
/// Era advance frequency (power of two; amortizes the clock's contention).
const ERA_FREQ: u64 = 64;

/// Node header: retire metadata + batch links.
#[derive(Default)]
#[repr(C)]
pub struct HyHeader {
    retire: super::retire::RetireHeader,
    /// Global era at allocation time (the robustness gate's input).
    birth: AtomicU64,
    /// `*const BatchCtl` once the node's batch is sealed.
    batch: AtomicUsize,
    /// Next node in a reader slot's enlist list (`Retired`).
    slot_link: AtomicUsize,
}

impl super::retire::AsRetireHeader for HyHeader {
    fn retire_header(&self) -> &super::retire::RetireHeader {
        &self.retire
    }
}

/// Recover the full Hyaline header from a retire-header pointer.
///
/// # Safety
/// `r` must point at the `retire` field of a live [`HyHeader`] (all nodes
/// retired through this scheme do — `HyHeader` is `repr(C)` with the retire
/// header first).
#[inline]
unsafe fn hy<'a>(r: Retired) -> &'a HyHeader {
    &*(r as *const HyHeader)
}

/// Sealed-batch control block: the reference counter and the whole-batch
/// chain (linked through the retire header's `next`).
struct BatchCtl {
    /// Insertions minus departures; see the module's counter argument.
    nrefs: AtomicIsize,
    /// Head of the batch's node chain.
    first: Retired,
}

/// Per-guard state: whether this guard's first protect entered the region.
#[derive(Default)]
pub struct HyGuardToken {
    entered: bool,
}

/// Per-reader shared slot (one registry entry per registered thread).
pub struct HySlot {
    /// [`INACTIVE`], null (active, empty) or the newest enlisted node.
    head: AtomicUsize,
    /// The era this reader announced at entry / last protect validation.
    era: AtomicU64,
}

impl Default for HySlot {
    fn default() -> Self {
        Self { head: AtomicUsize::new(INACTIVE), era: AtomicU64::new(0) }
    }
}

/// Shared per-domain state.
pub struct HyDomain {
    slots: ThreadList<HySlot>,
    /// Unsealed batches of exited threads (chains via `next`, sublists via
    /// `next_list`); absorbed into the next seal attempt.
    orphans: GlobalRetireList,
}

impl HyDomain {
    pub const fn new() -> Self {
        Self { slots: ThreadList::new(), orphans: GlobalRetireList::new() }
    }

    /// Readers currently inside a critical region (diagnostics/tests).
    pub fn active_slots(&self) -> usize {
        self.slots
            .iter()
            .filter(|e| e.is_active() && e.data().head.load(Ordering::Acquire) != INACTIVE)
            .count()
    }

    /// Nodes parked on the orphan list (diagnostics).
    pub fn orphan_count(&self) -> usize {
        self.orphans.count()
    }
}

impl Default for HyDomain {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-thread state (cached by a [`crate::reclaim::LocalHandle`]).
pub struct HyLocal {
    entry: super::registry::EntryRef<HySlot>,
    /// Critical-region nesting depth.
    nesting: u32,
    /// The era value currently announced in our slot (valid while nested).
    era_cache: u64,
    /// Current unsealed batch: manual chain via the retire header's `next`.
    batch_head: Retired,
    batch_tail: Retired,
    batch_count: usize,
    batch_min_birth: u64,
    /// Active-reader estimate from the last registry scan: seals are only
    /// attempted once the batch can cover that many slots, keeping the
    /// retire path O(1) between scans.
    active_est: usize,
    /// Re-entrancy latch: user drops inside a seal's reclamation may retire
    /// again; nested seal attempts are skipped (bounded recursion).
    sealing: bool,
}

impl HyLocal {
    fn take_batch(&mut self) -> (Retired, usize, u64) {
        let out = (self.batch_head, self.batch_count, self.batch_min_birth);
        self.batch_head = std::ptr::null_mut();
        self.batch_tail = std::ptr::null_mut();
        self.batch_count = 0;
        self.batch_min_birth = u64::MAX;
        out
    }

    /// Append one retired node to the unsealed batch.
    fn push_node(&mut self, r: Retired, birth: u64) {
        // SAFETY: `r` is a detached retired node owned by this thread.
        unsafe {
            (*r).set_next_in_chain(std::ptr::null_mut());
            if self.batch_tail.is_null() {
                self.batch_head = r;
            } else {
                (*self.batch_tail).set_next_in_chain(r);
            }
        }
        self.batch_tail = r;
        self.batch_count += 1;
        self.batch_min_birth = self.batch_min_birth.min(birth);
    }

    /// Merge a detached chain back (seal abort; no user code ran since the
    /// take, but be defensive about nested appends anyway).
    fn put_back(&mut self, head: Retired, count: usize, min_birth: u64) {
        if head.is_null() {
            return;
        }
        let mut cur = head;
        let mut n = 0usize;
        loop {
            n += 1;
            // SAFETY: we own the detached chain.
            let next = unsafe { (*cur).next_in_chain() };
            if next.is_null() {
                break;
            }
            cur = next;
        }
        debug_assert_eq!(n, count);
        if self.batch_tail.is_null() {
            self.batch_head = head;
        } else {
            // SAFETY: both chains are exclusively ours.
            unsafe { (*self.batch_tail).set_next_in_chain(head) };
        }
        self.batch_tail = cur;
        self.batch_count += count;
        self.batch_min_birth = self.batch_min_birth.min(min_birth);
    }
}

/// Register the calling thread: acquire/recycle a reader slot.
pub fn register(domain: &HyDomain) -> HyLocal {
    let entry = domain.slots.acquire(HySlot::default, |s| {
        s.head.store(INACTIVE, Ordering::Relaxed);
        s.era.store(0, Ordering::Relaxed);
    });
    HyLocal {
        entry,
        nesting: 0,
        era_cache: 0,
        batch_head: std::ptr::null_mut(),
        batch_tail: std::ptr::null_mut(),
        batch_count: 0,
        batch_min_birth: u64::MAX,
        active_est: 0,
        sealing: false,
    }
}

/// Enter a critical region (enlist on outermost entry).
pub fn enter(_domain: &HyDomain, local: &LocalCell<HyLocal>) {
    local.with(|l| {
        l.nesting += 1;
        if l.nesting > 1 {
            return;
        }
        let e = ERA.load(Ordering::Acquire);
        l.era_cache = e;
        let slot = l.entry.data();
        // Era first, then activation: a sealer that acquires the head store
        // is guaranteed to read this era or a newer one.
        slot.era.store(e, Ordering::Relaxed);
        slot.head.store(0, Ordering::Release);
    });
    // Dekker: order the enlist stores before every subsequent shared-data
    // load; pairs with the sealer's pre-scan fence.
    fence(Ordering::SeqCst);
}

/// Leave a critical region; on outermost exit detach the slot list and
/// depart from every listed batch (may reclaim — runs user drops, so the
/// traversal happens after the borrow is released).
pub fn exit(_domain: &HyDomain, local: &LocalCell<HyLocal>) {
    let detached = local.with(|l| {
        debug_assert!(l.nesting > 0, "unbalanced region exit");
        l.nesting -= 1;
        if l.nesting > 0 {
            return 0;
        }
        // AcqRel: acquire every push's Release (the traversal below reads
        // slot_link/batch written before those pushes).
        l.entry.data().head.swap(INACTIVE, Ordering::AcqRel)
    });
    if detached != 0 && detached != INACTIVE {
        // SAFETY: the swap detached the chain exclusively to us; nodes stay
        // alive until their batch counter reaches zero (we hold one ref per
        // listed node by construction).
        unsafe { depart(detached as Retired) };
    }
}

/// Walk a detached slot list, decrementing each batch; free batches whose
/// counter reaches zero. Runs user drops — never call under a borrow.
unsafe fn depart(mut cur: Retired) {
    while !cur.is_null() {
        let h = hy(cur);
        // Read the link before the decrement: the decrement may free the
        // whole batch, including this node.
        let next = h.slot_link.load(Ordering::Relaxed) as Retired;
        let ctl = h.batch.load(Ordering::Acquire) as *mut BatchCtl;
        debug_assert!(!ctl.is_null(), "enlisted node without a sealed batch");
        if (*ctl).nrefs.fetch_sub(1, Ordering::AcqRel) == 1 {
            free_batch(ctl);
        }
        cur = next;
    }
}

/// Reclaim every node of a sealed batch and its control block.
///
/// # Safety
/// The batch counter reached zero: every inserted reference departed, so no
/// reader can hold any node of the batch.
unsafe fn free_batch(ctl: *mut BatchCtl) {
    let mut cur = (*ctl).first;
    drop(Box::from_raw(ctl));
    while !cur.is_null() {
        let next = (*cur).next_in_chain();
        reclaim_one(cur);
        cur = next;
    }
}

/// Retire a node into the local batch; attempt a seal once the batch is
/// plausibly large enough to cover every active reader.
///
/// # Safety
/// See [`Reclaimer::retire`].
pub unsafe fn retire<T: Send + Sync + 'static>(
    domain: &HyDomain,
    local: &LocalCell<HyLocal>,
    node: *mut Node<T, Hyaline>,
) {
    let birth = (*node).header().birth.load(Ordering::Relaxed);
    let r = prepare_retire::<T, Hyaline>(node, birth);
    let try_now = local.with(|l| {
        l.push_node(r, birth);
        l.batch_count >= HY_BATCH_MIN.max(l.active_est)
    });
    if try_now {
        try_seal(domain, local);
    }
}

/// Seal the local batch (absorbing orphans first): insert one node into
/// every active, era-eligible reader slot and publish the insert count.
/// Aborts (keeps accumulating) while the batch has fewer nodes than there
/// are slots to cover.
fn try_seal(domain: &HyDomain, local: &LocalCell<HyLocal>) {
    if local.with(|l| std::mem::replace(&mut l.sealing, true)) {
        return; // re-entered from a reclamation drop; the outer call covers it
    }
    absorb_orphans(domain, local);
    let (head, count, min_birth) = local.with(|l| l.take_batch());
    if head.is_null() {
        local.with(|l| l.sealing = false);
        return;
    }
    // Order the scan after the unlink/retire of every batch node; pairs
    // with the readers' enlist fences (module docs).
    fence(Ordering::SeqCst);
    let mut eligible: Vec<&ThreadEntry<HySlot>> = Vec::new();
    let mut active = 0usize;
    for e in domain.slots.iter() {
        if !e.is_active() || e.data().head.load(Ordering::Acquire) == INACTIVE {
            continue;
        }
        active += 1;
        // Robustness gate: a reader announced before any node of this batch
        // was born cannot hold one (birth ≤ announce invariant) — skip it,
        // so a stalled reader strands only pre-stall batches. The era load
        // is ordered after the head load (Acquire) and eras only grow, so a
        // stale-low reading is impossible for an active slot.
        if e.data().era.load(Ordering::Acquire) < min_birth {
            continue;
        }
        eligible.push(e);
    }
    local.with(|l| l.active_est = active);
    if count < eligible.len() {
        // Not enough nodes to link one into every slot yet.
        local.with(|l| {
            l.put_back(head, count, min_birth);
            l.sealing = false;
        });
        return;
    }
    let ctl = Box::into_raw(Box::new(BatchCtl { nrefs: AtomicIsize::new(0), first: head }));
    // Point every node at its control block before any of them becomes
    // visible; the publishing CAS below carries the Release.
    // SAFETY: the chain is still exclusively ours.
    unsafe {
        let mut cur = head;
        while !cur.is_null() {
            hy(cur).batch.store(ctl as usize, Ordering::Relaxed);
            cur = (*cur).next_in_chain();
        }
    }
    let mut inserts: isize = 0;
    let mut node = head;
    for e in &eligible {
        let slot = e.data();
        let mut cur_head = slot.head.load(Ordering::Acquire);
        loop {
            if cur_head == INACTIVE {
                break; // reader left between the scan and the push: skip
            }
            // SAFETY: `node` is non-null — inserts never exceed
            // `eligible.len() ≤ count` (checked above).
            unsafe { hy(node).slot_link.store(cur_head, Ordering::Relaxed) };
            match slot.head.compare_exchange_weak(
                cur_head,
                node as usize,
                Ordering::Release,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    inserts += 1;
                    // SAFETY: as above.
                    node = unsafe { (*node).next_in_chain() };
                    break;
                }
                Err(h) => cur_head = h,
            }
        }
    }
    local.with(|l| l.sealing = false);
    // Publish the insert count. If every inserted reference already
    // departed (or nobody was eligible), this observer frees the batch.
    // SAFETY: ctl is live until the counter reaches zero.
    unsafe {
        if (*ctl).nrefs.fetch_add(inserts, Ordering::AcqRel) == -inserts {
            free_batch(ctl);
        }
    }
}

/// Move orphaned (unsealed, from exited threads) nodes into our batch.
fn absorb_orphans(domain: &HyDomain, local: &LocalCell<HyLocal>) {
    let mut sublist = domain.orphans.steal_all();
    if sublist.is_null() {
        return;
    }
    local.with(|l| {
        while !sublist.is_null() {
            // SAFETY: steal_all handed us the chains exclusively.
            unsafe {
                let next_list = (*sublist).next_list();
                let mut cur = sublist;
                while !cur.is_null() {
                    let next = (*cur).next_in_chain();
                    l.push_node(cur, (*cur).stamp());
                    cur = next;
                }
                sublist = next_list;
            }
        }
    });
}

/// Bench/test hook: force a seal attempt so everything reclaimable (e.g.
/// with no active readers: the whole batch) is reclaimed now.
pub fn flush(domain: &HyDomain, local: &LocalCell<HyLocal>) {
    try_seal(domain, local);
}

/// Handle drop: orphan the unsealed batch and release the reader slot. The
/// slot is already [`INACTIVE`] (no live guards/regions on this handle).
pub fn unregister(domain: &HyDomain, local: &mut HyLocal) {
    debug_assert_eq!(local.nesting, 0, "handle dropped inside a critical region");
    debug_assert_eq!(
        local.entry.data().head.load(Ordering::Acquire),
        INACTIVE,
        "live slot list at unregister"
    );
    let (head, _count, _min) = local.take_batch();
    domain.orphans.push_sublist(head);
    domain.slots.release(&local.entry);
}

/// Domain teardown: only unsealed orphan chains can remain (sealed batches
/// free when their last reader departs, and no handles exist anymore).
pub fn drain(domain: &mut HyDomain) {
    // SAFETY: exclusive access — no handles, guards or regions exist.
    unsafe {
        domain.orphans.reclaim_where(|_| true);
    }
}

/// Era-validated pointer snapshot: succeeds only if the global era did not
/// move past our announce between the announce and the load, which is what
/// makes the birth ≤ announce invariant (module docs) hold.
fn protect_load<T: Send + Sync + 'static>(
    local: &LocalCell<HyLocal>,
    src: &super::ConcurrentPtr<T, Hyaline>,
) -> super::MarkedPtr<T, Hyaline> {
    let mut announced = local.with(|l| l.era_cache);
    loop {
        let p = src.load(Ordering::Acquire);
        let e = ERA.load(Ordering::Acquire);
        if e == announced {
            return p;
        }
        announce(local, e);
        announced = e;
    }
}

/// Refresh our slot's era announce and fence it before the retry load.
fn announce(local: &LocalCell<HyLocal>, e: u64) {
    local.with(|l| {
        l.era_cache = e;
        l.entry.data().era.store(e, Ordering::Release);
    });
    fence(Ordering::SeqCst);
}

// SAFETY: a node is reclaimed only when its batch counter reaches zero,
// i.e. after every reader slot the sealer inserted into has departed; the
// Dekker pairing plus the era-validated protect (module docs) guarantee the
// insertion set covers every reader that could hold a reference. Domains
// share nothing but the monotone era clock.
unsafe impl Reclaimer for Hyaline {
    const NAME: &'static str = "Hyaline";
    type Header = HyHeader;
    type GuardState = HyGuardToken;
    type DomainState = HyDomain;
    type LocalState = HyLocal;

    fn new_domain_state() -> Self::DomainState {
        HyDomain::new()
    }

    crate::reclaim::domain::impl_domain_statics!(Hyaline);

    fn register(domain: &Self::DomainState) -> Self::LocalState {
        register(domain)
    }

    fn unregister(domain: &Self::DomainState, local: &mut Self::LocalState) {
        unregister(domain, local)
    }

    fn enter_region(domain: &Self::DomainState, local: &LocalCell<Self::LocalState>) {
        enter(domain, local)
    }

    fn exit_region(domain: &Self::DomainState, local: &LocalCell<Self::LocalState>) {
        exit(domain, local)
    }

    #[inline]
    fn protect<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        src: &super::ConcurrentPtr<T, Self>,
    ) -> super::MarkedPtr<T, Self> {
        if !state.entered {
            state.entered = true;
            enter(domain, local);
        }
        protect_load(local, src)
    }

    #[inline]
    fn protect_if_equal<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
        src: &super::ConcurrentPtr<T, Self>,
        expected: super::MarkedPtr<T, Self>,
    ) -> bool {
        if !state.entered {
            state.entered = true;
            enter(domain, local);
        }
        let mut announced = local.with(|l| l.era_cache);
        for _ in 0..PROTECT_RETRIES {
            if src.load(Ordering::Acquire) != expected {
                return false;
            }
            let e = ERA.load(Ordering::Acquire);
            if e == announced {
                return true;
            }
            announce(local, e);
            announced = e;
        }
        false // era kept moving; safe to report a failed snapshot
    }

    #[inline]
    fn release<T: Send + Sync + 'static>(
        _domain: &Self::DomainState,
        _local: &LocalCell<Self::LocalState>,
        _state: &mut Self::GuardState,
        _ptr: super::MarkedPtr<T, Self>,
    ) {
        // Protection is region-scoped; the region is left on guard drop.
    }

    fn drop_guard_state(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        state: &mut Self::GuardState,
    ) {
        if state.entered {
            state.entered = false;
            exit(domain, local);
        }
    }

    unsafe fn on_alloc<T: Send + Sync + 'static>(node: *mut Node<T, Self>) {
        let tick = ALLOC_TICK.fetch_add(1, Ordering::Relaxed);
        if tick & (ERA_FREQ - 1) == 0 {
            ERA.fetch_add(1, Ordering::AcqRel);
        }
        // Relaxed suffices: the node's publication (Release CAS at the ds
        // layer) orders this store before any reader's access, and era
        // coherence along that chain gives birth ≤ any later validated
        // announce (module docs).
        (*node).header().birth.store(ERA.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    unsafe fn retire<T: Send + Sync + 'static>(
        domain: &Self::DomainState,
        local: &LocalCell<Self::LocalState>,
        node: *mut Node<T, Self>,
    ) {
        retire::<T>(domain, local, node)
    }

    fn flush(domain: &Self::DomainState, local: &LocalCell<Self::LocalState>) {
        flush(domain, local)
    }

    fn drain_domain(domain: &mut Self::DomainState) {
        drain(domain)
    }
}

/// The global domain's Hyaline state (diagnostics; per-instance state lives
/// in each [`crate::reclaim::Domain`]).
pub fn domain() -> &'static HyDomain {
    super::Domain::<Hyaline>::global().state()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::tests_common::*;
    use crate::reclaim::{Atomic, DomainRef, MarkedPtr, Owned};
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn nodes_reclaimed_in_batches() {
        exercise_basic_reclamation::<Hyaline>();
    }

    #[test]
    fn guard_blocks_reclamation() {
        exercise_guard_blocks_reclamation::<Hyaline>();
    }

    #[test]
    fn region_guard_blocks() {
        exercise_region_guard::<Hyaline>();
    }

    #[test]
    fn facade_roundtrip() {
        exercise_facade::<Hyaline>();
    }

    #[test]
    fn domain_isolation() {
        exercise_domain_isolation::<Hyaline>();
    }

    #[test]
    fn concurrent_smoke() {
        exercise_concurrent_smoke::<Hyaline>(4, 500);
    }

    /// Batch-refcount round trip on one slot: a guard-holding thread seals
    /// a batch into its *own* slot (counter 1); nothing reclaims until the
    /// guard drops, and the region exit alone (no flush) frees the batch.
    #[test]
    fn batch_refcount_round_trip() {
        let domain = DomainRef::<Hyaline>::new_owned();
        let h = domain.register();
        let drops = Arc::new(AtomicUsize::new(0));

        // Allocate everything *before* protecting: births are then ≤ the
        // guard's announced era no matter how far concurrent tests advance
        // the process-global clock, so the era gate must include our slot.
        let victims: Vec<_> = (0..(2 * HY_BATCH_MIN) as u64)
            .map(|i| Owned::<Payload, Hyaline>::new(Payload::new(i, &drops)))
            .collect();

        let cell: Atomic<Payload, Hyaline> = Atomic::new(Owned::new(Payload::new(0, &drops)));
        let mut g = h.guard();
        assert!(g.protect(&cell).is_some());
        assert_eq!(domain.domain().state().active_slots(), 1);

        // Enough retires to force a seal while our slot is the only active
        // reader: every batch lands in our own slot list.
        for v in victims {
            h.retire_owned(v);
        }
        h.flush();
        assert_eq!(drops.load(Ordering::Relaxed), 0, "guarded slot must hold every batch");

        // The departure at region exit is the only reclamation trigger.
        drop(g);
        assert_eq!(
            drops.load(Ordering::Relaxed),
            2 * HY_BATCH_MIN,
            "slot departure must free the batches it held"
        );

        // Cleanup: the protected node itself.
        let node = cell.load(Ordering::Acquire);
        assert!(!node.is_null());
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked above; retired exactly once.
        unsafe { h.retire(node.get()) };
        assert!(flush_until(&h, || drops.load(Ordering::Relaxed) == 2 * HY_BATCH_MIN + 1));
    }

    /// The robustness property: a reader stalled since before a batch's
    /// nodes were even *allocated* is skipped by the era gate, so fresh
    /// churn keeps reclaiming while the reader stays parked.
    #[test]
    fn stalled_reader_strands_only_its_batches() {
        let domain = DomainRef::<Hyaline>::new_owned();
        let drops = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let ready = Arc::new(std::sync::Barrier::new(2));

        // A node the stalled reader protects (born before its announce).
        let cell = Arc::new(Atomic::<Payload, Hyaline>::new(Owned::new(Payload::new(
            7, &drops,
        ))));

        let staller = {
            let domain = domain.clone();
            let cell = cell.clone();
            let stop = stop.clone();
            let ready = ready.clone();
            std::thread::spawn(move || {
                let h = domain.register();
                let mut g = h.guard();
                let p = g.protect(&cell).expect("protect the pre-stall node");
                ready.wait();
                while !stop.load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
                assert_eq!(p.read(), 7, "protected node must stay alive across the stall");
            })
        };
        ready.wait();

        // Advance the era clock well past the staller's announce (dropping
        // an unpublished Owned frees it directly — no retires, no orphans
        // to drag the churn batches' min_birth down), then churn: every
        // batch below has min_birth > the stalled announce.
        for _ in 0..(2 * ERA_FREQ) {
            drop(Owned::<u64, Hyaline>::new(0));
        }
        let h = domain.register();
        let churn = 4 * HY_BATCH_MIN as u64;
        let churn_drops = Arc::new(AtomicUsize::new(0));
        for i in 0..churn {
            h.retire_owned(Owned::<Payload, Hyaline>::new(Payload::new(i, &churn_drops)));
        }
        let ok = flush_until(&h, || churn_drops.load(Ordering::Relaxed) == churn as usize);
        assert!(
            ok,
            "era gate failed: stalled reader stranded fresh batches ({} of {churn} freed)",
            churn_drops.load(Ordering::Relaxed)
        );

        stop.store(true, Ordering::Release);
        staller.join().unwrap();
        // Cleanup: unlink + retire the protected node, now unguarded.
        let node = cell.load(Ordering::Acquire);
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked above; retired exactly once.
        unsafe { h.retire(node.get()) };
        assert!(flush_until(&h, || drops.load(Ordering::Relaxed) == 1));
    }

    /// Enlist/seal race stress: readers cycling short regions while
    /// retirers push batches into their slots concurrently.
    #[test]
    fn slot_enlist_retire_race_stress() {
        let domain = DomainRef::<Hyaline>::new_owned();
        let drops = Arc::new(AtomicUsize::new(0));
        let cell = Arc::new(Atomic::<u64, Hyaline>::new(Owned::new(1)));
        let total = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for t in 0..4 {
            let domain = domain.clone();
            let drops = drops.clone();
            let cell = cell.clone();
            let total = total.clone();
            handles.push(std::thread::spawn(move || {
                let h = domain.register();
                for i in 0..800u64 {
                    // Short-lived guard: constant enlist/depart churn racing
                    // the CAS pushes of other threads' seals.
                    let mut g = h.guard();
                    let _ = g.protect(&cell);
                    if i % 3 == t % 3 {
                        h.retire_owned(Owned::<Payload, Hyaline>::new(Payload::new(
                            i, &drops,
                        )));
                        total.fetch_add(1, Ordering::Relaxed);
                    }
                    drop(g);
                }
            }));
        }
        for th in handles {
            th.join().unwrap();
        }
        let h = domain.register();
        let ok = flush_until(&h, || drops.load(Ordering::Relaxed) == total.load(Ordering::Relaxed));
        assert!(
            ok,
            "race stress leaked: {} of {} dropped",
            drops.load(Ordering::Relaxed),
            total.load(Ordering::Relaxed)
        );
        // Cleanup the shared cell (all writers joined; sole owner now).
        let last = cell.load(Ordering::Acquire);
        cell.store(MarkedPtr::null(), Ordering::Release);
        // SAFETY: unlinked above; retired exactly once.
        unsafe { h.retire(last.get()) };
        h.flush();
    }
}

//! PJRT runtime: load the AOT-compiled JAX/Pallas computation
//! (`artifacts/model_b{B}.hlo.txt`, produced once by `make artifacts`) and
//! execute it from Rust. Python never runs here.
//!
//! The PJRT backend (the `xla` crate) is behind the **`pjrt`** cargo
//! feature, which is off by default so the crate builds std-only and fully
//! offline: enabling it requires adding the `xla` dependency to
//! `rust/Cargo.toml` (see the commented stanza there). Without the feature
//! every entry point reports "built without pjrt" and
//! [`artifacts_available`] returns false, so the coordinator tests and
//! examples skip gracefully instead of failing.
//!
//! With the feature: the `xla` crate's `PjRtClient` is `Rc`-based (not
//! `Send`), so the [`Engine`] lives on a single thread; [`EngineThread`]
//! wraps it behind an mpsc channel for the coordinator (which is exactly
//! one dispatch thread anyway — the batcher).

pub mod exec;

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};
use std::path::{Path, PathBuf};
use std::sync::mpsc;

/// Result dimension (f32 lanes) — matches `python/compile/model.py::DIM`;
/// 256 × 4 B = the paper's 1024-byte payload.
pub const DIM: usize = 256;

/// A single-threaded PJRT engine holding one compiled executable per batch
/// size.
#[cfg(feature = "pjrt")]
pub struct Engine {
    _client: xla::PjRtClient,
    execs: std::collections::BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load every `model_b*.hlo.txt` under `dir` and compile it on the CPU
    /// PJRT client.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut execs = std::collections::BTreeMap::new();
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("artifact dir {dir:?} (run `make artifacts`)"))?
        {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            let Some(batch) = name
                .strip_prefix("model_b")
                .and_then(|r| r.strip_suffix(".hlo.txt"))
                .and_then(|b| b.parse::<usize>().ok())
            else {
                continue;
            };
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
            execs.insert(batch, exe);
        }
        if execs.is_empty() {
            bail!("no model_b*.hlo.txt artifacts in {dir:?} — run `make artifacts`");
        }
        Ok(Self { _client: client, execs })
    }

    /// Compiled batch sizes, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.execs.keys().copied().collect()
    }

    /// The largest compiled batch size (the batcher's accumulation bound).
    pub fn max_batch(&self) -> usize {
        *self.execs.keys().next_back().unwrap()
    }

    /// The smallest compiled batch that fits `n` seeds (or the largest one
    /// if nothing fits — callers then split).
    pub fn pick_batch(&self, n: usize) -> usize {
        self.execs.keys().copied().find(|&b| b >= n).unwrap_or_else(|| self.max_batch())
    }

    /// Compute partial results for up to `max_batch()` seeds: pads to the
    /// chosen executable's batch, executes, strips padding. Returns one
    /// `DIM`-float vector per input seed.
    pub fn execute(&self, seeds: &[i32]) -> Result<Vec<Vec<f32>>> {
        if seeds.is_empty() {
            return Ok(Vec::new());
        }
        let mut out = Vec::with_capacity(seeds.len());
        for chunk in seeds.chunks(self.max_batch()) {
            let batch = self.pick_batch(chunk.len());
            let mut padded: Vec<i32> = chunk.to_vec();
            padded.resize(batch, chunk[chunk.len() - 1]); // pad by repetition
            let input = xla::Literal::vec1(&padded);
            let exe = &self.execs[&batch];
            let result = exe
                .execute::<xla::Literal>(&[input])
                .map_err(|e| anyhow!("execute b{batch}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            // Lowered with return_tuple=True → unwrap the 1-tuple.
            let tuple = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
            let flat: Vec<f32> = tuple.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            if flat.len() != batch * DIM {
                bail!("shape mismatch: got {} f32s, want {}", flat.len(), batch * DIM);
            }
            for row in flat.chunks(DIM).take(chunk.len()) {
                out.push(row.to_vec());
            }
        }
        Ok(out)
    }
}

/// Stub engine when built without the `pjrt` feature: loading always fails
/// with an explanatory error, so everything downstream skips.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    never: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    pub fn load(_dir: &Path) -> Result<Self> {
        bail!("emr was built without the `pjrt` feature — PJRT execution is unavailable")
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        match self.never {}
    }

    pub fn max_batch(&self) -> usize {
        match self.never {}
    }

    pub fn pick_batch(&self, _n: usize) -> usize {
        match self.never {}
    }

    pub fn execute(&self, _seeds: &[i32]) -> Result<Vec<Vec<f32>>> {
        match self.never {}
    }
}

/// A job for the engine thread.
struct Job {
    seeds: Vec<i32>,
    reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
}

/// `Send`-able handle to an [`Engine`] running on its own thread.
pub struct EngineThread {
    tx: Option<mpsc::Sender<Job>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl EngineThread {
    /// Spawn the engine thread and wait until the artifacts are compiled.
    pub fn spawn(dir: PathBuf) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Vec<usize>>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(e.batch_sizes()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    let _ = job.reply.send(engine.execute(&job.seeds));
                }
            })
            .map_err(|e| anyhow!("spawn engine thread: {e}"))?;
        let batches = ready_rx.recv().context("engine thread died during load")??;
        eprintln!("[engine] compiled batch sizes: {batches:?}");
        Ok(Self { tx: Some(tx), handle: Some(handle) })
    }

    /// Execute a batch synchronously (blocks the calling thread).
    pub fn execute(&self, seeds: Vec<i32>) -> Result<Vec<Vec<f32>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .unwrap()
            .send(Job { seeds, reply: reply_tx })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("engine thread dropped reply"))?
    }
}

impl Drop for EngineThread {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; thread exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Locate the artifacts directory: `$EMR_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("EMR_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|| "artifacts".into())
}

/// True when PJRT is compiled in **and** AOT artifacts exist (tests skip
/// gracefully otherwise).
pub fn artifacts_available() -> bool {
    if !cfg!(feature = "pjrt") {
        return false;
    }
    std::fs::read_dir(default_artifact_dir())
        .map(|mut d| {
            d.any(|e| {
                e.map(|e| e.file_name().to_string_lossy().ends_with(".hlo.txt")).unwrap_or(false)
            })
        })
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        if !artifacts_available() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        Some(Engine::load(&default_artifact_dir()).expect("engine load"))
    }

    #[test]
    fn loads_all_batch_variants() {
        let Some(e) = engine() else { return };
        let sizes = e.batch_sizes();
        assert!(sizes.contains(&1) && sizes.len() >= 2, "sizes={sizes:?}");
        assert_eq!(e.pick_batch(1), 1);
        assert_eq!(e.pick_batch(e.max_batch() + 1), e.max_batch());
    }

    #[test]
    fn execute_shapes_and_values() {
        let Some(e) = engine() else { return };
        let out = e.execute(&[1, 2, 3]).unwrap();
        assert_eq!(out.len(), 3);
        for row in &out {
            assert_eq!(row.len(), DIM);
            assert!(row.iter().all(|v| v.is_finite() && v.abs() <= 1.0), "tanh-bounded");
        }
        // Distinct seeds → distinct results.
        assert_ne!(out[0], out[1]);
    }

    #[test]
    fn execute_is_deterministic_and_batch_invariant() {
        let Some(e) = engine() else { return };
        let a = e.execute(&[7]).unwrap();
        let b = e.execute(&[7]).unwrap();
        assert_eq!(a, b, "deterministic");
        // The same seed through a larger (padded) batch must agree with
        // the b1 executable — cross-validates the two compiled variants.
        let big = e.execute(&[7, 8, 9, 10, 11]).unwrap();
        for (x, y) in a[0].iter().zip(&big[0]) {
            assert!((x - y).abs() < 1e-5, "batch-size variance: {x} vs {y}");
        }
    }

    #[test]
    fn engine_thread_roundtrip() {
        if !artifacts_available() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let et = EngineThread::spawn(default_artifact_dir()).unwrap();
        let out = et.execute(vec![5, 6]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), DIM);
    }

    #[test]
    fn empty_batch_is_ok() {
        let Some(e) = engine() else { return };
        assert!(e.execute(&[]).unwrap().is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_missing_feature() {
        let err = Engine::load(Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("pjrt"));
        assert!(!artifacts_available());
    }
}

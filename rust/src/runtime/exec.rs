//! A minimal, dependency-free, completion-driven **async executor**
//! (DESIGN.md §6). No tokio, no epoll: tasks are plain `Future`s parked on
//! [`std::task::Waker`]s, and progress is driven entirely by completions —
//! the coordinator's shard workers and batcher fulfil a completion slot and
//! wake the owning task, which re-enters the run queue of one of N executor
//! threads.
//!
//! Three pieces:
//!
//! * [`Executor`] — a fixed pool of executor threads sharing one FIFO run
//!   queue (`Mutex<VecDeque>` + `Condvar`). [`Executor::spawn`] boxes the
//!   future into a task; the task's `Arc` **is** its waker
//!   ([`std::task::Wake`]), so waking is one atomic flag flip plus a queue
//!   push — no timers, no I/O reactor. Thousands to hundreds of thousands
//!   of logical tasks multiplex onto the pool; a parked task costs only its
//!   heap allocation.
//! * [`Semaphore`] — an async counting semaphore (the mux's per-shard
//!   in-flight budget). FIFO wakeup with barging: a fresh `acquire` may
//!   take a permit ahead of parked waiters, but every notification is
//!   either consumed by a waiter taking a permit or explicitly forwarded,
//!   so no wakeup is ever lost.
//! * [`block_on`] / [`block_on_deadline`] — drive one future on the
//!   calling OS thread with a park/unpark waker. This is how the blocking
//!   request path wraps the async one (`Router::submit` over
//!   `Router::submit_async`).
//!
//! The executor is deliberately completion-only: the coordinator's request
//! path never sleeps in a task, it only awaits slots that shard workers
//! fulfil. Tasks that busy-poll would monopolize an executor thread — don't
//! write those.

use std::collections::{BTreeMap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll, Wake, Waker};
use std::time::{Duration, Instant};

type BoxFuture = Pin<Box<dyn Future<Output = ()> + Send + 'static>>;

/// One spawned task: the boxed future plus its run-queue bookkeeping. The
/// `Arc<Task>` doubles as the task's [`Waker`].
struct Task {
    /// `None` once the future completed (or panicked): late wakes become
    /// no-ops instead of polls of a dead future.
    future: Mutex<Option<BoxFuture>>,
    exec: Arc<ExecShared>,
    /// True while the task sits in the run queue (or is about to). Wakers
    /// flip `false → true` to enqueue; the executor thread flips it back
    /// *before* polling, so a wake arriving mid-poll re-enqueues. Both
    /// sides use `swap(AcqRel)`: the RMW chain makes the completion data
    /// written before a `wake()` visible to the poll that follows it.
    queued: AtomicBool,
}

impl Wake for Task {
    fn wake(self: Arc<Self>) {
        if !self.queued.swap(true, Ordering::AcqRel) {
            crate::trace::event!("exec.wake");
            let exec = self.exec.clone();
            exec.push(self);
        }
    }

    fn wake_by_ref(self: &Arc<Self>) {
        self.clone().wake();
    }
}

/// State shared by the executor threads and every task's waker.
struct ExecShared {
    run_queue: Mutex<VecDeque<Arc<Task>>>,
    available: Condvar,
    shutdown: AtomicBool,
    /// Every spawned task, weakly, plus the length at which the list next
    /// compacts (dead entries dropped; doubles each time, so registration
    /// stays amortized O(1)). `Executor::drop` cancels *parked* tasks
    /// through this: a task waiting on a [`Semaphore`] is a reference
    /// cycle (future → semaphore → waiter `Waker` → task → future) with no
    /// external fulfiller to break it, so shutdown must take its future
    /// explicitly or the task leaks and its join wedges.
    tasks: Mutex<(Vec<std::sync::Weak<Task>>, usize)>,
}

impl ExecShared {
    fn register(&self, task: &Arc<Task>) {
        let mut guard = self.tasks.lock().unwrap();
        let (tasks, compact_at) = &mut *guard;
        if tasks.len() >= *compact_at {
            tasks.retain(|w| w.strong_count() > 0);
            *compact_at = (tasks.len() * 2).max(64);
        }
        tasks.push(Arc::downgrade(task));
    }

    fn push(&self, task: Arc<Task>) {
        {
            let mut q = self.run_queue.lock().unwrap();
            // The flag is checked UNDER the queue lock (and stored under it
            // in `Executor::drop`), so a wake racing shutdown either lands
            // before the drop's post-join clear (drained there) or observes
            // the flag here. Checked outside the lock, a task could slip
            // into the queue after the clear and pin the `Task → ExecShared
            // → run_queue → Task` cycle alive forever, wedging its join.
            if self.shutdown.load(Ordering::Acquire) {
                // Stopping: drop the reference instead of parking it in a
                // queue nobody drains (its `Settle` guard reports `Gone`).
                return;
            }
            q.push_back(task);
        }
        self.available.notify_one();
    }
}

/// Result slot a [`JoinHandle`] waits on.
enum JoinState<T> {
    Pending,
    Done(T),
    /// The task died without producing a value: it panicked, or the
    /// executor shut down before it completed.
    Gone,
}

struct JoinInner<T> {
    state: Mutex<JoinState<T>>,
    done: Condvar,
}

/// Blocking handle to a spawned task's result.
pub struct JoinHandle<T> {
    inner: Arc<JoinInner<T>>,
}

impl<T> JoinHandle<T> {
    /// Wait for the task. `None` if it panicked or was cancelled by
    /// executor shutdown.
    pub fn join(self) -> Option<T> {
        let mut s = self.inner.state.lock().unwrap();
        while matches!(*s, JoinState::Pending) {
            s = self.inner.done.wait(s).unwrap();
        }
        match std::mem::replace(&mut *s, JoinState::Gone) {
            JoinState::Done(v) => Some(v),
            _ => None,
        }
    }

    /// Has the task produced a result (or died) yet?
    pub fn is_finished(&self) -> bool {
        !matches!(*self.inner.state.lock().unwrap(), JoinState::Pending)
    }
}

/// Delivers the task's output to its [`JoinHandle`] — and, because it is
/// held across the await, reports `Gone` when the task is dropped
/// mid-flight (cancellation, panic, executor shutdown).
struct Settle<T> {
    inner: Arc<JoinInner<T>>,
    delivered: bool,
}

impl<T> Settle<T> {
    fn deliver(&mut self, v: T) {
        *self.inner.state.lock().unwrap() = JoinState::Done(v);
        self.delivered = true;
        self.inner.done.notify_all();
    }
}

impl<T> Drop for Settle<T> {
    fn drop(&mut self) {
        if self.delivered {
            return;
        }
        let mut s = self.inner.state.lock().unwrap();
        if matches!(*s, JoinState::Pending) {
            *s = JoinState::Gone;
        }
        drop(s);
        self.inner.done.notify_all();
    }
}

/// A fixed pool of executor threads driving spawned tasks to completion.
///
/// Dropping the executor cancels tasks that are still pending: queued tasks
/// are dropped un-polled, parked tasks have their futures taken and dropped
/// (breaking even self-referential cycles like a semaphore waiter), and
/// every affected [`JoinHandle`] unblocks with `None`.
pub struct Executor {
    shared: Arc<ExecShared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn `threads` executor threads (min 1).
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(ExecShared {
            run_queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            tasks: Mutex::new((Vec::new(), 64)),
        });
        let threads = (0..threads.max(1))
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("emr-exec-{i}"))
                    .spawn(move || executor_thread(&shared))
                    .expect("spawn executor thread")
            })
            .collect();
        Self { shared, threads }
    }

    /// Number of executor threads in the pool.
    pub fn threads(&self) -> usize {
        self.threads.len()
    }

    /// Spawn a task; its output is collected through the returned
    /// [`JoinHandle`].
    pub fn spawn<F>(&self, fut: F) -> JoinHandle<F::Output>
    where
        F: Future + Send + 'static,
        F::Output: Send + 'static,
    {
        let inner = Arc::new(JoinInner {
            state: Mutex::new(JoinState::Pending),
            done: Condvar::new(),
        });
        let handle = JoinHandle { inner: inner.clone() };
        // The `Settle` guard is constructed HERE and moved into the async
        // block, so it exists from the moment the task does: a task dropped
        // before its first poll (executor shut down under load) still runs
        // `Settle::drop` — its captured state drops with the future — and
        // the join handle unblocks with `Gone` instead of waiting forever.
        let mut settle = Settle { inner, delivered: false };
        let task = Arc::new(Task {
            future: Mutex::new(Some(Box::pin(async move {
                let out = fut.await;
                settle.deliver(out);
            }))),
            exec: self.shared.clone(),
            // Born queued: the push below is the one initial enqueue.
            queued: AtomicBool::new(true),
        });
        self.shared.register(&task);
        self.shared.push(task);
        handle
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            // Store the flag and notify while HOLDING the queue lock: an
            // executor thread sitting between its shutdown check and its
            // `Condvar::wait` still holds the lock, so the store cannot
            // slip into that window and lose the only wakeup (which would
            // park the thread forever and deadlock the joins below).
            let _q = self.shared.run_queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.available.notify_all();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // Cancel what never ran: dropping the tasks drops their futures,
        // whose `Settle` guards flip the join handles to `Gone`. Taken out
        // of the queue first and dropped OUTSIDE the lock — a dropped
        // future may release a `Permit`, whose wake re-enters
        // `ExecShared::push` and its `run_queue.lock()`.
        let cancelled = std::mem::take(&mut *self.shared.run_queue.lock().unwrap());
        drop(cancelled);
        // Cancel what is PARKED: a task waiting on a semaphore (or any
        // waker nothing will ever fire) is kept alive by its own reference
        // cycle, so its future is taken — and dropped outside both locks —
        // explicitly. Threads are already joined: nobody else polls.
        let parked: Vec<Arc<Task>> = {
            let mut guard = self.shared.tasks.lock().unwrap();
            guard.0.drain(..).filter_map(|w| w.upgrade()).collect()
        };
        for task in parked {
            let fut = task.future.lock().unwrap().take();
            drop(fut);
        }
    }
}

fn executor_thread(shared: &ExecShared) {
    loop {
        let task = {
            let mut q = shared.run_queue.lock().unwrap();
            loop {
                // Shutdown first: pending entries are cancelled, not
                // drained — Executor::drop clears them after the join.
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        // Clear the queued marker before polling (see `Task::queued`).
        task.queued.swap(false, Ordering::AcqRel);
        crate::trace::event!("exec.poll");
        let waker = Waker::from(task.clone());
        let mut cx = Context::from_waker(&waker);
        let mut slot = task.future.lock().unwrap();
        if let Some(fut) = slot.as_mut() {
            // A panicking task must not take the executor thread (and every
            // task scheduled after it) down with it. Its `Settle` guard
            // reports `Gone` when the future is dropped below.
            // The guard-across-await lint runs inside the same unwind
            // boundary: its debug assertion downs the offending task only.
            let poll = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let guards_before = crate::reclaim::facade::lint::live_guards();
                let poll = fut.as_mut().poll(&mut cx);
                if matches!(poll, Poll::Pending) {
                    crate::reclaim::facade::lint::check_after_poll(guards_before);
                }
                poll
            }));
            match poll {
                Ok(Poll::Pending) => {}
                Ok(Poll::Ready(())) | Err(_) => *slot = None,
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Blocking bridge: drive one future on the calling OS thread.
// ---------------------------------------------------------------------------

struct ThreadWaker(std::thread::Thread);

impl Wake for ThreadWaker {
    fn wake(self: Arc<Self>) {
        self.0.unpark();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        self.0.unpark();
    }
}

/// Run `fut` to completion on the current thread (park/unpark waker).
pub fn block_on<F: Future>(fut: F) -> F::Output {
    let mut fut = std::pin::pin!(fut);
    let waker = Waker::from(Arc::new(ThreadWaker(std::thread::current())));
    let mut cx = Context::from_waker(&waker);
    loop {
        if let Poll::Ready(v) = fut.as_mut().poll(&mut cx) {
            return v;
        }
        std::thread::park();
    }
}

/// [`block_on`] with a deadline: `None` if the future is still pending when
/// the deadline passes (the future is dropped — i.e. cancelled — then).
pub fn block_on_deadline<F: Future>(fut: F, deadline: Instant) -> Option<F::Output> {
    let mut fut = std::pin::pin!(fut);
    let waker = Waker::from(Arc::new(ThreadWaker(std::thread::current())));
    let mut cx = Context::from_waker(&waker);
    loop {
        if let Poll::Ready(v) = fut.as_mut().poll(&mut cx) {
            return Some(v);
        }
        let now = Instant::now();
        if now >= deadline {
            return None;
        }
        // Spurious unparks (including a stale unpark credit from before
        // this call) only cost an extra poll.
        std::thread::park_timeout(deadline - now);
    }
}

// ---------------------------------------------------------------------------
// Async counting semaphore (the mux's per-shard in-flight budget).
// ---------------------------------------------------------------------------

struct SemState {
    permits: usize,
    next_id: u64,
    /// Live waiters by id. An id present here is waiting; removal means the
    /// waiter was either notified (by `release`) or gave up (future drop).
    /// Ids are allocated monotonically, so the map's key order IS FIFO
    /// arrival order — the eldest live waiter is simply the first entry.
    waiters: BTreeMap<u64, Waker>,
}

impl SemState {
    /// Pop the eldest live waiter, removing it from `waiters`. The caller
    /// wakes it *after* releasing the lock.
    fn next_waiter(&mut self) -> Option<Waker> {
        self.waiters.pop_first().map(|(_, w)| w)
    }
}

/// Async counting semaphore: [`Semaphore::acquire`] suspends the task until
/// a permit is free; dropping the [`Permit`] releases it. Clones share the
/// same permit pool.
#[derive(Clone)]
pub struct Semaphore {
    inner: Arc<SemInner>,
}

struct SemInner {
    state: Mutex<SemState>,
}

impl Semaphore {
    pub fn new(permits: usize) -> Self {
        Self {
            inner: Arc::new(SemInner {
                state: Mutex::new(SemState { permits, next_id: 0, waiters: BTreeMap::new() }),
            }),
        }
    }

    /// Await one permit.
    pub fn acquire(&self) -> Acquire {
        Acquire { sem: self.clone(), id: None, done: false }
    }

    /// Permits currently free (diagnostic; racy by nature).
    pub fn available(&self) -> usize {
        self.inner.state.lock().unwrap().permits
    }

    fn release(&self) {
        let woken = {
            let mut s = self.inner.state.lock().unwrap();
            s.permits += 1;
            s.next_waiter()
        };
        if let Some(w) = woken {
            w.wake();
        }
    }
}

/// Future returned by [`Semaphore::acquire`].
pub struct Acquire {
    sem: Semaphore,
    /// Waiter id once registered. `Some` with the id absent from `waiters`
    /// means we have been notified and hold an un-consumed notification.
    id: Option<u64>,
    done: bool,
}

impl Future for Acquire {
    type Output = Permit;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Permit> {
        let this = self.get_mut();
        let mut s = this.sem.inner.state.lock().unwrap();
        if s.permits > 0 {
            s.permits -= 1;
            if let Some(id) = this.id.take() {
                // Deregister; if we had already been notified the permit we
                // just took is the one the notification promised.
                s.waiters.remove(&id);
            }
            this.done = true;
            drop(s);
            return Poll::Ready(Permit { sem: this.sem.clone() });
        }
        let id = match this.id {
            Some(id) => id,
            None => {
                let id = s.next_id;
                s.next_id += 1;
                this.id = Some(id);
                id
            }
        };
        // (Re-)register: refresh the waker every poll (the task may have
        // been notified and lost the race, or migrated executor threads).
        // Re-registration under the original id keeps the original FIFO
        // position — a robbed waiter does not go to the back of the line.
        s.waiters.insert(id, cx.waker().clone());
        Poll::Pending
    }
}

impl Drop for Acquire {
    fn drop(&mut self) {
        if self.done {
            return;
        }
        let Some(id) = self.id else { return };
        let woken = {
            let mut s = self.sem.inner.state.lock().unwrap();
            if s.waiters.remove(&id).is_some() {
                // Still registered: plain withdrawal.
                None
            } else if s.permits > 0 {
                // We were notified but are abandoning the wait with the
                // promised permit still free: forward the notification so
                // it is not lost on a dead waiter.
                s.next_waiter()
            } else {
                // Notified, but another acquire barged in and took the
                // permit; its eventual release re-notifies.
                None
            }
        };
        if let Some(w) = woken {
            w.wake();
        }
    }
}

/// RAII permit; dropping it releases back to the [`Semaphore`].
pub struct Permit {
    sem: Semaphore,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.sem.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn spawn_and_join() {
        let exec = Executor::new(2);
        let h = exec.spawn(async { 6 * 7 });
        assert_eq!(h.join(), Some(42));
    }

    #[test]
    fn many_tasks_all_complete() {
        let exec = Executor::new(4);
        let handles: Vec<_> = (0..1000u64).map(|i| exec.spawn(async move { i })).collect();
        let sum: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(sum, 999 * 1000 / 2);
    }

    #[test]
    fn tasks_wake_across_threads() {
        // A task parked on a waker must resume when an outside thread
        // fulfils its completion — the coordinator handshake in miniature.
        struct Flag {
            set: Mutex<bool>,
            waker: Mutex<Option<Waker>>,
        }
        struct WaitFlag(Arc<Flag>);
        impl Future for WaitFlag {
            type Output = ();
            fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
                if *self.0.set.lock().unwrap() {
                    return Poll::Ready(());
                }
                *self.0.waker.lock().unwrap() = Some(cx.waker().clone());
                // Re-check: the flag may have been set between the first
                // look and the waker registration.
                if *self.0.set.lock().unwrap() {
                    return Poll::Ready(());
                }
                Poll::Pending
            }
        }
        let exec = Executor::new(1);
        let flag = Arc::new(Flag { set: Mutex::new(false), waker: Mutex::new(None) });
        let h = {
            let flag = flag.clone();
            exec.spawn(async move {
                WaitFlag(flag).await;
                "done"
            })
        };
        assert!(!h.is_finished());
        std::thread::sleep(Duration::from_millis(20));
        *flag.set.lock().unwrap() = true;
        if let Some(w) = flag.waker.lock().unwrap().take() {
            w.wake();
        }
        assert_eq!(h.join(), Some("done"));
    }

    #[test]
    fn panicking_task_reports_gone_and_spares_the_pool() {
        let exec = Executor::new(1);
        let bad = exec.spawn(async { panic!("task panic (expected in test)") });
        assert_eq!(bad.join(), None);
        // The single executor thread survived and still runs tasks.
        let ok = exec.spawn(async { 7 });
        assert_eq!(ok.join(), Some(7));
    }

    #[test]
    fn shutdown_cancels_pending_tasks() {
        let exec = Executor::new(1);
        // A task that never completes (its waker is dropped immediately).
        struct Never;
        impl Future for Never {
            type Output = ();
            fn poll(self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<()> {
                Poll::Pending
            }
        }
        let h = exec.spawn(async {
            Never.await;
            1
        });
        std::thread::sleep(Duration::from_millis(10));
        drop(exec);
        assert_eq!(h.join(), None, "shutdown must cancel, not wedge, the join");
    }

    #[test]
    fn semaphore_parked_task_cancelled_at_shutdown() {
        // A task parked on a semaphore with no releaser is a pure reference
        // cycle (future → semaphore → waker → task → future): executor
        // shutdown must take its future explicitly or the join wedges.
        let exec = Executor::new(1);
        let sem = Semaphore::new(0);
        let h = {
            let sem = sem.clone();
            exec.spawn(async move {
                let _permit = sem.acquire().await;
            })
        };
        std::thread::sleep(Duration::from_millis(20)); // let it park
        drop(exec);
        assert_eq!(h.join(), None, "semaphore-parked task must cancel at shutdown");
    }

    #[test]
    fn unpolled_task_cancelled_at_shutdown_unblocks_join() {
        // A task still sitting in the run queue when the executor drops is
        // dropped WITHOUT ever being polled — its join must report `Gone`,
        // not hang (the Settle guard exists from spawn, not first poll).
        let exec = Executor::new(1);
        let started = Arc::new(AtomicBool::new(false));
        let slow = {
            let started = started.clone();
            exec.spawn(async move {
                started.store(true, Ordering::Release);
                std::thread::sleep(Duration::from_millis(50));
            })
        };
        // Wait until the single executor thread is inside `slow`, so the
        // next spawn stays queued and is never polled.
        while !started.load(Ordering::Acquire) {
            std::thread::yield_now();
        }
        let starved = exec.spawn(async { 1 });
        drop(exec);
        assert_eq!(slow.join(), Some(()));
        assert_eq!(starved.join(), None, "un-polled task must cancel, not wedge its join");
    }

    #[test]
    fn block_on_and_deadline() {
        assert_eq!(block_on(async { 5 }), 5);
        struct Never;
        impl Future for Never {
            type Output = ();
            fn poll(self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<()> {
                Poll::Pending
            }
        }
        let t0 = Instant::now();
        let out = block_on_deadline(Never, Instant::now() + Duration::from_millis(30));
        assert!(out.is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25), "deadline must be honored");
    }

    #[test]
    fn semaphore_bounds_concurrency() {
        let exec = Executor::new(4);
        let sem = Semaphore::new(3);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..64)
            .map(|_| {
                let sem = sem.clone();
                let live = live.clone();
                let peak = peak.clone();
                exec.spawn(async move {
                    let _permit = sem.acquire().await;
                    let now = live.fetch_add(1, Ordering::AcqRel) + 1;
                    peak.fetch_max(now, Ordering::AcqRel);
                    // Hop through the run queue once while holding the
                    // permit so tasks genuinely overlap.
                    yield_once().await;
                    live.fetch_sub(1, Ordering::AcqRel);
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join(), Some(()));
        }
        assert!(peak.load(Ordering::Acquire) <= 3, "semaphore must bound concurrency");
        assert_eq!(sem.available(), 3, "all permits must return");
    }

    #[test]
    fn semaphore_dropped_waiter_forwards_notification() {
        // waiter A is notified, then dropped before re-polling; waiter B
        // must still get the permit (no lost wakeup).
        let sem = Semaphore::new(1);
        let gate = block_on(sem.acquire()); // take the only permit
        let mut a = Box::pin(sem.acquire());
        let mut b = Box::pin(sem.acquire());
        let waker = Waker::from(Arc::new(ThreadWaker(std::thread::current())));
        let mut cx = Context::from_waker(&waker);
        assert!(a.as_mut().poll(&mut cx).is_pending());
        assert!(b.as_mut().poll(&mut cx).is_pending());
        drop(gate); // notifies A
        drop(a); // A abandons with the permit still free → must forward to B
        match b.as_mut().poll(&mut cx) {
            Poll::Ready(_p) => {}
            Poll::Pending => panic!("B lost the forwarded notification"),
        }
    }

    /// Yield back to the executor once (re-queue and return).
    fn yield_once() -> impl Future<Output = ()> {
        struct Yield(bool);
        impl Future for Yield {
            type Output = ();
            fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
                if self.0 {
                    Poll::Ready(())
                } else {
                    self.0 = true;
                    cx.waker().wake_by_ref();
                    Poll::Pending
                }
            }
        }
        Yield(false)
    }
}

//! # emr — Efficient Memory Reclamation for lock-free data structures
//!
//! A from-scratch reproduction of *“Stamp-it: A more Thread-efficient,
//! Concurrent Memory Reclamation Scheme in the C++ Memory Model”*
//! (Pöter & Träff, 2018) as a three-layer Rust + JAX + Pallas stack.
//!
//! ## Architecture: a safe facade over reclamation domains
//!
//! User-facing code (the data structures, the coordinator, the benches)
//! is written against the **lifetime-branded facade**
//! ([`reclaim::facade`]): [`reclaim::Atomic`] link words,
//! [`reclaim::Guard`] reusable shields, [`reclaim::Shared`] protected
//! pointers branded by their guard's borrow (safe dereference — the brand
//! is the proof), [`reclaim::Owned`] unpublished nodes, and one generic
//! [`reclaim::HandleSource`] argument per operation
//! ([`reclaim::Cached`] | `&LocalHandle`) instead of duplicated
//! `op`/`op_with` method pairs. `unsafe` at data-structure level narrows
//! to the unlink-then-retire sites. The raw N3712 `guard_ptr` machinery
//! remains underneath as the crate-internal scheme-facing layer.
//!
//! The reclamation layer itself is organized as a two-level **instance
//! model** (no process-global scheme state):
//!
//! * [`reclaim::Domain`]`<R>` owns one complete instance of a scheme's
//!   shared state — Stamp-it's stamp pool and global retire-list, an epoch
//!   family's epoch counter + thread registry + orphan list, HP's hazard
//!   registry. `Domain::global()` is the process-wide default;
//!   `DomainRef::new_owned()` creates isolated domains (one per shard,
//!   test, or benchmark trial). Independent domains never exchange retired
//!   nodes, and an owned domain drains everything it still holds when its
//!   last reference drops.
//! * [`reclaim::LocalHandle`]`<R>` caches a thread's registration with one
//!   domain (registry entry, hazard slots, local retire list — the paper's
//!   `thread_control_block`). Guards ([`reclaim::Guard`]), regions
//!   ([`reclaim::Region`]) and retires created through a handle touch **no
//!   TLS and no `RefCell`** on the fast path; the [`reclaim::Cached`]
//!   handle source resolves a thread-cached handle once per call instead
//!   (one TLS lookup), evicting cached handles whose domain has otherwise
//!   died.
//!
//! The [`reclaim::Reclaimer`] trait is the scheme plug-point: every
//! operation takes `(&DomainState, &LocalCell<LocalState>)`, so schemes are
//! written against explicit state and the same code serves any number of
//! domains.
//!
//! ## Crate layout
//!
//! * [`reclaim`] — eight safe-memory-reclamation (SMR) schemes behind the
//!   [`reclaim::Reclaimer`] interface (the Rust rendering of the Robison
//!   N3712 proposal the paper builds on): Stamp-it (the paper's
//!   contribution), LFRC, hazard pointers, quiescent-state, epoch,
//!   new-epoch, DEBRA and Hyaline (the post-paper *robust* scheme —
//!   per-batch refcounts with a birth-era gate, so a stalled reader
//!   strands only the batches it could actually hold; DESIGN.md §11),
//!   plus a leaky baseline. The facade's guard-across-await lint
//!   ([`reclaim::facade::lint`]) catches guards leaked across executor
//!   `Pending` polls, the failure mode Hyaline is robust against.
//! * [`ds`] — the paper's benchmark data structures, generic over the
//!   reclaimer and bound to a domain: Michael–Scott queue, Harris–Michael
//!   list-based set, and a Michael-style hash-map with bounded FIFO
//!   eviction. Each operation takes one `impl HandleSource<R>` argument:
//!   [`reclaim::Cached`] or a registered `&LocalHandle`.
//! * [`alloc`] — a pluggable node allocator (system vs pooled) with
//!   allocation/reclamation counters, reproducing the paper's
//!   jemalloc-vs-libc axis.
//! * [`bench_fw`] — the benchmark harness regenerating every figure of the
//!   paper's evaluation (throughput sweeps, reclamation-efficiency time
//!   series, warm-up trials), one fresh domain per configuration.
//! * [`coordinator`] + [`runtime`] — a **sharded** compute-cache fleet
//!   that makes the paper's HashMap workload real: a
//!   [`coordinator::Router`] key-hashes requests onto N
//!   [`coordinator::Shard`]s (each its own worker pool + reclaimed
//!   hash-map + — by default — its own reclamation domain), partitioned
//!   into **engine groups** (DESIGN.md §9): each group's batcher thread
//!   dispatches its member shards' misses to an AOT-compiled
//!   JAX/Pallas computation via PJRT (behind the `pjrt` cargo feature) or
//!   to a deterministic synthetic backend (artifact-free; what benches
//!   and CI smokes run). Requests enter through the completion-driven
//!   **async front-end** ([`coordinator::frontend`] over the std-only
//!   executor in [`runtime::exec`]): `submit_async` parks a task on a
//!   per-request completion slot, `submit` is its deadline-bounded
//!   blocking wrapper, and the connection mux drives tens of thousands
//!   of logical clients on a handful of executor threads (E17). The
//!   serving claim also crosses a real socket:
//!   [`coordinator::frontend::net`] is a TCP front — a single readiness
//!   reactor (std-only `poll(2)` shim) frames a length-prefixed wire
//!   protocol and fulfils the same completion slots over thousands of
//!   concurrent loopback connections (E18).
//! * [`trace`] — an always-on, lock-free **flight recorder** (DESIGN.md
//!   §10): every seam above — shard submit/complete, batcher
//!   dispatch/return, retire→reclaim, magazine hit/miss, the net reactor,
//!   the executor — drops 16-byte events into per-thread ring buffers via
//!   [`trace::event!`](trace_event). Trace-off is a single relaxed-atomic
//!   branch (`--trace on|off|<cap>`); a chained panic hook snapshots the
//!   last 30 s of all rings to a self-describing dump (`repro trace view`
//!   decodes it), and [`trace::LatencyRecorder`] pairs submit/complete
//!   events into the real p50/p99/p999 cells the E16/E17/E18 figures
//!   report.
//! * [`util`] — std-only stand-ins for `rand`/`clap`/`criterion`/
//!   `proptest`/`anyhow`/`crossbeam_utils::CachePadded`.
//!
//! ## Quickstart
//!
//! The one-liner API (global domain, cached handles):
//!
//! ```
//! use emr::reclaim::{stamp::StampIt, Cached};
//! use emr::ds::queue::Queue;
//!
//! let q: Queue<u64, StampIt> = Queue::new();
//! q.enqueue(Cached, 1);
//! assert_eq!(q.dequeue(Cached), Some(1));
//! ```
//!
//! The isolated, TLS-free fast path (own domain + explicit handle):
//!
//! ```
//! use emr::reclaim::{stamp::StampIt, DomainRef, Region};
//! use emr::ds::queue::Queue;
//!
//! let q: Queue<u64, StampIt> = Queue::new_in(DomainRef::new_owned());
//! let handle = q.domain().register();
//! let _region = Region::enter(&handle); // amortized critical region
//! q.enqueue(&handle, 1);
//! assert_eq!(q.dequeue(&handle), Some(1));
//! ```
//!
//! Protected reads hand out [`reclaim::Shared`] pointers whose lifetime
//! is branded by the shield that protects them — escaping the shield is a
//! compile error (see `rust/tests/compile_fail.rs`):
//!
//! ```
//! use emr::reclaim::{stamp::StampIt, Atomic, DomainRef, Guard, Owned};
//!
//! let domain = DomainRef::<StampIt>::new_owned();
//! let handle = domain.register();
//! let cell: Atomic<String, StampIt> = Atomic::new(Owned::new("hi".into()));
//! let mut shield: Guard<String, StampIt> = handle.guard();
//! if let Some(s) = shield.protect(&cell) {
//!     assert_eq!(s.get(), "hi"); // safe deref: the brand is the proof
//! }
//! # // drain the owned domain cleanly
//! # let last = cell.load(std::sync::atomic::Ordering::Acquire);
//! # cell.store(emr::reclaim::MarkedPtr::null(), std::sync::atomic::Ordering::Release);
//! # shield.reset();
//! # unsafe { handle.retire(last.get()) };
//! ```

pub mod alloc;
pub mod bench_fw;
pub mod coordinator;
pub mod ds;
pub mod reclaim;
pub mod runtime;
pub mod trace;
pub mod util;

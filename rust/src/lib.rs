//! # emr — Efficient Memory Reclamation for lock-free data structures
//!
//! A from-scratch reproduction of *“Stamp-it: A more Thread-efficient,
//! Concurrent Memory Reclamation Scheme in the C++ Memory Model”*
//! (Pöter & Träff, 2018) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! * [`reclaim`] — seven safe-memory-reclamation (SMR) schemes behind one
//!   generic [`reclaim::Reclaimer`] interface (the Rust rendering of the
//!   Robison N3712 proposal the paper builds on): Stamp-it (the paper's
//!   contribution), LFRC, hazard pointers, quiescent-state, epoch, new-epoch
//!   and DEBRA, plus a leaky baseline.
//! * [`ds`] — the paper's benchmark data structures, generic over the
//!   reclaimer: Michael–Scott queue, Harris–Michael list-based set, and a
//!   Michael-style hash-map with bounded FIFO eviction.
//! * [`alloc`] — a pluggable node allocator (system vs pooled) with
//!   allocation/reclamation counters, reproducing the paper's
//!   jemalloc-vs-libc axis.
//! * [`bench_fw`] — the benchmark harness regenerating every figure of the
//!   paper's evaluation (throughput sweeps, reclamation-efficiency time
//!   series, warm-up trials).
//! * [`coordinator`] + [`runtime`] — a compute-cache server that makes the
//!   paper's HashMap workload real: worker threads serve batched compute
//!   requests through the reclaimed hash-map, dispatching misses to an
//!   AOT-compiled JAX/Pallas computation via PJRT.
//!
//! ## Quickstart
//!
//! (`no_run`: doctest executables don't inherit the xla_extension rpath;
//! `examples/quickstart.rs` runs the same code for real.)
//!
//! ```no_run
//! use emr::reclaim::stamp::StampIt;
//! use emr::ds::queue::Queue;
//!
//! let q: Queue<u64, StampIt> = Queue::new();
//! q.enqueue(1);
//! assert_eq!(q.dequeue(), Some(1));
//! ```

pub mod alloc;
pub mod bench_fw;
pub mod coordinator;
pub mod ds;
pub mod reclaim;
pub mod runtime;
pub mod util;

//! Label interning: event slots carry a `u16` id, not a string.
//!
//! The table only ever grows and only holds `&'static str`s — labels are
//! call-site literals (see the [`crate::trace_event!`] macro), so the
//! mutex here is touched once per *call site*, never per event:
//! [`LazyLabel`] caches the resolved id in a per-site atomic.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Mutex, OnceLock};

fn table() -> &'static Mutex<Vec<&'static str>> {
    static TABLE: OnceLock<Mutex<Vec<&'static str>>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(Vec::new()))
}

/// Intern `name`, returning its stable `u16` id. Idempotent. Panics if
/// a process somehow defines more than 65 535 distinct labels.
pub fn intern(name: &'static str) -> u16 {
    let mut t = table().lock().unwrap();
    if let Some(i) = t.iter().position(|&n| n == name) {
        return i as u16;
    }
    assert!(t.len() < u16::MAX as usize, "trace label table full");
    t.push(name);
    (t.len() - 1) as u16
}

/// The label string for an id (diagnostics; dumps embed their own table).
pub fn label_name(id: u16) -> Option<&'static str> {
    table().lock().unwrap().get(id as usize).copied()
}

/// Snapshot of the whole table, index = id (what dumps serialize).
pub fn label_table() -> Vec<&'static str> {
    table().lock().unwrap().clone()
}

/// A lazily interned label for one `event!` call site: resolves through
/// the intern table once, then serves the id from a relaxed atomic.
pub struct LazyLabel {
    name: &'static str,
    /// 0 = unresolved; otherwise `id + 1`.
    cached: AtomicU32,
}

impl LazyLabel {
    pub const fn new(name: &'static str) -> Self {
        Self { name, cached: AtomicU32::new(0) }
    }

    #[inline]
    pub fn id(&self) -> u16 {
        match self.cached.load(Ordering::Relaxed) {
            0 => self.resolve(),
            c => (c - 1) as u16,
        }
    }

    #[cold]
    fn resolve(&self) -> u16 {
        let id = intern(self.name);
        self.cached.store(id as u32 + 1, Ordering::Relaxed);
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_idempotent() {
        let a = intern("test.intern.alpha");
        let b = intern("test.intern.beta");
        assert_ne!(a, b);
        assert_eq!(intern("test.intern.alpha"), a);
        assert_eq!(label_name(a), Some("test.intern.alpha"));
        assert!(label_table().len() as u32 > a.max(b) as u32);
    }

    #[test]
    fn lazy_label_caches() {
        static L: LazyLabel = LazyLabel::new("test.intern.lazy");
        let first = L.id();
        assert_eq!(L.id(), first);
        assert_eq!(label_name(first), Some("test.intern.lazy"));
    }
}

//! Per-thread event rings: single-producer seqlock slots, overwrite-oldest,
//! drained on demand by any thread through a cursor ([`Drainer`]).
//!
//! Each thread that emits gets one fixed-size, power-of-two [`Ring`],
//! registered process-wide so drains and crash snapshots can walk every
//! ring without the owners' cooperation. A push is wait-free and touches
//! only the owner's cache lines:
//!
//! ```text
//! seq[slot] = 2·pos+1      (relaxed)   "writing"
//! release fence                         readers that see the data see the odd seq
//! ts/payload stores         (relaxed)
//! seq[slot] = 2·pos+2      (release)   "published at position pos"
//! head      = pos+1        (release)
//! ```
//!
//! Readers run the classic C++11 seqlock validation (Boehm): load `seq`
//! (acquire) — relaxed data loads — acquire fence — reload `seq`; accept
//! only if both reads equal `2·pos+2`. Every field is an atomic, so a
//! lost race is a *discarded* slot, never a torn or UB read. Because the
//! sequence encodes the absolute position (not just a generation bit), a
//! reader can never confuse lap `k`'s slot with lap `k+1`'s.
//!
//! Rings are never unregistered: a dead thread's final events stay
//! drainable (exactly what a flight recorder wants), and the registry's
//! `Arc`s bound ring memory by the historical thread count.

use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Default per-thread ring capacity in events (must be a power of two).
/// 16 Ki events × 24 B/slot = 384 KiB per emitting thread.
pub const DEFAULT_RING_CAP: usize = 1 << 14;

/// Per-thread ring capacity used for rings created from now on.
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_RING_CAP);

/// Set the capacity (in events) for subsequently created rings; rounded
/// up to a power of two, floor 8 (the `--trace <cap>` knob).
pub fn set_capacity(cap: usize) {
    CAPACITY.store(cap.max(8).next_power_of_two(), Ordering::Relaxed);
}

struct Slot {
    /// 0 = never written; `2·pos+1` = being written at `pos`;
    /// `2·pos+2` = holds the event pushed at position `pos`.
    seq: AtomicU64,
    ts: AtomicU64,
    /// `label << 48 | arg` (16 label bits spare for future schema use).
    payload: AtomicU64,
}

/// One thread's event ring. Produced into only by its owning thread;
/// drained by anyone.
pub struct Ring {
    id: u32,
    mask: u64,
    /// Next write position (monotonic; slot index is `head & mask`).
    head: AtomicU64,
    slots: Box<[Slot]>,
}

impl Ring {
    fn new(id: u32, cap: usize) -> Self {
        let cap = cap.max(8).next_power_of_two();
        let slots = (0..cap)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                ts: AtomicU64::new(0),
                payload: AtomicU64::new(0),
            })
            .collect();
        Self { id, mask: (cap - 1) as u64, head: AtomicU64::new(0), slots }
    }

    fn capacity(&self) -> u64 {
        self.mask + 1
    }

    /// Owner-only push (single producer; enforced by TLS access).
    #[inline]
    fn push(&self, ts: u64, label: u16, arg: u32) {
        let pos = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(pos & self.mask) as usize];
        slot.seq.store(pos.wrapping_mul(2).wrapping_add(1), Ordering::Relaxed);
        // Readers that observe the data stores below must also observe
        // the odd ("writing") sequence above.
        fence(Ordering::Release);
        slot.ts.store(ts, Ordering::Relaxed);
        slot.payload.store((label as u64) << 48 | arg as u64, Ordering::Relaxed);
        slot.seq.store(pos.wrapping_mul(2).wrapping_add(2), Ordering::Release);
        self.head.store(pos.wrapping_add(1), Ordering::Release);
    }

    /// Seqlock read of the slot written at absolute position `pos`.
    /// `None` when the slot has been overwritten (or is mid-write).
    fn read(&self, pos: u64) -> Option<RawEvent> {
        let want = pos.wrapping_mul(2).wrapping_add(2);
        let slot = &self.slots[(pos & self.mask) as usize];
        let s1 = slot.seq.load(Ordering::Acquire);
        if s1 != want {
            return None;
        }
        let ts = slot.ts.load(Ordering::Relaxed);
        let payload = slot.payload.load(Ordering::Relaxed);
        // Order the data loads above before the validating reload below.
        fence(Ordering::Acquire);
        if slot.seq.load(Ordering::Relaxed) != want {
            return None;
        }
        Some(RawEvent {
            ts,
            label: (payload >> 48) as u16,
            tid: self.id as u16,
            arg: payload as u32,
        })
    }
}

/// One decoded event as stored in a ring (label still an interned id).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct RawEvent {
    pub ts: u64,
    pub label: u16,
    /// Ring (≈ thread) id, truncated to 16 bits for the dump format.
    pub tid: u16,
    pub arg: u32,
}

fn registry() -> &'static Mutex<Vec<Arc<Ring>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

#[cold]
fn new_ring() -> Arc<Ring> {
    let mut reg = registry().lock().unwrap();
    let ring = Arc::new(Ring::new(reg.len() as u32, CAPACITY.load(Ordering::Relaxed)));
    reg.push(ring.clone());
    ring
}

thread_local! {
    static RING: std::cell::OnceCell<Arc<Ring>> = const { std::cell::OnceCell::new() };
}

/// Push one event into the calling thread's ring (creating and
/// registering it on first use). Events emitted during TLS teardown are
/// silently dropped — a flight recorder must never abort a dying thread.
#[inline]
pub(crate) fn push(ts: u64, label: u16, arg: u32) {
    let _ = RING.try_with(|cell| cell.get_or_init(new_ring).push(ts, label, arg));
}

/// Aggregate ring counters (see [`crate::trace::stats`]).
pub(crate) fn stats() -> crate::trace::TraceStats {
    let reg = registry().lock().unwrap();
    crate::trace::TraceStats {
        rings: reg.len() as u64,
        recorded: reg.iter().map(|r| r.head.load(Ordering::Relaxed)).sum(),
    }
}

/// Result of one [`Drainer::drain`] pass.
#[derive(Debug, Default)]
pub struct Drained {
    /// Events new since the previous pass, grouped by ring, ascending
    /// position within each ring — **not** globally timestamp-sorted.
    pub events: Vec<RawEvent>,
    /// Events that were overwritten (ring lapped the cursor) or torn by
    /// a concurrent overwrite before this pass could read them.
    pub lost: u64,
}

/// An incremental consumer over all rings: remembers, per ring, the next
/// position to read, so periodic drains see every event exactly once
/// (minus overwrites, which are counted in [`Drained::lost`]).
#[derive(Default)]
pub struct Drainer {
    /// `cursors[ring.id]` = next unread position in that ring.
    cursors: Vec<u64>,
}

impl Drainer {
    pub fn new() -> Self {
        Self::default()
    }

    /// A drainer whose cursors start at the **current** head of every
    /// existing ring: subsequent drains see only events emitted after
    /// this call (per-trial isolation for the bench recorder).
    pub fn from_now() -> Self {
        let mut d = Self::default();
        let rings: Vec<Arc<Ring>> = registry().lock().unwrap().clone();
        for ring in &rings {
            let id = ring.id as usize;
            if d.cursors.len() <= id {
                d.cursors.resize(id + 1, 0);
            }
            d.cursors[id] = ring.head.load(Ordering::Acquire);
        }
        d
    }

    /// Harvest everything new since the last pass.
    pub fn drain(&mut self) -> Drained {
        // Snapshot the registry under the lock, read rings outside it:
        // draining must never block emitters (they don't take the lock)
        // or other drainers for longer than the Vec clone.
        let rings: Vec<Arc<Ring>> = registry().lock().unwrap().clone();
        let mut out = Drained::default();
        for ring in &rings {
            let id = ring.id as usize;
            if self.cursors.len() <= id {
                self.cursors.resize(id + 1, 0);
            }
            let head = ring.head.load(Ordering::Acquire);
            let cursor = self.cursors[id];
            // Oldest position that can still be resident. Anything
            // between the cursor and it was overwritten unread.
            let lo = head.saturating_sub(ring.capacity()).max(cursor);
            out.lost += lo - cursor;
            for pos in lo..head {
                match ring.read(pos) {
                    Some(ev) => out.events.push(ev),
                    None => out.lost += 1,
                }
            }
            self.cursors[id] = head;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_and_reads_back() {
        let ring = Ring::new(9999, 8);
        for i in 0..20u64 {
            ring.push(i, 1, i as u32);
        }
        // Positions 0..12 are overwritten; 12..20 resident.
        assert!(ring.read(0).is_none());
        assert!(ring.read(11).is_none());
        for pos in 12..20 {
            let ev = ring.read(pos).expect("resident slot");
            assert_eq!(ev.ts, pos);
            assert_eq!(ev.arg, pos as u32);
            assert_eq!(ev.label, 1);
        }
        assert!(ring.read(20).is_none(), "unwritten position");
    }

    #[test]
    fn payload_packs_label_and_arg() {
        let ring = Ring::new(4242, 8);
        ring.push(7, 0xABCD, 0xDEAD_BEEF);
        let ev = ring.read(0).unwrap();
        assert_eq!(ev.label, 0xABCD);
        assert_eq!(ev.arg, 0xDEAD_BEEF);
        assert_eq!(ev.tid, 4242 & 0xFFFF);
    }

    #[test]
    fn drainer_sees_each_event_once() {
        // Emit through the real TLS path so the global registry is used.
        crate::trace::set_enabled(true);
        let mut d = Drainer::from_now();
        let label = crate::trace::intern("test.drain_once");
        for i in 0..100u32 {
            crate::trace::emit(label, i);
        }
        let first = d.drain();
        let mine: Vec<u32> =
            first.events.iter().filter(|e| e.label == label).map(|e| e.arg).collect();
        assert_eq!(mine, (0..100).collect::<Vec<_>>());
        let second = d.drain();
        assert!(second.events.iter().all(|e| e.label != label), "no event seen twice");
    }
}

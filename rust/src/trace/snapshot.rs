//! Crash snapshots: serialize every ring — merged, timestamp-sorted —
//! to a self-describing binary dump, and decode such dumps back
//! (`repro trace view`).
//!
//! ## Dump format (version 1, little-endian)
//!
//! ```text
//! magic    8  b"EMRTRC1\n"
//! labels   u32 count, then per label: u32 byte-length + UTF-8 bytes
//! events   u64 count, then per event (16 B):
//!          u64 ts_ns | u16 label | u16 tid | u32 arg
//! ```
//!
//! The label table is embedded so a dump is readable by any build — ids
//! are file-local, not process-local.
//!
//! ## The panic hook
//!
//! [`install_panic_hook`] snapshots the last
//! [`DEFAULT_CRASH_WINDOW_NS`] of trace into
//! `<dir>/trace-crash-<pid>.bin` whenever any thread panics. It
//! **chains**: the previously installed hook (default backtrace printer
//! or a user's) runs first, then the snapshot is written — and a second
//! install is a no-op, so layered init paths can all call it safely.

use std::io::{self, Read as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};

use super::ring::{Drainer, RawEvent};

const MAGIC: &[u8; 8] = b"EMRTRC1\n";

/// How much history the panic hook keeps: the last 30 s of events.
pub const DEFAULT_CRASH_WINDOW_NS: u64 = 30_000_000_000;

/// What a snapshot wrote (event count after windowing, and how many
/// resident events were lost to concurrent overwrites mid-read).
#[derive(Debug)]
pub struct SnapshotInfo {
    pub events: u64,
    pub lost: u64,
}

/// Drain all rings and write a dump to `path`. `window_ns` keeps only
/// events within that distance of the newest event's timestamp
/// (`None` = everything still resident).
pub fn write_snapshot(path: &Path, window_ns: Option<u64>) -> io::Result<SnapshotInfo> {
    let drained = Drainer::new().drain();
    let mut events = drained.events;
    events.sort_by_key(|e| e.ts);
    if let (Some(w), Some(last)) = (window_ns, events.last().map(|e| e.ts)) {
        let cut = last.saturating_sub(w);
        events.retain(|e| e.ts >= cut);
    }

    let labels = super::intern::label_table();
    let mut buf: Vec<u8> = Vec::with_capacity(64 + labels.len() * 24 + events.len() * 16);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(labels.len() as u32).to_le_bytes());
    for l in &labels {
        buf.extend_from_slice(&(l.len() as u32).to_le_bytes());
        buf.extend_from_slice(l.as_bytes());
    }
    buf.extend_from_slice(&(events.len() as u64).to_le_bytes());
    for e in &events {
        buf.extend_from_slice(&e.ts.to_le_bytes());
        buf.extend_from_slice(&e.label.to_le_bytes());
        buf.extend_from_slice(&e.tid.to_le_bytes());
        buf.extend_from_slice(&e.arg.to_le_bytes());
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&buf)?;
    f.sync_all()?;
    Ok(SnapshotInfo { events: events.len() as u64, lost: drained.lost })
}

/// A decoded dump: the embedded label table plus timestamp-sorted events.
#[derive(Debug)]
pub struct Dump {
    pub labels: Vec<String>,
    pub events: Vec<RawEvent>,
}

impl Dump {
    /// The label string for an event (falls back to the numeric id for
    /// dumps written by a different build).
    pub fn label(&self, e: &RawEvent) -> String {
        self.labels
            .get(e.label as usize)
            .cloned()
            .unwrap_or_else(|| format!("label#{}", e.label))
    }

    /// One line per event: `ts_ns  label  tid  arg`.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            out.push_str(&format!("{:>16} {:<24} tid={:<5} arg={}\n", e.ts, self.label(e), e.tid, e.arg));
        }
        out
    }

    /// The dump as a JSON object (labels resolved inline).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"events\": [\n");
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "    {{\"ts_ns\": {}, \"label\": \"{}\", \"tid\": {}, \"arg\": {}}}",
                e.ts,
                self.label(e).escape_default(),
                e.tid,
                e.arg
            ));
        }
        out.push_str("\n  ]\n}\n");
        out
    }
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("trace dump: {msg}"))
}

/// Read and validate a dump written by [`write_snapshot`].
pub fn read_dump(path: &Path) -> io::Result<Dump> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    let mut at = 0usize;
    let mut take = |n: usize| -> io::Result<&[u8]> {
        let s = bytes.get(at..at + n).ok_or_else(|| bad("truncated"))?;
        at += n;
        Ok(s)
    };
    if take(8)? != MAGIC {
        return Err(bad("bad magic (not an EMRTRC1 dump)"));
    }
    let label_count = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
    let mut labels = Vec::with_capacity(label_count.min(u16::MAX as usize));
    for _ in 0..label_count {
        let len = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let s = std::str::from_utf8(take(len)?).map_err(|_| bad("label not UTF-8"))?;
        labels.push(s.to_string());
    }
    let event_count = u64::from_le_bytes(take(8)?.try_into().unwrap());
    let mut events = Vec::with_capacity(event_count.min(1 << 24) as usize);
    for _ in 0..event_count {
        let rec = take(16)?;
        events.push(RawEvent {
            ts: u64::from_le_bytes(rec[0..8].try_into().unwrap()),
            label: u16::from_le_bytes(rec[8..10].try_into().unwrap()),
            tid: u16::from_le_bytes(rec[10..12].try_into().unwrap()),
            arg: u32::from_le_bytes(rec[12..16].try_into().unwrap()),
        });
    }
    if at != bytes.len() {
        return Err(bad("trailing bytes after event section"));
    }
    Ok(Dump { labels, events })
}

static HOOK_INSTALLED: AtomicBool = AtomicBool::new(false);

/// The path the panic hook writes for this process under `dir`.
pub fn crash_dump_path(dir: &Path) -> PathBuf {
    dir.join(format!("trace-crash-{}.bin", std::process::id()))
}

/// Install the crash-snapshot panic hook, writing dumps into `dir`.
/// Returns `false` (and does nothing) if already installed — double
/// installation must not stack snapshot-writers or drop the chained
/// hook. The previously installed hook always runs first.
pub fn install_panic_hook(dir: impl Into<PathBuf>) -> bool {
    if HOOK_INSTALLED.swap(true, Ordering::SeqCst) {
        return false;
    }
    let dir: PathBuf = dir.into();
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        prev(info);
        let path = crash_dump_path(&dir);
        match write_snapshot(&path, Some(DEFAULT_CRASH_WINDOW_NS)) {
            Ok(i) => eprintln!(
                "trace: crash snapshot ({} events{}) written to {}",
                i.events,
                if i.lost > 0 { ", some lost to overwrite" } else { "" },
                path.display()
            ),
            Err(e) => eprintln!("trace: crash snapshot failed: {e}"),
        }
    }));
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dump_round_trips() {
        crate::trace::set_enabled(true);
        let label = crate::trace::intern("test.snapshot.rt");
        for i in 0..50u32 {
            crate::trace::emit(label, i);
        }
        let dir = std::env::temp_dir();
        let path = dir.join(format!("emr-trace-rt-{}.bin", std::process::id()));
        let info = write_snapshot(&path, None).unwrap();
        assert!(info.events >= 50);
        let dump = read_dump(&path).unwrap();
        assert!(dump.events.windows(2).all(|w| w[0].ts <= w[1].ts), "timestamp-sorted");
        let mine: Vec<u32> = dump
            .events
            .iter()
            .filter(|e| dump.label(e) == "test.snapshot.rt")
            .map(|e| e.arg)
            .collect();
        assert_eq!(mine, (0..50).collect::<Vec<_>>());
        assert!(!dump.to_text().is_empty());
        assert!(dump.to_json().contains("\"events\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn read_rejects_garbage() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("emr-trace-garbage-{}.bin", std::process::id()));
        std::fs::write(&path, b"not a dump at all").unwrap();
        assert!(read_dump(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }
}

//! Always-on, lock-free flight recorder (DESIGN.md §10).
//!
//! Every layer of the serving stack — shard submit/complete, batcher
//! dispatch/return, the retire→reclaim funnel (including the `smr.stall`
//! high-water-mark event a domain emits when its pending-retire count
//! crosses the configurable stall watermark; DESIGN.md §11), magazine
//! hit/miss, the net reactor, the executor (including the facade's
//! `lint.guard_await` guard-across-await violations) — drops compact
//! binary events into per-thread ring buffers via
//! [`event!`](crate::trace::event):
//!
//! ```text
//! event = { ts: u64 monotonic ns, label: u16 interned, tid: u16, arg: u32 }
//! ```
//!
//! The design goals, in priority order:
//!
//! 1. **Trace-off is a branch.** [`enabled`] is one relaxed atomic load;
//!    when it is false the [`event!`] macro does nothing else. The
//!    recorder can therefore stay compiled into every hot path
//!    (retire/reclaim fire per node) and still be an honest ablation
//!    axis (`--trace on|off|<cap>`; the E13 trace-overhead CI gate pins
//!    on ≤ 1.05× off).
//! 2. **Writers never coordinate.** Each thread owns one fixed-size,
//!    power-of-two ring ([`ring`]) and is its only producer: a push is
//!    two relaxed seqlock stores around three relaxed field stores —
//!    no CAS, no sharing, overwrite-oldest when full.
//! 3. **Drain-on-demand, not stream.** Nothing reads the rings in
//!    steady state. A [`ring::Drainer`] (cursor per ring) harvests new
//!    events when *asked* — by the bench framework's
//!    [`recorder::LatencyRecorder`] every few milliseconds, or by the
//!    crash hook exactly once. Torn slots (overwritten mid-read) are
//!    detected by the per-slot sequence and counted, never surfaced.
//! 4. **Survive the crash.** [`snapshot::install_panic_hook`] chains to
//!    the previously installed hook and writes the last
//!    [`snapshot::DEFAULT_CRASH_WINDOW_NS`] of all rings — merged and
//!    timestamp-sorted — to a self-describing binary dump that
//!    `repro trace view` decodes offline.
//!
//! Labels are interned once per call site ([`LazyLabel`] inside the
//! macro expansion), so steady-state emission never touches the intern
//! table.

pub mod intern;
pub mod recorder;
pub mod ring;
pub mod snapshot;

pub use intern::{intern, label_name, LazyLabel};
pub use recorder::{LatencyRecorder, LatencySummary, RecorderThread};
pub use ring::{Drained, Drainer, RawEvent, DEFAULT_RING_CAP};
pub use snapshot::{install_panic_hook, read_dump, write_snapshot, Dump, SnapshotInfo};

/// Re-export of the [`trace_event!`](crate::trace_event) macro as
/// `trace::event!` — the spelling instrumentation sites use.
pub use crate::trace_event as event;

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

/// The always-on default: recording is enabled unless `--trace off`.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Is the recorder on? One relaxed load — this is the *entire* trace-off
/// cost at every instrumentation site (the `event!` macro checks it
/// before touching anything else).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on or off (the `--trace` knob; also the E13 overhead
/// gate's toggle). Existing ring contents are kept.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Apply a `--trace on|off|<cap>` knob value parsed to a capacity:
/// `0` disables recording; anything else enables it and sets the
/// per-thread ring capacity (rounded up to a power of two) used by
/// rings created *after* this call.
pub fn apply_knob(cap: usize) {
    if cap == 0 {
        set_enabled(false);
    } else {
        ring::set_capacity(cap);
        set_enabled(true);
    }
}

/// Parse a `--trace on|off|<cap>` CLI value into the capacity encoding
/// `apply_knob` takes (`0` = off).
pub fn parse_knob(s: &str) -> Option<usize> {
    match s {
        "on" | "true" => Some(DEFAULT_RING_CAP),
        "off" | "false" => Some(0),
        n => match n.parse::<usize>() {
            Ok(0) | Err(_) => None,
            Ok(c) => Some(c),
        },
    }
}

/// Emit one event into the calling thread's ring. Callers go through
/// [`event!`] (which performs the [`enabled`] check and label interning);
/// this function unconditionally records.
#[inline]
pub fn emit(label: u16, arg: u32) {
    ring::push(crate::util::monotonic_ns(), label, arg);
}

/// Correlation ids pairing `shard.submit` with `shard.complete` events
/// (the [`recorder::LatencyRecorder`] join key). Wrapping is fine: by the
/// time an id recurs, its predecessor has long been drained or
/// overwritten.
static NEXT_REQUEST_ID: AtomicU32 = AtomicU32::new(1);

/// A fresh request correlation id. Call only under [`enabled`] — the
/// fetch-add is the one shared-write this module ever does on a hot
/// path, and trace-off must stay a pure branch.
#[inline]
pub fn next_request_id() -> u32 {
    NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed)
}

/// Aggregate recorder counters (surfaced once per fleet via
/// `MetricsSnapshot::set_trace_stats`, like the magazine/net stats).
#[derive(Copy, Clone, Debug, Default)]
pub struct TraceStats {
    /// Per-thread rings ever created (threads that emitted ≥ 1 event).
    pub rings: u64,
    /// Events ever recorded, summed over rings (monotonic; includes
    /// events since overwritten).
    pub recorded: u64,
}

/// Process-wide recorder counters.
pub fn stats() -> TraceStats {
    ring::stats()
}

/// Record one event at an instrumentation seam:
/// `trace::event!("shard.submit", id)` (or argless, arg = 0).
///
/// Expansion order is the whole contract: first the [`enabled()`] branch
/// (one relaxed load — all of trace-off), then the per-call-site
/// [`LazyLabel`] resolves its interned id (one relaxed load after the
/// first hit), then [`emit`] timestamps and pushes. The label must be a
/// string literal — interning is keyed on call sites, not dynamic data.
#[macro_export]
macro_rules! trace_event {
    ($name:literal, $arg:expr) => {{
        if $crate::trace::enabled() {
            static __TRACE_LBL: $crate::trace::LazyLabel = $crate::trace::LazyLabel::new($name);
            $crate::trace::emit(__TRACE_LBL.id(), $arg as u32);
        }
    }};
    ($name:literal) => {
        $crate::trace_event!($name, 0u32)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_parses() {
        assert_eq!(parse_knob("on"), Some(DEFAULT_RING_CAP));
        assert_eq!(parse_knob("off"), Some(0));
        assert_eq!(parse_knob("4096"), Some(4096));
        assert_eq!(parse_knob("0"), None);
        assert_eq!(parse_knob("bogus"), None);
    }

    #[test]
    fn request_ids_advance() {
        let a = next_request_id();
        let b = next_request_id();
        assert_ne!(a, b);
    }

    #[test]
    fn macro_emits_under_enabled() {
        // The default is enabled; other tests in this binary only ever
        // turn it back on, so this cannot race to a false failure.
        set_enabled(true);
        let before = stats().recorded;
        crate::trace::event!("test.macro_emits", 7);
        crate::trace::event!("test.macro_emits");
        let after = stats().recorded;
        assert!(after >= before + 2);
    }
}

//! Drain-based latency capture: pair `shard.submit` / `shard.complete`
//! events by correlation id and histogram the timestamp deltas.
//!
//! This is how the E16/E17/E18 figures get p50/p99/p999 cells without
//! keeping (or sorting) a per-request latency vector: the recorder
//! periodically drains the rings, joins submit/complete pairs on the
//! `arg` correlation id ([`crate::trace::next_request_id`]), and feeds a
//! [`LogHistogram`] — O(1) per request, mergeable, ~6% relative error.
//!
//! Ring drains from different threads are not mutually ordered, so a
//! completion can be harvested before its submission; unmatched events
//! park in a side map until the partner arrives. Events lost to ring
//! overwrite surface as `lost`/`unpaired` in the summary instead of
//! silently skewing the distribution.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::ring::Drainer;
use crate::util::stats::LogHistogram;

/// Label a request's entry into the shard funnel carries.
pub const SUBMIT_LABEL: &str = "shard.submit";
/// Label emitted when a request's response is fulfilled.
pub const COMPLETE_LABEL: &str = "shard.complete";

/// Pairs submit/complete trace events into a latency histogram.
pub struct LatencyRecorder {
    drainer: Drainer,
    submit: u16,
    complete: u16,
    /// submit ts by correlation id, waiting for its completion.
    pending: HashMap<u32, u64>,
    /// complete ts by correlation id, harvested before its submit.
    orphans: HashMap<u32, u64>,
    hist: LogHistogram,
    lost: u64,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    /// A recorder that sees only events emitted after this call.
    pub fn new() -> Self {
        Self {
            drainer: Drainer::from_now(),
            submit: super::intern(SUBMIT_LABEL),
            complete: super::intern(COMPLETE_LABEL),
            pending: HashMap::new(),
            orphans: HashMap::new(),
            hist: LogHistogram::new(),
            lost: 0,
        }
    }

    /// Harvest new events and pair what can be paired. Call often enough
    /// that rings do not lap between polls (every few ms at bench rates).
    pub fn poll(&mut self) {
        let drained = self.drainer.drain();
        self.lost += drained.lost;
        for ev in &drained.events {
            if ev.label == self.submit {
                match self.orphans.remove(&ev.arg) {
                    Some(complete_ts) => {
                        self.hist.record(complete_ts.saturating_sub(ev.ts));
                    }
                    None => {
                        self.pending.insert(ev.arg, ev.ts);
                    }
                }
            } else if ev.label == self.complete {
                match self.pending.remove(&ev.arg) {
                    Some(submit_ts) => {
                        self.hist.record(ev.ts.saturating_sub(submit_ts));
                    }
                    None => {
                        self.orphans.insert(ev.arg, ev.ts);
                    }
                }
            }
        }
    }

    /// Final poll, then fold into a summary.
    pub fn finish(mut self) -> LatencySummary {
        self.poll();
        LatencySummary {
            p50_ns: self.hist.percentile(50.0),
            p99_ns: self.hist.percentile(99.0),
            p999_ns: self.hist.percentile(99.9),
            max_ns: self.hist.max(),
            pairs: self.hist.count(),
            unpaired: self.pending.len() as u64 + self.orphans.len() as u64,
            lost: self.lost,
            hist: self.hist,
        }
    }

    /// Run a recorder on a background thread, polling every `period`.
    pub fn spawn(period: Duration) -> RecorderThread {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("emr-trace-rec".into())
            .spawn(move || {
                let mut rec = LatencyRecorder::new();
                while !stop2.load(Ordering::Acquire) {
                    std::thread::sleep(period);
                    rec.poll();
                }
                rec
            })
            .expect("spawn trace recorder thread");
        RecorderThread { stop, handle }
    }
}

/// Handle to a background [`LatencyRecorder`].
pub struct RecorderThread {
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<LatencyRecorder>,
}

impl RecorderThread {
    /// Stop polling, run one final drain, and summarize.
    pub fn stop(self) -> LatencySummary {
        self.stop.store(true, Ordering::Release);
        match self.handle.join() {
            Ok(rec) => rec.finish(),
            Err(_) => LatencySummary::default(),
        }
    }
}

/// Trace-derived latency distribution for one bench cell.
#[derive(Debug, Default)]
pub struct LatencySummary {
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub p999_ns: u64,
    pub max_ns: u64,
    /// Submit/complete pairs that produced a sample.
    pub pairs: u64,
    /// Events whose partner never arrived (lost to overwrite, or still
    /// in flight at finish).
    pub unpaired: u64,
    /// Ring slots overwritten or torn before they could be drained.
    pub lost: u64,
    pub hist: LogHistogram,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairs_submit_complete_across_order() {
        crate::trace::set_enabled(true);
        let mut rec = LatencyRecorder::new();
        let submit = crate::trace::intern(SUBMIT_LABEL);
        let complete = crate::trace::intern(COMPLETE_LABEL);
        // Ten requests, 1000 ns apart; completion events deliberately
        // emitted before their submit events to exercise the orphan map
        // (cross-ring drain order is arbitrary in production).
        for _ in 0..10 {
            let id = crate::trace::next_request_id();
            crate::trace::emit(complete, id);
            crate::trace::emit(submit, id);
        }
        rec.poll();
        let s = rec.finish();
        assert_eq!(s.pairs, 10);
        assert_eq!(s.unpaired, 0);
        // Same-thread emit order means complete-ts ≤ submit-ts here; the
        // recorder saturates to 0 rather than wrapping.
        assert!(s.p99_ns < 1_000_000_000);
    }
}

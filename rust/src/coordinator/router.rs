//! The **router**: key-hash front-end over N [`Shard`]s, partitioned into
//! **engine groups** (DESIGN.md §9).
//!
//! * `submit(key)` routes by [`shard_for_key`] — a pure function of the key
//!   and the shard count, so the same key lands on the same shard across
//!   restarts and processes.
//! * Shards are partitioned into [`ServerConfig::groups`] engine groups by
//!   [`group_for_shard`] (pure, so key→shard→group is restart-stable too).
//!   Each group owns its own miss channel plus a **batcher + engine
//!   thread**: `PjRtClient` is not `Send`, so each group's engine is
//!   created *on* that group's batcher thread — engine-per-group is the
//!   unit of compute parallelism, and misses never cross a group boundary.
//!   Results are inserted back through a per-shard registered handle.
//! * With `shards = 1, groups = 1` the router is exactly the old single
//!   `CacheServer`: one domain, one worker pool, one queue, one batcher —
//!   the same loop the pre-group fleet ran.
//! * Domain modes: **domain-per-shard** (default — shards never share
//!   retire lists, epochs or hazard registries; reclamation overhead stays
//!   per-shard-thread-count) vs **shared-domain**
//!   ([`ServerConfig::shared_domain`] — one fleet-wide domain, the
//!   single-domain baseline the Stamp-it comparison study assumes). The
//!   `shard_scaling` bench measures the two against each other.

use super::frontend::{SubmitFuture, SubmitHandle};
use super::metrics::{GroupMetrics, GroupSnapshot, MetricsSnapshot};
use super::shard::{Miss, Request, Shard, ShardShared};
use super::{Backend, Payload, Response, ServerConfig};
use crate::reclaim::{DomainRef, LocalHandle, Reclaimer};
use crate::runtime::{Engine, DIM};
use crate::util::error::{Context, Result};
use crate::util::monotonic_ns;
use crate::util::rng::mix64;
use std::collections::HashMap as StdHashMap;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// Deterministic key→shard routing: a pure function of `(key, shards)`,
/// stable across restarts and processes. `shards = 1` always maps to 0.
pub fn shard_for_key(key: u32, shards: usize) -> usize {
    debug_assert!(shards > 0);
    if shards == 1 {
        return 0;
    }
    // mix64 avalanches the key over the full word; use the top half so the
    // low-bit structure of small keys cannot skew the modulo.
    ((mix64(key as u64) >> 32) as usize) % shards
}

/// Deterministic shard→group assignment: round-robin (`shard % groups`), a
/// pure function stable across restarts — so the whole key→shard→group path
/// is. Round-robin (rather than contiguous ranges) keeps group populations
/// within one shard of each other for any `(shards, groups)` pair.
pub fn group_for_shard(shard: usize, groups: usize) -> usize {
    debug_assert!(groups > 0);
    shard % groups
}

/// The group count the router actually runs: at least 1, at most the shard
/// count (a group without shards would just idle an engine thread).
pub fn effective_groups(shards: usize, groups: usize) -> usize {
    groups.max(1).min(shards.max(1))
}

/// The sharded compute-cache front-end (the paper's HashMap benchmark,
/// serving shape, scaled out). See the module docs for the layering.
pub struct Router<R: Reclaimer> {
    shards: Vec<Shard<R>>,
    /// The *distinct* reclamation domains backing the fleet: one per shard
    /// in domain-per-shard mode, exactly one in shared-domain mode. Used
    /// for double-count-free unreclaimed aggregation.
    domains: Vec<DomainRef<R>>,
    /// Effective engine-group count (see [`effective_groups`]).
    groups: usize,
    /// Per-group batcher counters, index-aligned with group ids. Each is
    /// written only by its group's batcher thread.
    group_metrics: Vec<Arc<GroupMetrics>>,
    /// One miss-channel sender per group; dropping them all (shutdown)
    /// closes every group's channel so its batcher drains and exits.
    miss_txs: Mutex<Option<Vec<mpsc::Sender<Miss>>>>,
    batchers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl<R: Reclaimer> Router<R> {
    /// Start the fleet: `cfg.shards` shards — each with its own worker
    /// pool and (unless `cfg.shared_domain`) its own reclamation domain —
    /// partitioned into `cfg.groups` engine groups, each with its own
    /// batcher/engine thread. Fails fast (and tears the fleet down again)
    /// if any engine cannot load.
    pub fn start(cfg: ServerConfig) -> Result<Arc<Self>> {
        let domains: Vec<DomainRef<R>> = if cfg.shared_domain {
            vec![DomainRef::new_owned()]
        } else {
            (0..cfg.shards.max(1)).map(|_| DomainRef::new_owned()).collect()
        };
        Self::start_with_domains(cfg, domains)
    }

    /// [`Self::start`] with an explicit domain shared by every shard (the
    /// old `CacheServer::start_in` shape; shared-shard setups and tests).
    pub fn start_in(cfg: ServerConfig, domain: DomainRef<R>) -> Result<Arc<Self>> {
        Self::start_with_domains(cfg, vec![domain])
    }

    fn start_with_domains(cfg: ServerConfig, domains: Vec<DomainRef<R>>) -> Result<Arc<Self>> {
        let n = cfg.shards.max(1);
        let groups = effective_groups(n, cfg.groups);

        // One miss channel per group: a shard's workers send only to their
        // own group's batcher, so a wedged group cannot absorb (or delay)
        // another group's misses.
        let mut miss_txs: Vec<mpsc::Sender<Miss>> = Vec::with_capacity(groups);
        let mut miss_rxs: Vec<Option<mpsc::Receiver<Miss>>> = Vec::with_capacity(groups);
        for _ in 0..groups {
            let (tx, rx) = mpsc::channel::<Miss>();
            miss_txs.push(tx);
            miss_rxs.push(Some(rx));
        }

        let mut shards: Vec<Shard<R>> = Vec::with_capacity(n);
        for i in 0..n {
            let domain = domains[i % domains.len()].clone();
            let g = group_for_shard(i, groups);
            // Group-local slot: shard i is the (i / groups)-th member of
            // group i % groups (round-robin), so the group's batcher indexes
            // its member vector directly by the miss tag.
            let slot = i / groups;
            match Shard::start(i, &cfg, domain, miss_txs[g].clone(), slot) {
                Ok(s) => shards.push(s),
                Err(e) => {
                    for s in &shards {
                        s.shutdown();
                    }
                    return Err(e);
                }
            }
        }

        // One batcher thread per group, each owning its compute engine
        // (PjRtClient is not Send, so every engine is created on its own
        // batcher thread). Readiness is confirmed through a channel so
        // start() fails fast on missing artifacts — all groups must come
        // up, or the whole fleet comes down.
        let group_metrics: Vec<Arc<GroupMetrics>> =
            (0..groups).map(|_| Arc::new(GroupMetrics::default())).collect();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut batchers: Vec<std::thread::JoinHandle<()>> = Vec::with_capacity(groups);
        for g in 0..groups {
            // Slot-ordered member list: global shard order filtered to this
            // group IS slot order (slot = index / groups is increasing).
            let shareds: Vec<Arc<ShardShared<R>>> = shards
                .iter()
                .filter(|s| group_for_shard(s.index(), groups) == g)
                .map(|s| s.shared().clone())
                .collect();
            let gm = group_metrics[g].clone();
            let backend = cfg.backend.clone();
            let dir = cfg.artifact_dir.clone();
            let wait = cfg.batch_wait;
            let ready_tx = ready_tx.clone();
            let miss_rx = miss_rxs[g].take().expect("each group rx taken once");
            let spawned =
                std::thread::Builder::new().name(format!("emr-batcher-g{g}")).spawn(move || {
                    let engine = match BatchEngine::load(&backend, &dir) {
                        Ok(e) => {
                            let _ = ready_tx.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    batcher_loop(g, &shareds, &gm, &engine, miss_rx, wait);
                });
            match spawned {
                Ok(b) => batchers.push(b),
                Err(e) => {
                    tear_down(&shards, miss_txs, batchers);
                    return Err(e.into());
                }
            }
        }
        drop(ready_tx);
        for _ in 0..groups {
            if let Err(e) = ready_rx.recv().context("batcher thread died").and_then(|r| r) {
                // An engine failed to load: stop the worker pools and the
                // sibling batchers we already started before surfacing it.
                tear_down(&shards, miss_txs, batchers);
                return Err(e);
            }
        }

        Ok(Arc::new(Self {
            shards,
            domains,
            groups,
            group_metrics,
            miss_txs: Mutex::new(Some(miss_txs)),
            batchers: Mutex::new(batchers),
        }))
    }

    /// Number of shards in the fleet.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Number of engine groups serving the fleet (≥ 1, ≤ shard count).
    pub fn group_count(&self) -> usize {
        self.groups
    }

    /// The shard `key` routes to.
    pub fn shard_of(&self, key: u32) -> usize {
        shard_for_key(key, self.shards.len())
    }

    /// The engine group serving shard `shard`.
    pub fn group_of_shard(&self, shard: usize) -> usize {
        group_for_shard(shard, self.groups)
    }

    /// The engine group `key`'s misses are computed by (via its shard).
    pub fn group_of(&self, key: u32) -> usize {
        self.group_of_shard(self.shard_of(key))
    }

    /// Global indices of the shards group `group` owns, in group-local
    /// slot order.
    pub fn group_shards(&self, group: usize) -> Vec<usize> {
        (0..self.shards.len())
            .filter(|&i| group_for_shard(i, self.groups) == group)
            .collect()
    }

    /// The shards themselves (per-shard metrics, cache sizes, domains).
    pub fn shards(&self) -> &[Shard<R>] {
        &self.shards
    }

    /// Submit a request on the async path (routes by key hash): the
    /// returned [`SubmitFuture`] resolves when a shard worker (hit) or the
    /// shard's group batcher (computed miss) fulfils its completion slot.
    /// On a stopped router the future is already closed. Safe to drop
    /// mid-flight — cancellation neither leaks the slot nor wedges the
    /// shard worker.
    pub fn submit_async(&self, key: u32) -> SubmitFuture {
        self.shards[self.shard_of(key)].submit_async(key)
    }

    /// Submit a request; the returned [`SubmitHandle`] yields the
    /// [`Response`] with a bounded wait — a blocking wrapper over
    /// [`Self::submit_async`]. On a stopped router the handle errors
    /// immediately.
    pub fn submit(&self, key: u32) -> SubmitHandle {
        self.shards[self.shard_of(key)].submit(key)
    }

    /// Blocking convenience: submit + wait (bounded by
    /// [`frontend::DEFAULT_RECV_TIMEOUT`](super::frontend::DEFAULT_RECV_TIMEOUT)).
    pub fn request(&self, key: u32) -> Result<Response> {
        self.submit(key).recv().context("server dropped request")
    }

    /// Rolled-up metrics: shard counters summed, plus the engine-group
    /// counters (batch dispatches and engine errors summed over groups,
    /// group count echoed) and the unreclaimed-node population across the
    /// *distinct* backing domains (no double counting in shared-domain
    /// mode).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut agg = MetricsSnapshot::default();
        for s in &self.shards {
            agg.add_counters(&s.shared().metrics.snapshot_with(0));
        }
        agg.batches =
            self.group_metrics.iter().map(|g| g.batches.load(Ordering::Relaxed)).sum();
        agg.engine_errors =
            self.group_metrics.iter().map(|g| g.engine_errors.load(Ordering::Relaxed)).sum();
        agg.engine_groups = self.groups as u64;
        agg.unreclaimed_nodes = self.domains.iter().map(|d| d.domain().unreclaimed()).sum();
        // Magazine counters are process-wide (worker threads serve all
        // shards), so — like unreclaimed_nodes — they are set once here
        // rather than summed per shard.
        agg.set_magazine_stats(&crate::alloc::magazine_stats());
        // Listener counters likewise: one aggregate over every live
        // `frontend::net` listener in the process, set once post roll-up.
        agg.set_net_stats(&super::frontend::net::net_stats());
        // And the flight recorder's: ring count and events recorded are
        // process-wide, set once.
        agg.set_trace_stats(&crate::trace::stats());
        agg
    }

    /// Per-shard snapshots, index-aligned with [`Self::shards`]. Each
    /// carries its own domain's unreclaimed count; `batches` and
    /// `engine_errors` are group metrics and stay 0 here (see
    /// [`Self::metrics`] and [`Self::group_metrics`]).
    pub fn shard_metrics(&self) -> Vec<MetricsSnapshot> {
        self.shards.iter().map(|s| s.metrics()).collect()
    }

    /// Per-group batcher snapshots, index-aligned with group ids: batch
    /// dispatches, occupancy and engine errors of each group's engine,
    /// tagged with the group's member shards.
    pub fn group_metrics(&self) -> Vec<GroupSnapshot> {
        self.group_metrics
            .iter()
            .enumerate()
            .map(|(g, gm)| gm.snapshot(g, self.group_shards(g)))
            .collect()
    }

    /// Entries currently cached across all shards.
    pub fn cache_len(&self) -> usize {
        self.shards.iter().map(|s| s.cache_len()).sum()
    }

    /// Stop the fleet: each shard drains and joins its workers (queued
    /// stragglers are rejected, not leaked — see [`Shard`]), then every
    /// group's miss channel closes and its batcher answers what it already
    /// holds and exits.
    pub fn shutdown(&self) {
        for s in &self.shards {
            s.shutdown();
        }
        *self.miss_txs.lock().unwrap() = None;
        let batchers = std::mem::take(&mut *self.batchers.lock().unwrap());
        for b in batchers {
            let _ = b.join();
        }
    }
}

impl<R: Reclaimer> Drop for Router<R> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start-failure teardown: stop every shard's worker pool, close all miss
/// channels, and join the batcher threads already spawned (their receivers
/// disconnect, so they drain and exit).
fn tear_down<R: Reclaimer>(
    shards: &[Shard<R>],
    miss_txs: Vec<mpsc::Sender<Miss>>,
    batchers: Vec<std::thread::JoinHandle<()>>,
) {
    for s in shards {
        s.shutdown();
    }
    drop(miss_txs);
    for b in batchers {
        let _ = b.join();
    }
}

/// A group batcher's compute engine: real PJRT artifacts, the deterministic
/// in-process fallback (the artifact-free path benches/CI smokes use), or a
/// fault/stall-injecting test double.
enum BatchEngine {
    Pjrt(Engine),
    Synthetic { max_batch: usize },
    /// Every `execute` fails ([`Backend::SyntheticFailing`]).
    SyntheticFailing { max_batch: usize },
    /// A batch containing `key` sleeps `delay_ms` first
    /// ([`Backend::SyntheticStall`]).
    SyntheticStall { key: u32, delay_ms: u64, max_batch: usize },
}

impl BatchEngine {
    fn load(backend: &Backend, dir: &Path) -> Result<Self> {
        match backend {
            Backend::Pjrt => Ok(Self::Pjrt(Engine::load(dir)?)),
            Backend::Synthetic { max_batch } => {
                Ok(Self::Synthetic { max_batch: (*max_batch).max(1) })
            }
            Backend::SyntheticFailing => {
                Ok(Self::SyntheticFailing { max_batch: Backend::SYNTHETIC_MAX_BATCH })
            }
            Backend::SyntheticStall { key, delay_ms } => Ok(Self::SyntheticStall {
                key: *key,
                delay_ms: *delay_ms,
                max_batch: Backend::SYNTHETIC_MAX_BATCH,
            }),
        }
    }

    fn max_batch(&self) -> usize {
        match self {
            Self::Pjrt(e) => e.max_batch(),
            Self::Synthetic { max_batch }
            | Self::SyntheticFailing { max_batch }
            | Self::SyntheticStall { max_batch, .. } => *max_batch,
        }
    }

    fn execute(&self, seeds: &[i32]) -> Result<Vec<Vec<f32>>> {
        match self {
            Self::Pjrt(e) => e.execute(seeds),
            Self::Synthetic { .. } => Ok(synthetic_rows(seeds)),
            Self::SyntheticFailing { .. } => {
                Err(crate::anyhow!("injected engine failure ({} keys)", seeds.len()))
            }
            Self::SyntheticStall { key, delay_ms, .. } => {
                if seeds.iter().any(|&s| s as u32 == *key) {
                    std::thread::sleep(Duration::from_millis(*delay_ms));
                }
                Ok(synthetic_rows(seeds))
            }
        }
    }
}

/// The deterministic synthetic compute: the same function the bench
/// workloads "calculate" with; keys are u32, so the i32 round-trip is
/// lossless.
fn synthetic_rows(seeds: &[i32]) -> Vec<Vec<f32>> {
    seeds
        .iter()
        .map(|&s| crate::bench_fw::workload::compute_payload(s as u32 as u64).to_vec())
        .collect()
}

fn batcher_loop<R: Reclaimer>(
    gid: usize,
    shards: &[Arc<ShardShared<R>>],
    group_metrics: &GroupMetrics,
    engine: &BatchEngine,
    miss_rx: mpsc::Receiver<Miss>,
    batch_wait: Duration,
) {
    let max_batch = engine.max_batch();
    // `shards` is this group's member list in slot order; every miss's
    // `slot` tag indexes it directly. One registered handle per *distinct*
    // member domain (members share the registration in shared-domain mode —
    // no redundant registry entries inflating every scan): every cache
    // insert below is TLS-free, and a key's whole answer path runs through
    // the handle of the shard that owns it (the facade's HandleSource
    // plumbing).
    let mut by_domain: Vec<(usize, LocalHandle<R>)> = Vec::new();
    let handles: Vec<LocalHandle<R>> = shards
        .iter()
        .map(|s| {
            let key = s.domain.key();
            match by_domain.iter().find(|(k, _)| *k == key) {
                Some((_, h)) => h.clone(),
                None => {
                    let h = s.domain.register();
                    by_domain.push((key, h.clone()));
                    h
                }
            }
        })
        .collect();
    // key → (owning slot, requests waiting for it). Key-hash routing means
    // a key belongs to exactly one shard, so the tag is a scalar.
    let mut waiting: StdHashMap<u32, (usize, Vec<Request>)> = StdHashMap::new();
    loop {
        // Block for the first miss (with a timeout to notice shutdown).
        match miss_rx.recv_timeout(Duration::from_millis(5)) {
            Ok(m) => {
                waiting.entry(m.req.key).or_insert((m.slot, Vec::new())).1.push(m.req);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if waiting.is_empty() {
                    continue;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if waiting.is_empty() {
                    return;
                }
            }
        }
        // Accumulate until the batch is full or the wait window closes.
        let deadline = std::time::Instant::now() + batch_wait;
        while waiting.len() < max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match miss_rx.recv_timeout(deadline - now) {
                Ok(m) => {
                    waiting.entry(m.req.key).or_insert((m.slot, Vec::new())).1.push(m.req);
                }
                Err(_) => break,
            }
        }

        // Dispatch one batch of distinct keys (possibly spanning this
        // group's shards).
        let keys: Vec<u32> = waiting.keys().copied().take(max_batch).collect();
        let seeds: Vec<i32> = keys.iter().map(|&k| k as i32).collect();
        crate::trace::event!("batch.dispatch", seeds.len());
        match engine.execute(&seeds) {
            Ok(results) => {
                crate::trace::event!("batch.return", keys.len());
                group_metrics.batches.fetch_add(1, Ordering::Relaxed);
                for (key, row) in keys.iter().zip(results) {
                    let Some((slot, reqs)) = waiting.remove(key) else { continue };
                    let shard = &shards[slot];
                    shard.metrics.batched_keys.fetch_add(1, Ordering::Relaxed);
                    group_metrics.batched_keys.fetch_add(1, Ordering::Relaxed);
                    let mut payload: Payload = [0.0; DIM];
                    payload.copy_from_slice(&row);
                    // Insert evicts FIFO-oldest beyond capacity — retiring
                    // 1 KiB nodes through the shard's reclamation domain.
                    if !shard.cache.insert(&handles[slot], *key, payload) {
                        shard.metrics.evictions_observed.fetch_add(1, Ordering::Relaxed);
                    }
                    for req in reqs {
                        let Request { t0, trace_id, reply, _in_flight: token, .. } = req;
                        // Gauge closes before the send wakes the waiter —
                        // same ordering as the shard worker's hit path (the
                        // waiter's freed budget permit may admit the next
                        // request immediately).
                        drop(token);
                        if trace_id != 0 {
                            crate::trace::event!("shard.complete", trace_id);
                        }
                        reply.send(Response {
                            data: Box::new(payload),
                            hit: false,
                            latency_ns: monotonic_ns() - t0,
                        });
                    }
                }
            }
            Err(e) => {
                // Engine failure: count it, then answer the batch by
                // dropping its requests — each drop closes the request's
                // completion slot, so every waiter resolves immediately
                // with an error (the net front maps a closed slot to
                // `Status::Dropped`) instead of hanging until its recv
                // deadline. The batcher keeps serving.
                group_metrics.engine_errors.fetch_add(1, Ordering::Relaxed);
                crate::trace::event!("batch.error", keys.len());
                eprintln!("[batcher g{gid}] execute failed: {e:#}");
                for key in keys {
                    waiting.remove(&key);
                }
            }
        }
    }
}

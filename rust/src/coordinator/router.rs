//! The **router**: key-hash front-end over N [`Shard`]s.
//!
//! * `submit(key)` routes by [`shard_for_key`] — a pure function of the key
//!   and the shard count, so the same key lands on the same shard across
//!   restarts and processes.
//! * One **shared batcher + engine thread** serves every shard's misses:
//!   `PjRtClient` is not `Send`, so the engine stays unique regardless of
//!   shard count; misses arrive tagged with their shard and results are
//!   inserted back through a per-shard registered handle.
//! * With `shards = 1` the router is exactly the old single `CacheServer`:
//!   one domain, one worker pool, one queue, same batcher loop.
//! * Domain modes: **domain-per-shard** (default — shards never share
//!   retire lists, epochs or hazard registries; reclamation overhead stays
//!   per-shard-thread-count) vs **shared-domain**
//!   ([`ServerConfig::shared_domain`] — one fleet-wide domain, the
//!   single-domain baseline the Stamp-it comparison study assumes). The
//!   `shard_scaling` bench measures the two against each other.

use super::frontend::{SubmitFuture, SubmitHandle};
use super::metrics::{Metrics, MetricsSnapshot};
use super::shard::{Miss, Request, Shard, ShardShared};
use super::{Backend, Payload, Response, ServerConfig};
use crate::reclaim::{DomainRef, LocalHandle, Reclaimer};
use crate::runtime::{Engine, DIM};
use crate::util::error::{Context, Result};
use crate::util::monotonic_ns;
use crate::util::rng::mix64;
use std::collections::HashMap as StdHashMap;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// Deterministic key→shard routing: a pure function of `(key, shards)`,
/// stable across restarts and processes. `shards = 1` always maps to 0.
pub fn shard_for_key(key: u32, shards: usize) -> usize {
    debug_assert!(shards > 0);
    if shards == 1 {
        return 0;
    }
    // mix64 avalanches the key over the full word; use the top half so the
    // low-bit structure of small keys cannot skew the modulo.
    ((mix64(key as u64) >> 32) as usize) % shards
}

/// The sharded compute-cache front-end (the paper's HashMap benchmark,
/// serving shape, scaled out). See the module docs for the layering.
pub struct Router<R: Reclaimer> {
    shards: Vec<Shard<R>>,
    /// The *distinct* reclamation domains backing the fleet: one per shard
    /// in domain-per-shard mode, exactly one in shared-domain mode. Used
    /// for double-count-free unreclaimed aggregation.
    domains: Vec<DomainRef<R>>,
    /// Router-level counters (engine batch dispatches span shards).
    metrics: Arc<Metrics>,
    miss_tx: Mutex<Option<mpsc::Sender<Miss>>>,
    batcher: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl<R: Reclaimer> Router<R> {
    /// Start the fleet: `cfg.shards` shards — each with its own worker
    /// pool and (unless `cfg.shared_domain`) its own reclamation domain —
    /// plus the single shared batcher/engine thread. Fails fast (and tears
    /// the fleet down again) if the engine cannot load.
    pub fn start(cfg: ServerConfig) -> Result<Arc<Self>> {
        let domains: Vec<DomainRef<R>> = if cfg.shared_domain {
            vec![DomainRef::new_owned()]
        } else {
            (0..cfg.shards.max(1)).map(|_| DomainRef::new_owned()).collect()
        };
        Self::start_with_domains(cfg, domains)
    }

    /// [`Self::start`] with an explicit domain shared by every shard (the
    /// old `CacheServer::start_in` shape; shared-shard setups and tests).
    pub fn start_in(cfg: ServerConfig, domain: DomainRef<R>) -> Result<Arc<Self>> {
        Self::start_with_domains(cfg, vec![domain])
    }

    fn start_with_domains(cfg: ServerConfig, domains: Vec<DomainRef<R>>) -> Result<Arc<Self>> {
        let n = cfg.shards.max(1);
        let (miss_tx, miss_rx) = mpsc::channel::<Miss>();
        let mut shards: Vec<Shard<R>> = Vec::with_capacity(n);
        for i in 0..n {
            let domain = domains[i % domains.len()].clone();
            match Shard::start(i, &cfg, domain, miss_tx.clone()) {
                Ok(s) => shards.push(s),
                Err(e) => {
                    for s in &shards {
                        s.shutdown();
                    }
                    return Err(e);
                }
            }
        }

        // Batcher thread owns the compute engine (PjRtClient is not Send,
        // so it is created on this thread — the one engine thread of the
        // whole fleet). Readiness is confirmed through a channel so
        // start() fails fast on missing artifacts.
        let metrics = Arc::new(Metrics::default());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let batcher = {
            let shareds: Vec<Arc<ShardShared<R>>> =
                shards.iter().map(|s| s.shared().clone()).collect();
            let metrics = metrics.clone();
            let backend = cfg.backend.clone();
            let dir = cfg.artifact_dir.clone();
            let wait = cfg.batch_wait;
            let spawned = std::thread::Builder::new().name("emr-batcher".into()).spawn(move || {
                let engine = match BatchEngine::load(&backend, &dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                batcher_loop(&shareds, &metrics, &engine, miss_rx, wait);
            });
            match spawned {
                Ok(b) => b,
                Err(e) => {
                    for s in &shards {
                        s.shutdown();
                    }
                    return Err(e.into());
                }
            }
        };
        if let Err(e) = ready_rx.recv().context("batcher thread died").and_then(|r| r) {
            // Engine failed to load: stop the worker pools we already
            // started before surfacing the error.
            for s in &shards {
                s.shutdown();
            }
            drop(miss_tx);
            let _ = batcher.join();
            return Err(e);
        }

        Ok(Arc::new(Self {
            shards,
            domains,
            metrics,
            miss_tx: Mutex::new(Some(miss_tx)),
            batcher: Mutex::new(Some(batcher)),
        }))
    }

    /// Number of shards in the fleet.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard `key` routes to.
    pub fn shard_of(&self, key: u32) -> usize {
        shard_for_key(key, self.shards.len())
    }

    /// The shards themselves (per-shard metrics, cache sizes, domains).
    pub fn shards(&self) -> &[Shard<R>] {
        &self.shards
    }

    /// Submit a request on the async path (routes by key hash): the
    /// returned [`SubmitFuture`] resolves when a shard worker (hit) or the
    /// batcher (computed miss) fulfils its completion slot. On a stopped
    /// router the future is already closed. Safe to drop mid-flight —
    /// cancellation neither leaks the slot nor wedges the shard worker.
    pub fn submit_async(&self, key: u32) -> SubmitFuture {
        self.shards[self.shard_of(key)].submit_async(key)
    }

    /// Submit a request; the returned [`SubmitHandle`] yields the
    /// [`Response`] with a bounded wait — a blocking wrapper over
    /// [`Self::submit_async`]. On a stopped router the handle errors
    /// immediately.
    pub fn submit(&self, key: u32) -> SubmitHandle {
        self.shards[self.shard_of(key)].submit(key)
    }

    /// Blocking convenience: submit + wait (bounded by
    /// [`frontend::DEFAULT_RECV_TIMEOUT`](super::frontend::DEFAULT_RECV_TIMEOUT)).
    pub fn request(&self, key: u32) -> Result<Response> {
        self.submit(key).recv().context("server dropped request")
    }

    /// Rolled-up metrics: shard counters summed, plus the fleet-wide batch
    /// counters and the unreclaimed-node population across the *distinct*
    /// backing domains (no double counting in shared-domain mode).
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut agg = MetricsSnapshot::default();
        for s in &self.shards {
            agg.add_counters(&s.shared().metrics.snapshot_with(0));
        }
        agg.batches = self.metrics.batches.load(Ordering::Relaxed);
        agg.unreclaimed_nodes = self.domains.iter().map(|d| d.domain().unreclaimed()).sum();
        // Magazine counters are process-wide (worker threads serve all
        // shards), so — like unreclaimed_nodes — they are set once here
        // rather than summed per shard.
        agg.set_magazine_stats(&crate::alloc::magazine_stats());
        // Listener counters likewise: one aggregate over every live
        // `frontend::net` listener in the process, set once post roll-up.
        agg.set_net_stats(&super::frontend::net::net_stats());
        agg
    }

    /// Per-shard snapshots, index-aligned with [`Self::shards`]. Each
    /// carries its own domain's unreclaimed count; `batches` is a fleet
    /// metric and stays 0 here (see [`Self::metrics`]).
    pub fn shard_metrics(&self) -> Vec<MetricsSnapshot> {
        self.shards.iter().map(|s| s.metrics()).collect()
    }

    /// Entries currently cached across all shards.
    pub fn cache_len(&self) -> usize {
        self.shards.iter().map(|s| s.cache_len()).sum()
    }

    /// Stop the fleet: each shard drains and joins its workers (queued
    /// stragglers are rejected, not leaked — see [`Shard`]), then the miss
    /// channel closes and the batcher answers what it already holds and
    /// exits.
    pub fn shutdown(&self) {
        for s in &self.shards {
            s.shutdown();
        }
        *self.miss_tx.lock().unwrap() = None;
        if let Some(b) = self.batcher.lock().unwrap().take() {
            let _ = b.join();
        }
    }
}

impl<R: Reclaimer> Drop for Router<R> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The batcher's compute engine: real PJRT artifacts or the deterministic
/// in-process fallback (the artifact-free path benches/CI smokes use).
enum BatchEngine {
    Pjrt(Engine),
    Synthetic { max_batch: usize },
}

impl BatchEngine {
    fn load(backend: &Backend, dir: &Path) -> Result<Self> {
        match backend {
            Backend::Pjrt => Ok(Self::Pjrt(Engine::load(dir)?)),
            Backend::Synthetic { max_batch } => {
                Ok(Self::Synthetic { max_batch: (*max_batch).max(1) })
            }
        }
    }

    fn max_batch(&self) -> usize {
        match self {
            Self::Pjrt(e) => e.max_batch(),
            Self::Synthetic { max_batch } => *max_batch,
        }
    }

    fn execute(&self, seeds: &[i32]) -> Result<Vec<Vec<f32>>> {
        match self {
            Self::Pjrt(e) => e.execute(seeds),
            // Same deterministic function the bench workloads "calculate"
            // with; keys are u32, so the i32 round-trip is lossless.
            Self::Synthetic { .. } => Ok(seeds
                .iter()
                .map(|&s| crate::bench_fw::workload::compute_payload(s as u32 as u64).to_vec())
                .collect()),
        }
    }
}

fn batcher_loop<R: Reclaimer>(
    shards: &[Arc<ShardShared<R>>],
    router_metrics: &Metrics,
    engine: &BatchEngine,
    miss_rx: mpsc::Receiver<Miss>,
    batch_wait: Duration,
) {
    let max_batch = engine.max_batch();
    // One registered handle per *distinct* shard domain (shards share the
    // registration in shared-domain mode — no redundant registry entries
    // inflating every scan): every cache insert below is TLS-free, and a
    // key's whole answer path runs through the handle of the shard that
    // owns it (the facade's HandleSource plumbing).
    let mut by_domain: Vec<(usize, LocalHandle<R>)> = Vec::new();
    let handles: Vec<LocalHandle<R>> = shards
        .iter()
        .map(|s| {
            let key = s.domain.key();
            match by_domain.iter().find(|(k, _)| *k == key) {
                Some((_, h)) => h.clone(),
                None => {
                    let h = s.domain.register();
                    by_domain.push((key, h.clone()));
                    h
                }
            }
        })
        .collect();
    // key → (owning shard, requests waiting for it). Key-hash routing means
    // a key belongs to exactly one shard, so the tag is a scalar.
    let mut waiting: StdHashMap<u32, (usize, Vec<Request>)> = StdHashMap::new();
    loop {
        // Block for the first miss (with a timeout to notice shutdown).
        match miss_rx.recv_timeout(Duration::from_millis(5)) {
            Ok(m) => {
                waiting.entry(m.req.key).or_insert((m.shard, Vec::new())).1.push(m.req);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if waiting.is_empty() {
                    continue;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if waiting.is_empty() {
                    return;
                }
            }
        }
        // Accumulate until the batch is full or the wait window closes.
        let deadline = std::time::Instant::now() + batch_wait;
        while waiting.len() < max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match miss_rx.recv_timeout(deadline - now) {
                Ok(m) => {
                    waiting.entry(m.req.key).or_insert((m.shard, Vec::new())).1.push(m.req);
                }
                Err(_) => break,
            }
        }

        // Dispatch one batch of distinct keys (possibly spanning shards).
        let keys: Vec<u32> = waiting.keys().copied().take(max_batch).collect();
        let seeds: Vec<i32> = keys.iter().map(|&k| k as i32).collect();
        match engine.execute(&seeds) {
            Ok(results) => {
                router_metrics.batches.fetch_add(1, Ordering::Relaxed);
                for (key, row) in keys.iter().zip(results) {
                    let Some((shard_idx, reqs)) = waiting.remove(key) else { continue };
                    let shard = &shards[shard_idx];
                    shard.metrics.batched_keys.fetch_add(1, Ordering::Relaxed);
                    let mut payload: Payload = [0.0; DIM];
                    payload.copy_from_slice(&row);
                    // Insert evicts FIFO-oldest beyond capacity — retiring
                    // 1 KiB nodes through the shard's reclamation domain.
                    if !shard.cache.insert(&handles[shard_idx], *key, payload) {
                        shard.metrics.evictions_observed.fetch_add(1, Ordering::Relaxed);
                    }
                    for req in reqs {
                        let Request { t0, reply, _in_flight: token, .. } = req;
                        // Gauge closes before the send wakes the waiter —
                        // same ordering as the shard worker's hit path (the
                        // waiter's freed budget permit may admit the next
                        // request immediately).
                        drop(token);
                        reply.send(Response {
                            data: Box::new(payload),
                            hit: false,
                            latency_ns: monotonic_ns() - t0,
                        });
                    }
                }
            }
            Err(e) => {
                // Engine failure: drop the affected requests (their
                // completion slots close, so waiters error out) and keep
                // serving.
                eprintln!("[batcher] execute failed: {e:#}");
                for key in keys {
                    waiting.remove(&key);
                }
            }
        }
    }
}

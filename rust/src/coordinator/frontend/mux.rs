//! The **connection multiplexer**: drives N logical clients — thousands
//! per executor thread — against a [`Router`], entirely on the async
//! submission path (DESIGN.md §6).
//!
//! Each logical client is one spawned task: pick a key (hot-set-skewed,
//! like E15/E16), acquire its target shard's **in-flight budget** (an async
//! [`Semaphore`] — the back-pressure bound that keeps a hot shard's queue
//! from growing without limit), `submit_async`, await the completion, record
//! the latency, repeat. A parked client costs one heap allocation, not an
//! OS thread — this is the many-lightweight-tasks-on-few-threads regime
//! that thread-per-request cannot reach (ISSUE: the Hyaline comparison
//! axis), and E17 measures how each reclamation scheme behaves under it.

use crate::coordinator::{Response, Router};
use crate::reclaim::Reclaimer;
use crate::runtime::exec::{Executor, JoinHandle, Semaphore};
use crate::util::monotonic_ns;
use crate::util::rng::{mix64, Xoshiro256};
use crate::util::stats::LogHistogram;
use std::sync::Arc;

/// Mux workload shape. Defaults mirror E15's serving load (30k keys, 80%
/// of traffic on a 1% hot set) with a 256-deep per-shard budget.
#[derive(Clone, Debug)]
pub struct MuxConfig {
    /// Logical clients (concurrent tasks).
    pub clients: usize,
    /// Requests each client issues, sequentially.
    pub requests_per_client: usize,
    /// Key space the clients draw from.
    pub key_space: u64,
    /// Percent of requests aimed at the hot set (1% of the key space).
    pub hot_pct: u32,
    /// In-flight budget per shard: a client stalls (asynchronously) until
    /// its target shard has a free slot. Min 1.
    pub shard_in_flight: usize,
    /// Base RNG seed (client c uses `seed ^ mix64(c)`).
    pub seed: u64,
}

impl Default for MuxConfig {
    fn default() -> Self {
        Self {
            clients: 1000,
            requests_per_client: 10,
            key_space: 30_000,
            hot_pct: 80,
            shard_in_flight: 256,
            seed: 0xE17,
        }
    }
}

/// What one mux run observed. Latencies live in log-bucketed histograms
/// ([`LogHistogram`], ≤6.25% relative error) rather than per-request
/// vectors — O(1) per response, constant memory at 100k clients, and the
/// percentile cells fall straight out of `latency_hist().percentile(..)`.
#[derive(Clone, Debug, Default)]
pub struct MuxReport {
    /// Latency distribution of cache-hit responses (submit → reply, ns).
    pub hit: LogHistogram,
    /// Latency distribution of computed (miss) responses.
    pub miss: LogHistogram,
    /// Requests that resolved with an error (dropped by the server), plus
    /// the FULL per-client quota for any client task that died without
    /// reporting (its tally is lost with the task, so all of its requests
    /// count as errors — `served() + errors` always equals
    /// `clients × requests_per_client`).
    pub errors: u64,
    /// Wall time of the whole run.
    pub wall_ns: u64,
}

impl MuxReport {
    /// Responses successfully served.
    pub fn served(&self) -> u64 {
        self.hit.count() + self.miss.count()
    }

    /// Hit and miss latencies folded into one distribution.
    pub fn latency_hist(&self) -> LogHistogram {
        let mut all = self.hit.clone();
        all.merge(&self.miss);
        all
    }
}

/// Per-client tally: (hit latencies, miss latencies, errors).
type ClientStats = (LogHistogram, LogHistogram, u64);

/// Drive `cfg.clients` logical clients over `exec` against `router`,
/// blocking the calling thread until every client finished its requests.
///
/// The call owns no threads of its own: all concurrency lives on the
/// executor, and the caller just joins the client tasks.
pub fn drive<R: Reclaimer>(exec: &Executor, router: Arc<Router<R>>, cfg: &MuxConfig) -> MuxReport {
    let budgets: Arc<Vec<Semaphore>> = Arc::new(
        (0..router.shard_count()).map(|_| Semaphore::new(cfg.shard_in_flight.max(1))).collect(),
    );
    let key_space = cfg.key_space.max(1);
    let t0 = monotonic_ns();
    let handles: Vec<JoinHandle<ClientStats>> = (0..cfg.clients)
        .map(|c| {
            let router = router.clone();
            let budgets = budgets.clone();
            let requests = cfg.requests_per_client;
            let hot_pct = cfg.hot_pct;
            let seed = cfg.seed ^ mix64(c as u64);
            exec.spawn(async move {
                let mut rng = Xoshiro256::new(seed);
                let mut hit = LogHistogram::new();
                let mut miss = LogHistogram::new();
                let mut errors = 0u64;
                for _ in 0..requests {
                    let key = rng.skewed_key(key_space, hot_pct);
                    // Back-pressure: hold a budget slot of the shard this
                    // key routes to for the whole submit → reply window.
                    let _permit = budgets[router.shard_of(key)].acquire().await;
                    match router.submit_async(key).await {
                        Ok(Response { hit: true, latency_ns, .. }) => hit.record(latency_ns),
                        Ok(Response { latency_ns, .. }) => miss.record(latency_ns),
                        Err(_) => errors += 1,
                    }
                }
                (hit, miss, errors)
            })
        })
        .collect();

    let mut report = MuxReport::default();
    for h in handles {
        match h.join() {
            Some((hit, miss, errors)) => {
                report.hit.merge(&hit);
                report.miss.merge(&miss);
                report.errors += errors;
            }
            // A client task died (cancelled/panicked): its tally is lost,
            // so its whole quota counts as errors — `served() + errors`
            // stays exactly `clients × requests_per_client`.
            None => report.errors += cfg.requests_per_client as u64,
        }
    }
    report.wall_ns = monotonic_ns() - t0;
    report
}

//! Wire protocol for the TCP serving front: tiny length-prefixed frames.
//!
//! Both directions share one shape — a little-endian `u32` body length
//! followed by the body — so one incremental [`FrameBuf`] serves client and
//! server alike; only the body parsers differ.
//!
//! ```text
//! request  body:  u64 request-id | key bytes (1..=4, LE, zero-extended u32)
//! response body:  u64 request-id | u8 status | payload
//!                   status 0 (Ok):  u8 hit | DIM × f32 (LE)
//!                   status 1 (BadRequest), 2 (Dropped): empty payload
//! ```
//!
//! Design points (DESIGN.md §8):
//! - **Partial reads are the norm.** [`FrameBuf::extend`] buffers whatever a
//!   nonblocking read produced; [`FrameBuf::next_frame`] yields complete
//!   bodies as borrowed slices, or `None` until more bytes arrive. No frame
//!   is ever allocated per-message — the buffer compacts in place.
//! - **Malformed input never kills the process.** A body longer than the
//!   direction's maximum is a [`ProtoError::Oversized`] *before* any
//!   buffering of the body, so a hostile 4 GiB length prefix costs four
//!   bytes, not an allocation. The connection is closed; the listener and
//!   every other connection are untouched.
//! - **Answerable vs fatal.** A zero-length key is a well-formed frame with
//!   a recoverable semantic error: it parses to [`ParsedRequest::Invalid`]
//!   and earns a [`Status::BadRequest`] response on the same connection.
//!   A body too short to carry a request id is fatal — there is no id to
//!   attach an error to — and closes the connection.

use crate::coordinator::{Payload, Response};
use crate::runtime::DIM;
use std::fmt;

/// Bytes of length prefix framing every message.
pub const LEN_PREFIX: usize = 4;
/// Bytes of request id leading every body.
pub const ID_BYTES: usize = 8;
/// Longest key encoding accepted (a little-endian `u32`, possibly trimmed).
pub const MAX_KEY_BYTES: usize = 4;
/// Largest request body the server will buffer.
pub const MAX_REQ_BODY: usize = ID_BYTES + MAX_KEY_BYTES;
/// Body length of an OK response: id, status, hit flag, DIM f32 values.
pub const RESP_OK_BODY: usize = ID_BYTES + 1 + 1 + DIM * 4;
/// Largest response body a client should accept.
pub const MAX_RESP_BODY: usize = RESP_OK_BODY;

/// Response status byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Request served; payload carries the hit flag and data.
    Ok,
    /// Well-formed frame, unusable request (e.g. zero-length key). The
    /// connection stays open.
    BadRequest,
    /// The server dropped the request (router shutting down); the
    /// connection stays open and may retry.
    Dropped,
}

impl Status {
    fn to_byte(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::BadRequest => 1,
            Status::Dropped => 2,
        }
    }

    fn from_byte(b: u8) -> Option<Status> {
        match b {
            0 => Some(Status::Ok),
            1 => Some(Status::BadRequest),
            2 => Some(Status::Dropped),
            _ => None,
        }
    }
}

/// A fatal framing error: the peer is not speaking the protocol and the
/// connection should be closed. Never panics, never kills the listener.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtoError {
    /// Declared body length exceeds the direction's maximum.
    Oversized { len: usize, max: usize },
    /// Body too short for the fixed leading fields (no request id to
    /// answer, so the error is unanswerable).
    Truncated { len: usize },
    /// Unknown status byte in a response body.
    BadStatus { byte: u8 },
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            ProtoError::Oversized { len, max } => {
                write!(f, "frame body {len} B exceeds max {max} B")
            }
            ProtoError::Truncated { len } => {
                write!(f, "frame body {len} B too short for header")
            }
            ProtoError::BadStatus { byte } => write!(f, "unknown status byte {byte:#04x}"),
        }
    }
}

/// A decoded request body.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParsedRequest {
    /// Submit `key`, answer with `id`.
    Valid { id: u64, key: u32 },
    /// Answerable semantic error (zero-length key): reply
    /// [`Status::BadRequest`] to `id`, keep the connection.
    Invalid { id: u64 },
}

/// A decoded response body (client side).
#[derive(Clone, Debug)]
pub struct ResponseFrame {
    pub id: u64,
    pub status: Status,
    /// Cache hit flag (only meaningful for [`Status::Ok`]).
    pub hit: bool,
    /// Payload data for [`Status::Ok`]; `None` for error statuses.
    pub data: Option<Box<Payload>>,
}

// ---------------------------------------------------------------------------
// Encoders
// ---------------------------------------------------------------------------

/// Append one encoded request frame to `buf`. The key is trimmed to its
/// shortest little-endian encoding (at least one byte), exercising the
/// variable-width path the decoder must accept.
pub fn encode_request(buf: &mut Vec<u8>, id: u64, key: u32) {
    let kb = key.to_le_bytes();
    let klen = (4 - (key.leading_zeros() as usize / 8)).max(1);
    buf.extend_from_slice(&((ID_BYTES + klen) as u32).to_le_bytes());
    buf.extend_from_slice(&id.to_le_bytes());
    buf.extend_from_slice(&kb[..klen]);
}

/// Append one encoded OK response frame to `buf`.
pub fn encode_response(buf: &mut Vec<u8>, id: u64, resp: &Response) {
    buf.reserve(LEN_PREFIX + RESP_OK_BODY);
    buf.extend_from_slice(&(RESP_OK_BODY as u32).to_le_bytes());
    buf.extend_from_slice(&id.to_le_bytes());
    buf.push(Status::Ok.to_byte());
    buf.push(resp.hit as u8);
    for v in resp.data.iter() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Append one encoded error response frame (empty payload) to `buf`.
pub fn encode_error(buf: &mut Vec<u8>, id: u64, status: Status) {
    debug_assert!(status != Status::Ok, "error frames carry no payload");
    buf.extend_from_slice(&((ID_BYTES + 1) as u32).to_le_bytes());
    buf.extend_from_slice(&id.to_le_bytes());
    buf.push(status.to_byte());
}

// ---------------------------------------------------------------------------
// Incremental framer
// ---------------------------------------------------------------------------

/// Incremental, allocation-light frame accumulator.
///
/// Feed it raw socket bytes with [`extend`](FrameBuf::extend); pull complete
/// bodies with [`next_frame`](FrameBuf::next_frame). Frames split across
/// arbitrarily many reads, or coalesced many-per-read, decode identically
/// (the round-trip property `fuzz_rechunked_roundtrip` asserts). The
/// internal buffer grows to the high-water mark once and is reused;
/// consumed prefixes are dropped by pointer bump and compacted lazily.
pub struct FrameBuf {
    buf: Vec<u8>,
    /// Consumed prefix of `buf`.
    pos: usize,
    /// Largest acceptable body; longer declared lengths are fatal.
    max_body: usize,
}

/// Compact once the dead prefix crosses this many bytes (keeps `memmove`
/// traffic amortized while bounding buffer growth).
const COMPACT_AT: usize = 16 * 1024;

impl FrameBuf {
    /// A framer for request bodies (server side).
    pub fn for_requests() -> FrameBuf {
        FrameBuf::with_max_body(MAX_REQ_BODY)
    }

    /// A framer for response bodies (client side).
    pub fn for_responses() -> FrameBuf {
        FrameBuf::with_max_body(MAX_RESP_BODY)
    }

    /// A framer accepting bodies up to `max_body` bytes.
    pub fn with_max_body(max_body: usize) -> FrameBuf {
        FrameBuf { buf: Vec::new(), pos: 0, max_body }
    }

    /// Buffer `bytes` (one nonblocking read's worth, any size incl. zero).
    pub fn extend(&mut self, bytes: &[u8]) {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= COMPACT_AT {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Unconsumed bytes currently buffered.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Next complete body, if one is buffered.
    ///
    /// * `Ok(Some(body))` — a complete frame body; consumed from the buffer.
    /// * `Ok(None)` — need more bytes.
    /// * `Err(_)` — the declared length is unacceptable; the caller should
    ///   drop the connection (the framer is poisoned at the bad prefix).
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>, ProtoError> {
        let avail = self.buf.len() - self.pos;
        if avail < LEN_PREFIX {
            return Ok(None);
        }
        let p = self.pos;
        let len =
            u32::from_le_bytes([self.buf[p], self.buf[p + 1], self.buf[p + 2], self.buf[p + 3]])
                as usize;
        if len > self.max_body {
            return Err(ProtoError::Oversized { len, max: self.max_body });
        }
        if avail < LEN_PREFIX + len {
            return Ok(None);
        }
        self.pos = p + LEN_PREFIX + len;
        Ok(Some(&self.buf[p + LEN_PREFIX..p + LEN_PREFIX + len]))
    }
}

// ---------------------------------------------------------------------------
// Body parsers
// ---------------------------------------------------------------------------

/// Parse a request body produced by [`FrameBuf::next_frame`].
pub fn parse_request(body: &[u8]) -> Result<ParsedRequest, ProtoError> {
    if body.len() < ID_BYTES {
        return Err(ProtoError::Truncated { len: body.len() });
    }
    let id = u64::from_le_bytes(body[..ID_BYTES].try_into().unwrap());
    let key_bytes = &body[ID_BYTES..];
    if key_bytes.is_empty() {
        return Ok(ParsedRequest::Invalid { id });
    }
    if key_bytes.len() > MAX_KEY_BYTES {
        // Unreachable behind `for_requests()` (the framer bounds bodies at
        // MAX_REQ_BODY) but kept for direct callers.
        return Err(ProtoError::Oversized {
            len: body.len(),
            max: MAX_REQ_BODY,
        });
    }
    let mut kb = [0u8; 4];
    kb[..key_bytes.len()].copy_from_slice(key_bytes);
    Ok(ParsedRequest::Valid { id, key: u32::from_le_bytes(kb) })
}

/// Parse a response body produced by [`FrameBuf::next_frame`] (client side).
pub fn parse_response(body: &[u8]) -> Result<ResponseFrame, ProtoError> {
    if body.len() < ID_BYTES + 1 {
        return Err(ProtoError::Truncated { len: body.len() });
    }
    let id = u64::from_le_bytes(body[..ID_BYTES].try_into().unwrap());
    let status =
        Status::from_byte(body[ID_BYTES]).ok_or(ProtoError::BadStatus { byte: body[ID_BYTES] })?;
    if status != Status::Ok {
        return Ok(ResponseFrame { id, status, hit: false, data: None });
    }
    if body.len() != RESP_OK_BODY {
        return Err(ProtoError::Truncated { len: body.len() });
    }
    let hit = body[ID_BYTES + 1] != 0;
    let mut data: Box<Payload> = Box::new([0.0; DIM]);
    for (slot, chunk) in data.iter_mut().zip(body[ID_BYTES + 2..].chunks_exact(4)) {
        *slot = f32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(ResponseFrame { id, status, hit, data: Some(data) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn ok_response(seed: f32) -> Response {
        let mut data = Box::new([0.0f32; DIM]);
        for (i, v) in data.iter_mut().enumerate() {
            *v = seed + i as f32;
        }
        Response { data, hit: true, latency_ns: 7 }
    }

    fn drain_requests(fb: &mut FrameBuf) -> Vec<ParsedRequest> {
        let mut out = Vec::new();
        while let Some(body) = fb.next_frame().expect("well-formed stream") {
            out.push(parse_request(body).expect("parseable body"));
        }
        out
    }

    #[test]
    fn request_roundtrip_one_frame() {
        let mut bytes = Vec::new();
        encode_request(&mut bytes, 42, 0xdead_beef);
        let mut fb = FrameBuf::for_requests();
        fb.extend(&bytes);
        assert_eq!(
            drain_requests(&mut fb),
            vec![ParsedRequest::Valid { id: 42, key: 0xdead_beef }]
        );
        assert_eq!(fb.buffered(), 0);
    }

    #[test]
    fn short_keys_use_trimmed_encoding_and_roundtrip() {
        // key 5 encodes in 1 byte, key 0 still needs 1 byte.
        for key in [0u32, 5, 0x100, 0x10000, u32::MAX] {
            let mut bytes = Vec::new();
            encode_request(&mut bytes, 9, key);
            let expected_len = LEN_PREFIX + ID_BYTES + ((32 - key.leading_zeros() as usize).div_ceil(8)).max(1);
            assert_eq!(bytes.len(), expected_len, "key {key:#x}");
            let mut fb = FrameBuf::for_requests();
            fb.extend(&bytes);
            assert_eq!(drain_requests(&mut fb), vec![ParsedRequest::Valid { id: 9, key }]);
        }
    }

    #[test]
    fn split_frame_decodes_across_byte_at_a_time_reads() {
        let mut bytes = Vec::new();
        encode_request(&mut bytes, 7, 123);
        let mut fb = FrameBuf::for_requests();
        for (i, b) in bytes.iter().enumerate() {
            // Nothing decodes before the last byte lands.
            if i + 1 < bytes.len() {
                assert!(fb.next_frame().unwrap().is_none());
            }
            fb.extend(std::slice::from_ref(b));
        }
        assert_eq!(drain_requests(&mut fb), vec![ParsedRequest::Valid { id: 7, key: 123 }]);
    }

    #[test]
    fn coalesced_frames_decode_from_one_read() {
        let mut bytes = Vec::new();
        for id in 0..50u64 {
            encode_request(&mut bytes, id, id as u32 * 3);
        }
        let mut fb = FrameBuf::for_requests();
        fb.extend(&bytes);
        let got = drain_requests(&mut fb);
        assert_eq!(got.len(), 50);
        for (id, req) in got.into_iter().enumerate() {
            assert_eq!(req, ParsedRequest::Valid { id: id as u64, key: id as u32 * 3 });
        }
    }

    #[test]
    fn zero_length_key_is_answerable_not_fatal() {
        // Hand-build: len=8 (id only, no key bytes).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(ID_BYTES as u32).to_le_bytes());
        bytes.extend_from_slice(&77u64.to_le_bytes());
        let mut fb = FrameBuf::for_requests();
        fb.extend(&bytes);
        assert_eq!(drain_requests(&mut fb), vec![ParsedRequest::Invalid { id: 77 }]);
    }

    #[test]
    fn oversized_frame_is_rejected_before_buffering_body() {
        // Declared length of 4 GiB-ish: the framer must error on the four
        // prefix bytes alone, without waiting for (or allocating) the body.
        let mut fb = FrameBuf::for_requests();
        fb.extend(&u32::MAX.to_le_bytes());
        match fb.next_frame() {
            Err(ProtoError::Oversized { len, max }) => {
                assert_eq!(len, u32::MAX as usize);
                assert_eq!(max, MAX_REQ_BODY);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn oversized_by_one_is_rejected_max_is_accepted() {
        let mut fb = FrameBuf::for_requests();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&((MAX_REQ_BODY + 1) as u32).to_le_bytes());
        bytes.extend_from_slice(&[0u8; MAX_REQ_BODY + 1]);
        fb.extend(&bytes);
        assert!(matches!(fb.next_frame(), Err(ProtoError::Oversized { .. })));

        let mut fb = FrameBuf::for_requests();
        let mut bytes = Vec::new();
        encode_request(&mut bytes, 1, u32::MAX); // max-width key = max body
        assert_eq!(bytes.len(), LEN_PREFIX + MAX_REQ_BODY);
        fb.extend(&bytes);
        assert_eq!(drain_requests(&mut fb).len(), 1);
    }

    #[test]
    fn truncated_body_has_no_answerable_id() {
        // len=4: not enough for the u64 id — fatal.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&4u32.to_le_bytes());
        bytes.extend_from_slice(&[1, 2, 3, 4]);
        let mut fb = FrameBuf::for_requests();
        fb.extend(&bytes);
        let body = fb.next_frame().unwrap().expect("frame complete");
        assert_eq!(parse_request(body), Err(ProtoError::Truncated { len: 4 }));
    }

    #[test]
    fn response_roundtrip_ok_and_error() {
        let resp = ok_response(0.5);
        let mut bytes = Vec::new();
        encode_response(&mut bytes, 31, &resp);
        encode_error(&mut bytes, 32, Status::BadRequest);
        encode_error(&mut bytes, 33, Status::Dropped);

        let mut fb = FrameBuf::for_responses();
        fb.extend(&bytes);

        let f1 = parse_response(fb.next_frame().unwrap().unwrap()).unwrap();
        assert_eq!(f1.id, 31);
        assert_eq!(f1.status, Status::Ok);
        assert!(f1.hit);
        assert_eq!(f1.data.as_deref().unwrap()[..], resp.data[..]);

        let f2 = parse_response(fb.next_frame().unwrap().unwrap()).unwrap();
        assert_eq!((f2.id, f2.status), (32, Status::BadRequest));
        assert!(f2.data.is_none());

        let f3 = parse_response(fb.next_frame().unwrap().unwrap()).unwrap();
        assert_eq!((f3.id, f3.status), (33, Status::Dropped));
        assert!(fb.next_frame().unwrap().is_none());
    }

    #[test]
    fn response_bad_status_byte_is_fatal() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&((ID_BYTES + 1) as u32).to_le_bytes());
        bytes.extend_from_slice(&5u64.to_le_bytes());
        bytes.push(0xEE);
        let mut fb = FrameBuf::for_responses();
        fb.extend(&bytes);
        let body = fb.next_frame().unwrap().unwrap();
        assert!(matches!(parse_response(body), Err(ProtoError::BadStatus { byte: 0xEE })));
    }

    #[test]
    fn buffer_compacts_and_is_reused_across_frames() {
        let mut fb = FrameBuf::for_requests();
        let mut bytes = Vec::new();
        encode_request(&mut bytes, 1, 2);
        // Enough traffic to trip the COMPACT_AT path several times over.
        for round in 0..(COMPACT_AT / bytes.len()) * 3 {
            fb.extend(&bytes);
            let got = drain_requests(&mut fb);
            assert_eq!(got, vec![ParsedRequest::Valid { id: 1, key: 2 }], "round {round}");
        }
        // Fully-consumed buffer resets to the front: capacity stays bounded
        // by one frame's worth, not the cumulative stream.
        assert!(fb.buf.capacity() < COMPACT_AT, "capacity {}", fb.buf.capacity());
    }

    /// The satellite fuzz test: any re-chunking of an encoded stream decodes
    /// to the same frame sequence. Randomized chunk boundaries via the
    /// crate's own deterministic RNG — failures reproduce from the seed.
    #[test]
    fn fuzz_rechunked_roundtrip() {
        let mut rng = Xoshiro256::new(0x9e37_79b9_7f4a_7c15);
        for round in 0..50 {
            // A mixed stream of valid, zero-key and error-free frames.
            let mut want = Vec::new();
            let mut bytes = Vec::new();
            let n = 1 + rng.below(40) as usize;
            for _ in 0..n {
                let id = rng.below(u64::MAX);
                if rng.below(10) == 0 {
                    bytes.extend_from_slice(&(ID_BYTES as u32).to_le_bytes());
                    bytes.extend_from_slice(&id.to_le_bytes());
                    want.push(ParsedRequest::Invalid { id });
                } else {
                    let key = (rng.below(u32::MAX as u64 + 1)) as u32;
                    encode_request(&mut bytes, id, key);
                    want.push(ParsedRequest::Valid { id, key });
                }
            }

            // Feed in random chunks (including empty ones) and decode as we go.
            let mut fb = FrameBuf::for_requests();
            let mut got = Vec::new();
            let mut off = 0;
            while off < bytes.len() {
                let take = (rng.below(17) as usize).min(bytes.len() - off);
                fb.extend(&bytes[off..off + take]);
                off += take;
                got.extend(drain_requests(&mut fb));
            }
            assert_eq!(got, want, "round {round}");
            assert_eq!(fb.buffered(), 0, "round {round}");
        }
    }

    /// Response frames survive re-chunking too (the client-side framer).
    #[test]
    fn fuzz_rechunked_response_roundtrip() {
        let mut rng = Xoshiro256::new(42);
        let mut bytes = Vec::new();
        let mut want = Vec::new();
        for id in 0..20u64 {
            if rng.below(4) == 0 {
                encode_error(&mut bytes, id, Status::Dropped);
                want.push((id, Status::Dropped, None));
            } else {
                let resp = ok_response(id as f32);
                encode_response(&mut bytes, id, &resp);
                want.push((id, Status::Ok, Some(resp.data)));
            }
        }
        let mut fb = FrameBuf::for_responses();
        let mut got = Vec::new();
        let mut off = 0;
        while off < bytes.len() {
            let take = (1 + rng.below(700) as usize).min(bytes.len() - off);
            fb.extend(&bytes[off..off + take]);
            off += take;
            while let Some(body) = fb.next_frame().expect("clean stream") {
                got.push(parse_response(body).expect("parseable"));
            }
        }
        assert_eq!(got.len(), want.len());
        for (frame, (id, status, data)) in got.iter().zip(want.iter()) {
            assert_eq!(frame.id, *id);
            assert_eq!(frame.status, *status);
            match (frame.data.as_ref(), data.as_ref()) {
                (Some(a), Some(b)) => assert_eq!(a[..], b[..]),
                (None, None) => {}
                other => panic!("payload mismatch: {other:?}"),
            }
        }
    }
}

//! Client-side drivers for the TCP front.
//!
//! Two shapes:
//! - [`NetClient`] — a simple blocking one-connection client for tests and
//!   examples (send a key, wait for the response).
//! - [`storm`] — the loopback load generator behind E18, `serve --frontend
//!   net` and the CI smoke: thousands of *multiplexed* nonblocking
//!   connections driven by one thread over the same [`poll`] shim the
//!   server uses. Thread-per-connection clients top out around the OS
//!   thread budget; reaching the 10⁴-connection acceptance target needs
//!   the client to be a reactor too.

use super::poll::{fd_of, raise_nofile_limit, Poller};
use super::proto::{self, FrameBuf, ProtoError, ResponseFrame, Status};
use crate::util::monotonic_ns;
use crate::util::rng::Xoshiro256;
use std::io::{self, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Blocking single-connection client
// ---------------------------------------------------------------------------

/// A blocking protocol client over one connection. Supports pipelining
/// ([`send`](NetClient::send) many, then [`recv`](NetClient::recv)) or
/// simple call-response ([`request`](NetClient::request)).
pub struct NetClient {
    stream: TcpStream,
    fb: FrameBuf,
    next_id: u64,
}

impl NetClient {
    pub fn connect(addr: SocketAddr) -> io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient { stream, fb: FrameBuf::for_responses(), next_id: 1 })
    }

    /// Bound subsequent `recv`s (and the reads inside `request`): a lost
    /// reply errors with `WouldBlock`/`TimedOut` instead of blocking forever.
    pub fn set_timeout(&mut self, t: Option<Duration>) -> io::Result<()> {
        self.stream.set_read_timeout(t)
    }

    /// Pipelined send; returns the request id the response will carry.
    pub fn send(&mut self, key: u32) -> io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let mut bytes = Vec::with_capacity(proto::LEN_PREFIX + proto::MAX_REQ_BODY);
        proto::encode_request(&mut bytes, id, key);
        self.stream.write_all(&bytes)?;
        Ok(id)
    }

    /// Push raw bytes down the connection — test hook for malformed and
    /// oversized frames.
    pub fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.stream.write_all(bytes)
    }

    /// Next response frame (they may arrive out of submission order).
    pub fn recv(&mut self) -> io::Result<ResponseFrame> {
        let mut buf = [0u8; 4096];
        loop {
            // Scope the decode so the frame borrow ends before the read.
            let parsed: Option<Result<ResponseFrame, ProtoError>> = match self.fb.next_frame() {
                Ok(Some(body)) => Some(proto::parse_response(body)),
                Ok(None) => None,
                Err(e) => Some(Err(e)),
            };
            match parsed {
                Some(Ok(frame)) => return Ok(frame),
                Some(Err(e)) => {
                    return Err(io::Error::new(ErrorKind::InvalidData, e.to_string()))
                }
                None => {}
            }
            let n = self.stream.read(&mut buf)?;
            if n == 0 {
                return Err(io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "server closed the connection",
                ));
            }
            self.fb.extend(&buf[..n]);
        }
    }

    /// Send one key and wait for its response.
    pub fn request(&mut self, key: u32) -> io::Result<ResponseFrame> {
        let id = self.send(key)?;
        loop {
            let frame = self.recv()?;
            if frame.id == id {
                return Ok(frame);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Multiplexed client storm
// ---------------------------------------------------------------------------

/// Load-generator parameters.
#[derive(Clone, Debug)]
pub struct StormConfig {
    /// Concurrent connections (all open simultaneously).
    pub conns: usize,
    /// Requests issued per connection.
    pub requests_per_conn: usize,
    /// Pipelined in-flight requests per connection.
    pub window: usize,
    /// Key space for the skewed (80%-hot by default) key stream.
    pub key_space: u64,
    pub hot_pct: u32,
    pub seed: u64,
    /// Abort (counting unfinished work as errors) if no response arrives
    /// for this long — a wedged server fails fast instead of hanging.
    pub progress_timeout: Duration,
}

impl Default for StormConfig {
    fn default() -> Self {
        StormConfig {
            conns: 100,
            requests_per_conn: 10,
            window: 4,
            key_space: 10_000,
            hot_pct: 80,
            seed: 42,
            progress_timeout: Duration::from_secs(30),
        }
    }
}

/// What the storm observed. `errors` counts everything that kept a request
/// from a `Status::Ok` response: connect failures, mid-flight closes,
/// protocol violations, `BadRequest`/`Dropped` statuses, and a progress
/// timeout. A healthy server yields `errors == 0` and
/// `received == conns * requests_per_conn`.
#[derive(Clone, Debug, Default)]
pub struct StormReport {
    pub conns: usize,
    pub sent: u64,
    pub received: u64,
    pub errors: u64,
    /// Drive-phase wall time (connect phase excluded).
    pub wall_ns: u64,
    /// Client-observed encode-to-decode latency distribution per OK
    /// response, split by the response's cache-hit flag (the `MuxReport`
    /// shape): log-bucketed histograms (≤6.25% relative error), so a
    /// 10k-connection storm costs constant latency-tracking memory.
    pub hit: crate::util::stats::LogHistogram,
    pub miss: crate::util::stats::LogHistogram,
}

impl StormReport {
    pub fn reqs_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.received as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Hit and miss latencies folded into one distribution.
    pub fn latency_hist(&self) -> crate::util::stats::LogHistogram {
        let mut all = self.hit.clone();
        all.merge(&self.miss);
        all
    }

    /// (p50, p99) latency in ns over all responses; 0.0 when none completed.
    pub fn latency_percentiles(&self) -> (f64, f64) {
        let all = self.latency_hist();
        (all.percentile(50.0) as f64, all.percentile(99.0) as f64)
    }
}

struct StormConn {
    stream: TcpStream,
    fb: FrameBuf,
    /// Encoded request bytes not yet accepted by the kernel.
    out: Vec<u8>,
    out_pos: usize,
    /// `(request id, encode timestamp)` — window-sized, linear scan is fine.
    inflight: Vec<(u64, u64)>,
    /// Requests not yet encoded.
    remaining: usize,
    done: bool,
}

impl StormConn {
    /// Keep the pipeline full: encode fresh requests up to the window.
    fn refill(&mut self, idx: usize, cfg: &StormConfig, rng: &mut Xoshiro256, sent: &mut u64) {
        while self.remaining > 0 && self.inflight.len() < cfg.window {
            self.remaining -= 1;
            let seq = (cfg.requests_per_conn - self.remaining) as u64;
            let id = ((idx as u64) << 32) | seq;
            let key = rng.skewed_key(cfg.key_space, cfg.hot_pct);
            proto::encode_request(&mut self.out, id, key);
            self.inflight.push((id, monotonic_ns()));
            *sent += 1;
        }
    }

    fn take_inflight(&mut self, id: u64) -> Option<u64> {
        let pos = self.inflight.iter().position(|&(i, _)| i == id)?;
        Some(self.inflight.swap_remove(pos).1)
    }
}

/// Drive `cfg.conns` simultaneous multiplexed connections against `addr`
/// until every connection has sent and settled its quota (or progress
/// stalls). Single-threaded; see the module docs for why.
pub fn storm(addr: SocketAddr, cfg: &StormConfig) -> StormReport {
    raise_nofile_limit();
    let mut rng = Xoshiro256::new(cfg.seed);
    let mut report = StormReport { conns: cfg.conns, ..StormReport::default() };
    let mut conns: Vec<StormConn> = Vec::with_capacity(cfg.conns);

    // Connect phase: blocking connects (microseconds each on loopback, and
    // the server's reactor keeps the accept queue drained), brief retries
    // for transient backlog overflow.
    for _ in 0..cfg.conns {
        let mut attempt = 0;
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break Some(s),
                Err(_) if attempt < 3 => {
                    attempt += 1;
                    std::thread::sleep(Duration::from_millis(10 << attempt));
                }
                Err(_) => break None,
            }
        };
        let Some(stream) = stream else {
            report.errors += cfg.requests_per_conn as u64;
            continue;
        };
        let _ = stream.set_nodelay(true);
        if stream.set_nonblocking(true).is_err() {
            report.errors += cfg.requests_per_conn as u64;
            continue;
        }
        conns.push(StormConn {
            stream,
            fb: FrameBuf::for_responses(),
            out: Vec::new(),
            out_pos: 0,
            inflight: Vec::new(),
            remaining: cfg.requests_per_conn,
            done: false,
        });
    }

    // Prime every pipeline before the clock starts.
    for (i, c) in conns.iter_mut().enumerate() {
        c.refill(i, cfg, &mut rng, &mut report.sent);
        // Zero-request storms (connection-count probes) finish immediately.
        c.done = c.remaining == 0 && c.inflight.is_empty();
    }

    let t0 = Instant::now();
    let mut last_progress = Instant::now();
    let mut poller = Poller::new();
    let mut order: Vec<usize> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let mut live = conns.iter().filter(|c| !c.done).count();

    while live > 0 {
        if last_progress.elapsed() >= cfg.progress_timeout {
            // Wedged server (or dropped responses): fail fast.
            for c in conns.iter_mut().filter(|c| !c.done) {
                report.errors += (c.inflight.len() + c.remaining) as u64;
                c.done = true;
            }
            break;
        }

        poller.clear();
        order.clear();
        for (i, c) in conns.iter().enumerate() {
            if c.done {
                continue;
            }
            let want_write = c.out_pos < c.out.len();
            poller.push(fd_of(&c.stream), true, want_write);
            order.push(i);
        }
        if poller.wait(Duration::from_millis(50)).is_err() {
            std::thread::sleep(Duration::from_millis(1));
        }

        for (slot, &i) in order.iter().enumerate() {
            let ready = poller.ready(slot);
            let c = &mut conns[i];
            let mut failed = false;

            if ready.writable && c.out_pos < c.out.len() {
                loop {
                    match c.stream.write(&c.out[c.out_pos..]) {
                        Ok(0) => {
                            failed = true;
                            break;
                        }
                        Ok(n) => {
                            c.out_pos += n;
                            if c.out_pos == c.out.len() {
                                c.out.clear();
                                c.out_pos = 0;
                                break;
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => {
                            failed = true;
                            break;
                        }
                    }
                }
            }

            if !failed && ready.readable {
                'read: loop {
                    match c.stream.read(&mut scratch) {
                        Ok(0) => {
                            failed = true;
                            break;
                        }
                        Ok(n) => {
                            c.fb.extend(&scratch[..n]);
                            loop {
                                let parsed = match c.fb.next_frame() {
                                    Ok(Some(body)) => Some(proto::parse_response(body)),
                                    Ok(None) => None,
                                    Err(e) => Some(Err(e)),
                                };
                                match parsed {
                                    Some(Ok(frame)) => {
                                        let t_enc = c.take_inflight(frame.id);
                                        match (frame.status, t_enc) {
                                            (Status::Ok, Some(t)) => {
                                                report.received += 1;
                                                let lat = monotonic_ns().saturating_sub(t);
                                                if frame.hit {
                                                    report.hit.record(lat);
                                                } else {
                                                    report.miss.record(lat);
                                                }
                                                last_progress = Instant::now();
                                            }
                                            _ => report.errors += 1,
                                        }
                                    }
                                    Some(Err(_)) => {
                                        report.errors += 1;
                                        failed = true;
                                        break 'read;
                                    }
                                    None => break,
                                }
                            }
                            c.refill(i, cfg, &mut rng, &mut report.sent);
                            if n < scratch.len() {
                                break;
                            }
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(_) => {
                            failed = true;
                            break;
                        }
                    }
                }
            }

            if failed {
                report.errors += (c.inflight.len() + c.remaining) as u64;
                c.done = true;
                live -= 1;
            } else if c.remaining == 0 && c.inflight.is_empty() && c.out_pos == c.out.len() {
                c.done = true;
                live -= 1;
            }
        }
    }

    report.wall_ns = t0.elapsed().as_nanos() as u64;
    report
}

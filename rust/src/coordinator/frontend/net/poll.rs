//! Minimal platform readiness shim for the net reactor — std-only.
//!
//! The crate deliberately carries zero dependencies, so there is no `mio`
//! and no `libc` crate to lean on. On Unix, std already links the platform
//! C library; declaring `poll(2)` ourselves adds a symbol reference, not a
//! dependency — this is the "minimal platform poll shim" DESIGN.md §8
//! documents. `poll` (not `epoll`/`kqueue`) keeps the shim to one portable
//! syscall and one `#[repr(C)]` struct; at the 10⁴-connection scale E18
//! targets, the O(n) fd scan is a measured, acceptable cost (≈ a few µs per
//! wakeup) and the reactor rebuilds its interest set each iteration anyway.
//!
//! On non-Unix targets the same [`Poller`] API degrades to a timed sleep
//! that reports every registered source ready: the reactor's nonblocking
//! I/O then simply observes `WouldBlock` on the idle ones. Correct,
//! level-triggered, CPU-hungrier — a fallback, not the product.
//!
//! Also here, for the same "std links libc anyway" reason:
//! [`raise_nofile_limit`] (best-effort `RLIMIT_NOFILE` bump so 10k-socket
//! runs survive the common 1024-fd default) and the UDP self-wake pair the
//! reactor uses as its std-only self-pipe.

use std::io;
use std::net::UdpSocket;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Once};
use std::time::Duration;

/// Raw descriptor handed to [`Poller::push`].
#[cfg(unix)]
pub(crate) type RawFd = std::os::unix::io::RawFd;
#[cfg(not(unix))]
pub(crate) type RawFd = i32;

/// Descriptor of any socket type (listener, stream, UDP wake socket).
#[cfg(unix)]
pub(crate) fn fd_of<T: std::os::unix::io::AsRawFd>(sock: &T) -> RawFd {
    sock.as_raw_fd()
}
#[cfg(not(unix))]
pub(crate) fn fd_of<T>(_sock: &T) -> RawFd {
    0
}

/// Readiness reported for one registered source.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct Readiness {
    pub readable: bool,
    pub writable: bool,
}

#[cfg(unix)]
mod sys {
    /// `struct pollfd` — identical layout on every Unix.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    // `nfds_t` is `unsigned long` on Linux (== usize for every Rust Linux
    // target) and `unsigned int` elsewhere.
    #[cfg(any(target_os = "linux", target_os = "android"))]
    pub type NFds = usize;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    pub type NFds = u32;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NFds, timeout: i32) -> i32;
    }
}

/// A reusable interest set + `poll(2)` wrapper. The backing vector persists
/// across iterations, so steady-state polling allocates nothing.
#[cfg(unix)]
pub(crate) struct Poller {
    fds: Vec<sys::PollFd>,
}

#[cfg(unix)]
impl Poller {
    pub fn new() -> Poller {
        Poller { fds: Vec::new() }
    }

    /// Forget the previous interest set (keeps capacity).
    pub fn clear(&mut self) {
        self.fds.clear();
    }

    /// Register `fd`; returns its slot index for [`ready`](Poller::ready).
    pub fn push(&mut self, fd: RawFd, read: bool, write: bool) -> usize {
        let mut events = 0i16;
        if read {
            events |= sys::POLLIN;
        }
        if write {
            events |= sys::POLLOUT;
        }
        self.fds.push(sys::PollFd { fd, events, revents: 0 });
        self.fds.len() - 1
    }

    /// Block until something is ready or `timeout` elapses. Returns the
    /// number of ready sources (0 on timeout). Retries `EINTR` internally.
    pub fn wait(&mut self, timeout: Duration) -> io::Result<usize> {
        let ms = timeout.as_millis().min(i32::MAX as u128).max(1) as i32;
        loop {
            // SAFETY: `fds` is a live, exclusively-borrowed slice of
            // `#[repr(C)]` PollFd matching the kernel's struct pollfd; the
            // kernel writes only `revents` within the given length.
            let rc = unsafe { sys::poll(self.fds.as_mut_ptr(), self.fds.len() as sys::NFds, ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }

    /// Readiness of the source registered at `idx`. Error/hangup conditions
    /// surface as readability: the subsequent nonblocking read observes the
    /// EOF or error and runs the connection's close path.
    pub fn ready(&self, idx: usize) -> Readiness {
        let re = self.fds[idx].revents;
        Readiness {
            readable: re & (sys::POLLIN | sys::POLLHUP | sys::POLLERR) != 0,
            writable: re & (sys::POLLOUT | sys::POLLERR) != 0,
        }
    }
}

/// Portable fallback: a timed sleep that claims everything is ready. The
/// reactor's nonblocking I/O turns false positives into cheap `WouldBlock`s.
#[cfg(not(unix))]
pub(crate) struct Poller {
    registered: usize,
}

#[cfg(not(unix))]
impl Poller {
    pub fn new() -> Poller {
        Poller { registered: 0 }
    }

    pub fn clear(&mut self) {
        self.registered = 0;
    }

    pub fn push(&mut self, _fd: RawFd, _read: bool, _write: bool) -> usize {
        self.registered += 1;
        self.registered - 1
    }

    pub fn wait(&mut self, timeout: Duration) -> io::Result<usize> {
        std::thread::sleep(timeout.min(Duration::from_millis(1)));
        Ok(self.registered)
    }

    pub fn ready(&self, _idx: usize) -> Readiness {
        Readiness { readable: true, writable: true }
    }
}

// ---------------------------------------------------------------------------
// Reactor wake-up: a connected UDP socket pair as a std-only self-pipe.
// ---------------------------------------------------------------------------

struct WakerInner {
    tx: UdpSocket,
    /// Coalesces wakes: only the `false → true` transition sends a datagram,
    /// so the socket buffer holds at most a handful of bytes regardless of
    /// completion rate.
    pending: AtomicBool,
}

/// Cloneable cross-thread handle that interrupts [`Poller::wait`].
#[derive(Clone)]
pub(crate) struct NetWaker(Arc<WakerInner>);

impl NetWaker {
    pub fn wake(&self) {
        if !self.0.pending.swap(true, Ordering::SeqCst) {
            // A full buffer or transient error just means a wake is already
            // deliverable; losing this byte is fine.
            let _ = self.0.tx.send(&[1]);
        }
    }
}

/// The reactor-owned end of the wake channel.
pub(crate) struct WakePair {
    /// Polled (readable) by the reactor; private to it.
    pub rx: UdpSocket,
    inner: Arc<WakerInner>,
}

impl WakePair {
    pub fn new() -> io::Result<WakePair> {
        let rx = UdpSocket::bind("127.0.0.1:0")?;
        let tx = UdpSocket::bind("127.0.0.1:0")?;
        tx.connect(rx.local_addr()?)?;
        // Only accept wake bytes from our own tx socket.
        rx.connect(tx.local_addr()?)?;
        rx.set_nonblocking(true)?;
        Ok(WakePair {
            rx,
            inner: Arc::new(WakerInner { tx, pending: AtomicBool::new(false) }),
        })
    }

    pub fn waker(&self) -> NetWaker {
        NetWaker(self.inner.clone())
    }

    /// Drain pending wake bytes and re-arm. Call at the TOP of a reactor
    /// iteration, *before* inspecting the work queues: any `wake()` racing
    /// past the re-arm sends a fresh datagram, so the next `poll` returns
    /// immediately instead of sleeping through the work.
    pub fn drain(&self) {
        let mut scratch = [0u8; 16];
        while let Ok(n) = self.rx.recv(&mut scratch) {
            if n == 0 {
                break;
            }
        }
        self.inner.pending.store(false, Ordering::SeqCst);
    }
}

/// Best-effort `RLIMIT_NOFILE` soft→hard bump (Linux). The common 1024-fd
/// soft default would cap a 10k-connection E18 run at ~500 sockets per
/// side; the hard limit on modern distros (and GitHub runners) is ≥ 2²⁰.
/// No-op elsewhere; never fails — a refused bump surfaces later as accept/
/// connect errors, which the metrics count.
pub fn raise_nofile_limit() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        #[cfg(target_os = "linux")]
        {
            #[repr(C)]
            struct Rlimit {
                cur: u64,
                max: u64,
            }
            extern "C" {
                fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
                fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
            }
            const RLIMIT_NOFILE: i32 = 7;
            // SAFETY: Rlimit matches the kernel's struct rlimit (two u64 on
            // 64-bit Linux); both calls only read/write that struct.
            unsafe {
                let mut r = Rlimit { cur: 0, max: 0 };
                if getrlimit(RLIMIT_NOFILE, &mut r) == 0 && r.cur < r.max {
                    r.cur = r.max;
                    let _ = setrlimit(RLIMIT_NOFILE, &r);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wake_interrupts_wait_and_drain_rearms() {
        let pair = WakePair::new().expect("wake pair");
        let waker = pair.waker();
        let mut poller = Poller::new();

        // No wake pending: wait times out with nothing ready on the rx fd.
        poller.clear();
        poller.push(fd_of(&pair.rx), true, false);
        let t0 = std::time::Instant::now();
        let n = poller.wait(Duration::from_millis(40)).unwrap();
        #[cfg(unix)]
        {
            assert_eq!(n, 0);
            assert!(t0.elapsed() >= Duration::from_millis(30));
        }
        #[cfg(not(unix))]
        let _ = (n, t0);

        // Wake from another thread interrupts the next wait promptly.
        let w2 = waker.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            w2.wake();
            // Coalesced: a second wake before drain sends no second byte.
            w2.wake();
        });
        poller.clear();
        poller.push(fd_of(&pair.rx), true, false);
        let n = poller.wait(Duration::from_secs(5)).unwrap();
        assert!(n >= 1);
        #[cfg(unix)]
        assert!(poller.ready(0).readable);
        h.join().unwrap();

        // Drain re-arms: a later wake produces a fresh readable event.
        pair.drain();
        waker.wake();
        poller.clear();
        poller.push(fd_of(&pair.rx), true, false);
        assert!(poller.wait(Duration::from_secs(5)).unwrap() >= 1);
        pair.drain();
    }

    #[test]
    fn raise_nofile_limit_is_idempotent() {
        raise_nofile_limit();
        raise_nofile_limit();
    }
}

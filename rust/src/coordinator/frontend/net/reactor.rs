//! The readiness reactor: one thread driving every nonblocking socket.
//!
//! Single-threaded by design — all connection state (frame buffers,
//! outboxes, pending counts) is owned by this thread and touched without
//! synchronization. The only cross-thread surfaces are the
//! [`NetShared`] completed-frame queue (bridge tasks push, reactor
//! drains), the wake pair, and the listener metrics.
//!
//! Per iteration, in order:
//! 1. drain the wake socket and re-arm it (*before* looking at any queue,
//!    so a racing wake always lands a fresh datagram for the next poll);
//! 2. route completed response frames into their connections' outboxes and
//!    opportunistically flush them;
//! 3. dispatch socket readiness: accept, read → decode → submit, write;
//! 4. resume decoding on connections that were paused by back-pressure and
//!    now have slack (their buffered bytes got no new readiness event);
//! 5. evict idle connections.
//!
//! Back-pressure is two simple caps per connection: decoded-but-unanswered
//! requests (`max_pending_per_conn`) and buffered response bytes
//! (`outbox_cap_bytes`). A connection at either cap is *paused* — the
//! reactor stops pulling bytes off its socket, the kernel receive buffer
//! fills, and TCP flow control pushes back on the client. Nothing is ever
//! dropped server-side; responses already in flight may overshoot the
//! outbox cap transiently, which is why the cap gates reading, not writing.

use super::poll::{fd_of, Poller, WakePair};
use super::proto::{self, ParsedRequest, Status};
use super::{NetConfig, NetShared, Submit};
use std::collections::{HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering::{Relaxed, SeqCst};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Read chunk size; large enough that even a coalesced burst of pipelined
/// requests lands in one syscall.
const READ_CHUNK: usize = 64 * 1024;
/// Max full read chunks per connection per iteration (fairness bound —
/// level-triggered poll re-reports whatever is left).
const READ_ROUNDS: usize = 4;
/// Upper bound on the poll timeout (idle sweeps and drain checks run at
/// least this often even with no socket activity).
const TICK: Duration = Duration::from_millis(250);

struct Conn {
    stream: TcpStream,
    fb: proto::FrameBuf,
    /// Encoded-but-unsent response bytes (frames are contiguous).
    outbox: VecDeque<u8>,
    /// Requests submitted to the router, response not yet routed back.
    pending: usize,
    last_activity: Instant,
}

impl Conn {
    fn new(stream: TcpStream, now: Instant) -> Conn {
        Conn {
            stream,
            fb: proto::FrameBuf::for_requests(),
            outbox: VecDeque::new(),
            pending: 0,
            last_activity: now,
        }
    }

    /// At a back-pressure cap: stop pulling bytes off this socket.
    fn paused(&self, cfg: &NetConfig) -> bool {
        self.pending >= cfg.max_pending_per_conn || self.outbox.len() >= cfg.outbox_cap_bytes
    }

    fn push_frame(&mut self, frame: &[u8]) {
        self.outbox.extend(frame.iter().copied());
    }
}

/// What a poll slot refers to this iteration.
#[derive(Clone, Copy)]
enum Source {
    Wake,
    Listener,
    Conn(u64),
}

pub(crate) struct Reactor {
    /// `None` once shutdown begins (stop accepting) — or if accepts hit a
    /// persistent non-`WouldBlock` error.
    listener: Option<TcpListener>,
    wake: WakePair,
    shared: Arc<NetShared>,
    cfg: NetConfig,
    submit: Submit,
    conns: HashMap<u64, Conn>,
    poller: Poller,
    order: Vec<Source>,
    next_id: u64,
    scratch: Box<[u8]>,
    completed: Vec<(u64, Vec<u8>)>,
    ids: Vec<u64>,
    draining: bool,
    drain_deadline: Option<Instant>,
    last_sweep: Instant,
}

impl Reactor {
    pub fn new(
        listener: TcpListener,
        wake: WakePair,
        shared: Arc<NetShared>,
        cfg: NetConfig,
        submit: Submit,
    ) -> Reactor {
        Reactor {
            listener: Some(listener),
            wake,
            shared,
            cfg,
            submit,
            conns: HashMap::new(),
            poller: Poller::new(),
            order: Vec::new(),
            next_id: 1,
            scratch: vec![0u8; READ_CHUNK].into_boxed_slice(),
            completed: Vec::new(),
            ids: Vec::new(),
            draining: false,
            drain_deadline: None,
            last_sweep: Instant::now(),
        }
    }

    pub fn run(mut self) {
        loop {
            if !self.draining && self.shared.shutdown.load(SeqCst) {
                // Graceful shutdown, phase 1: stop accepting and stop
                // reading, keep fulfilling. In-flight submissions drain
                // through the completed queue into outboxes below.
                self.draining = true;
                self.listener = None;
                self.drain_deadline = Some(Instant::now() + self.cfg.drain_timeout);
            }
            if self.draining {
                let deadline_hit =
                    self.drain_deadline.is_some_and(|dl| Instant::now() >= dl);
                if deadline_hit || self.drained() {
                    break;
                }
            }

            self.build_interest();
            let timeout = if self.draining {
                Duration::from_millis(5)
            } else {
                TICK.min(self.cfg.idle_timeout / 2).max(Duration::from_millis(1))
            };
            if self.poller.wait(timeout).is_err() {
                // poll(2) itself failing (e.g. fd exhaustion mid-rebuild) is
                // not actionable per-connection; briefly yield and retry.
                std::thread::sleep(Duration::from_millis(1));
            }
            self.wake.drain();

            self.route_completed();

            for idx in 0..self.order.len() {
                let r = self.poller.ready(idx);
                match self.order[idx] {
                    Source::Wake => {}
                    Source::Listener => {
                        if r.readable {
                            self.accept_ready();
                        }
                    }
                    Source::Conn(id) => {
                        if r.readable || r.writable {
                            self.service_conn(id, r.readable, r.writable);
                        }
                    }
                }
            }

            self.pump_unpaused();
            self.sweep_idle();
        }
        // Phase 2: everything drained (or the deadline expired) — close.
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            if let Some(c) = self.conns.remove(&id) {
                self.close_conn(c, false);
            }
        }
    }

    /// All responses delivered and flushed: safe to close.
    fn drained(&self) -> bool {
        self.shared.pending.load(SeqCst) == 0
            && self.shared.completed_empty()
            && self.conns.values().all(|c| c.outbox.is_empty())
    }

    fn build_interest(&mut self) {
        self.poller.clear();
        self.order.clear();
        self.poller.push(fd_of(&self.wake.rx), true, false);
        self.order.push(Source::Wake);
        if let Some(l) = &self.listener {
            if self.conns.len() < self.cfg.max_connections {
                self.poller.push(fd_of(l), true, false);
                self.order.push(Source::Listener);
            }
        }
        let draining = self.draining;
        for (&id, c) in &self.conns {
            // Paused/draining connections still register (events = hangup
            // only) so a dead peer is noticed without reading it.
            let read = !draining && !c.paused(&self.cfg);
            let write = !c.outbox.is_empty();
            self.poller.push(fd_of(&c.stream), read, write);
            self.order.push(Source::Conn(id));
        }
    }

    /// Move completed response frames into their connections' outboxes and
    /// flush opportunistically. Frames for connections that died mid-flight
    /// are dropped here — the shard already fulfilled the slot, so gauges
    /// drained; only the bytes are unwanted.
    fn route_completed(&mut self) {
        let mut frames = std::mem::take(&mut self.completed);
        self.shared.take_completed(&mut frames);
        if frames.is_empty() {
            self.completed = frames;
            return;
        }
        self.ids.clear();
        for (cid, frame) in frames.drain(..) {
            if let Some(c) = self.conns.get_mut(&cid) {
                c.pending = c.pending.saturating_sub(1);
                c.push_frame(&frame);
                if self.ids.last() != Some(&cid) {
                    self.ids.push(cid);
                }
            }
        }
        self.completed = frames;
        let touched = std::mem::take(&mut self.ids);
        for &cid in &touched {
            self.service_conn(cid, false, true);
        }
        self.ids = touched;
    }

    fn accept_ready(&mut self) {
        loop {
            if self.conns.len() >= self.cfg.max_connections {
                return;
            }
            let accepted = match &self.listener {
                Some(l) => l.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let id = self.next_id;
                    self.next_id += 1;
                    self.conns.insert(id, Conn::new(stream, Instant::now()));
                    crate::trace::event!("net.accept", id);
                    self.shared.metrics.accepted.fetch_add(1, Relaxed);
                    self.shared.metrics.active.fetch_add(1, Relaxed);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                // Transient resource errors (EMFILE & friends): leave the
                // backlog alone this iteration; poll re-reports.
                Err(_) => return,
            }
        }
    }

    fn service_conn(&mut self, id: u64, readable: bool, writable: bool) {
        let Some(mut c) = self.conns.remove(&id) else { return };
        let mut alive = true;
        if writable && !c.outbox.is_empty() {
            alive = self.flush_outbox(&mut c);
        }
        if alive && readable && !self.draining {
            alive = self.read_conn(&mut c, id);
        }
        if alive {
            self.conns.insert(id, c);
        } else {
            self.close_conn(c, false);
        }
    }

    fn read_conn(&mut self, c: &mut Conn, id: u64) -> bool {
        for _round in 0..READ_ROUNDS {
            if c.paused(&self.cfg) {
                return true;
            }
            match c.stream.read(&mut self.scratch) {
                // EOF: the peer is gone; buffered requests and queued
                // responses are moot. In-flight submissions still fulfil
                // their slots — route_completed drops the orphan frames.
                Ok(0) => return false,
                Ok(n) => {
                    crate::trace::event!("net.read", n);
                    self.shared.metrics.bytes_in.fetch_add(n as u64, Relaxed);
                    c.last_activity = Instant::now();
                    c.fb.extend(&self.scratch[..n]);
                    if !self.pump(c, id) {
                        return false;
                    }
                    if n < self.scratch.len() {
                        return true;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        true
    }

    /// Decode buffered frames up to the back-pressure caps. Returns `false`
    /// on a fatal protocol error (connection must close).
    fn pump(&mut self, c: &mut Conn, id: u64) -> bool {
        loop {
            if c.paused(&self.cfg) {
                return true;
            }
            let parsed = match c.fb.next_frame() {
                Ok(Some(body)) => proto::parse_request(body),
                Ok(None) => return true,
                Err(_oversized) => {
                    self.shared.metrics.protocol_errors.fetch_add(1, Relaxed);
                    return false;
                }
            };
            match parsed {
                Ok(ParsedRequest::Valid { id: rid, key }) => {
                    c.pending += 1;
                    self.shared.pending.fetch_add(1, SeqCst);
                    (self.submit)(id, rid, key);
                }
                Ok(ParsedRequest::Invalid { id: rid }) => {
                    // Answerable: BadRequest on the same connection.
                    self.shared.metrics.protocol_errors.fetch_add(1, Relaxed);
                    let mut frame = Vec::new();
                    proto::encode_error(&mut frame, rid, Status::BadRequest);
                    c.push_frame(&frame);
                }
                Err(_truncated) => {
                    self.shared.metrics.protocol_errors.fetch_add(1, Relaxed);
                    return false;
                }
            }
        }
    }

    fn flush_outbox(&mut self, c: &mut Conn) -> bool {
        while !c.outbox.is_empty() {
            let (head, tail) = c.outbox.as_slices();
            let chunk = if head.is_empty() { tail } else { head };
            match c.stream.write(chunk) {
                Ok(0) => return false,
                Ok(n) => {
                    crate::trace::event!("net.write", n);
                    c.outbox.drain(..n);
                    self.shared.metrics.bytes_out.fetch_add(n as u64, Relaxed);
                    c.last_activity = Instant::now();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        true
    }

    /// Connections paused earlier may have gained slack from completions
    /// without any new socket readiness; resume decoding their buffer.
    fn pump_unpaused(&mut self) {
        if self.draining {
            return;
        }
        self.ids.clear();
        for (&id, c) in &self.conns {
            if c.fb.buffered() > 0 && !c.paused(&self.cfg) {
                self.ids.push(id);
            }
        }
        let ids = std::mem::take(&mut self.ids);
        for &id in &ids {
            if let Some(mut c) = self.conns.remove(&id) {
                if self.pump(&mut c, id) {
                    self.conns.insert(id, c);
                } else {
                    self.close_conn(c, false);
                }
            }
        }
        self.ids = ids;
    }

    fn sweep_idle(&mut self) {
        let now = Instant::now();
        let tick = (self.cfg.idle_timeout / 4).max(Duration::from_millis(10));
        if now.duration_since(self.last_sweep) < tick {
            return;
        }
        self.last_sweep = now;
        self.ids.clear();
        for (&id, c) in &self.conns {
            if now.duration_since(c.last_activity) >= self.cfg.idle_timeout {
                self.ids.push(id);
            }
        }
        let ids = std::mem::take(&mut self.ids);
        for &id in &ids {
            if let Some(c) = self.conns.remove(&id) {
                self.close_conn(c, true);
            }
        }
        self.ids = ids;
    }

    fn close_conn(&mut self, c: Conn, evicted: bool) {
        self.shared.metrics.active.fetch_sub(1, Relaxed);
        self.shared.metrics.closed.fetch_add(1, Relaxed);
        if evicted {
            crate::trace::event!("net.evict");
            self.shared.metrics.idle_evicted.fetch_add(1, Relaxed);
        }
        drop(c);
    }
}

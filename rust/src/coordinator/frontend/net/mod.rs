//! `frontend::net` — a real TCP serving front over the completion slots.
//!
//! The serving claim finally crosses a socket: a [`NetServer`] binds a
//! listener, a single reactor thread ([`reactor`]) drives every nonblocking
//! connection through the tiny length-prefixed protocol ([`proto`]), and
//! each decoded request becomes one `Router::submit_async` bridge task on a
//! small internal executor. When the shard worker (or batcher) fulfils the
//! completion slot, the bridge task encodes the response, enqueues it on
//! the owning connection's outbox, and wakes the reactor — the shard
//! workers, executor, and mux layers are untouched, exactly the seam
//! DESIGN.md §6 planned and §8 documents.
//!
//! Layering per request:
//!
//! ```text
//! socket bytes ──reactor──▶ FrameBuf ──parse──▶ submit_async ─┐
//!                                                   (executor task awaits)
//! socket bytes ◀──reactor◀── outbox ◀── NetShared::complete ◀─┘
//! ```
//!
//! Per-listener metrics (accepted/active/closed connections, protocol
//! errors, bytes in/out, idle evictions) aggregate process-wide through
//! [`net_stats`] and ride [`Router::metrics`]
//! (`crate::coordinator::metrics::MetricsSnapshot`) like the magazine
//! counters do — set once post-rollup, never summed per shard.

pub mod client;
pub(crate) mod poll;
pub mod proto;
mod reactor;

pub use poll::raise_nofile_limit;

use crate::coordinator::Router;
use crate::reclaim::Reclaimer;
use crate::runtime::exec::Executor;
use poll::{NetWaker, WakePair};
use reactor::Reactor;
use std::io;
use std::net::{Ipv4Addr, SocketAddr, SocketAddrV4, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::Duration;

/// Listener configuration (defaults favor tests/benches: ephemeral
/// loopback port, 8 bridge-executor threads — the E18 budget).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`NetServer::local_addr`]).
    pub listen: SocketAddr,
    /// Threads in the internal completion-bridge executor.
    pub exec_threads: usize,
    /// Accept gate: above this many live connections the listener is
    /// deregistered until some close (backlog, then kernel, absorb the rest).
    pub max_connections: usize,
    /// Per-connection cap on decoded-but-unanswered requests; at the cap
    /// the reactor stops reading that socket (TCP back-pressure).
    pub max_pending_per_conn: usize,
    /// Per-connection cap on buffered response bytes; same pause behavior.
    /// In-flight completions may transiently overshoot — responses are
    /// never dropped.
    pub outbox_cap_bytes: usize,
    /// Connections with no successful read/write for this long are evicted.
    pub idle_timeout: Duration,
    /// Graceful-shutdown bound: how long to wait for in-flight completions
    /// to drain and outboxes to flush before closing anyway.
    pub drain_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            listen: SocketAddr::V4(SocketAddrV4::new(Ipv4Addr::LOCALHOST, 0)),
            exec_threads: 8,
            max_connections: 65_536,
            max_pending_per_conn: 128,
            outbox_cap_bytes: 256 * 1024,
            idle_timeout: Duration::from_secs(60),
            drain_timeout: Duration::from_secs(10),
        }
    }
}

/// Live per-listener counters (atomics shared between the reactor and
/// metric readers).
#[derive(Default)]
pub struct NetMetrics {
    pub accepted: AtomicU64,
    /// Gauge: currently-open connections.
    pub active: AtomicU64,
    pub closed: AtomicU64,
    pub protocol_errors: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub idle_evicted: AtomicU64,
}

/// Point-in-time copy of [`NetMetrics`], also the process-wide aggregate
/// [`net_stats`] returns.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    pub accepted: u64,
    pub active: u64,
    pub closed: u64,
    pub protocol_errors: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub idle_evicted: u64,
}

impl NetMetrics {
    pub fn snapshot(&self) -> NetStats {
        NetStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            closed: self.closed.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            idle_evicted: self.idle_evicted.load(Ordering::Relaxed),
        }
    }
}

impl NetStats {
    fn add(&mut self, other: NetStats) {
        self.accepted += other.accepted;
        self.active += other.active;
        self.closed += other.closed;
        self.protocol_errors += other.protocol_errors;
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
        self.idle_evicted += other.idle_evicted;
    }
}

/// Every live listener's metrics, for the process-wide rollup. `Weak` so a
/// dropped server unregisters itself implicitly (pruned on read).
fn registry() -> &'static Mutex<Vec<Weak<NetMetrics>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Weak<NetMetrics>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Process-wide listener totals across all live [`NetServer`]s — consumed
/// once per [`Router::metrics`] rollup (the `magazine_stats` pattern).
pub fn net_stats() -> NetStats {
    let mut total = NetStats::default();
    let mut reg = registry().lock().unwrap();
    reg.retain(|w| match w.upgrade() {
        Some(m) => {
            total.add(m.snapshot());
            true
        }
        None => false,
    });
    total
}

/// Reactor → router bridge: `(connection id, request id, key)`.
pub(crate) type Submit = Box<dyn Fn(u64, u64, u32) + Send>;

/// State shared between the reactor thread and the bridge tasks.
pub(crate) struct NetShared {
    /// Encoded response frames awaiting routing: `(connection id, frame)`.
    completed: Mutex<Vec<(u64, Vec<u8>)>>,
    /// Requests submitted but not yet pushed to `completed`. Incremented by
    /// the reactor at submit; decremented by [`complete`](Self::complete)
    /// *after* the push, so `pending == 0` implies every frame is visible.
    pub(crate) pending: AtomicU64,
    pub(crate) shutdown: AtomicBool,
    pub(crate) metrics: Arc<NetMetrics>,
    waker: NetWaker,
}

impl NetShared {
    /// Called by a bridge task when its completion slot fulfils.
    pub(crate) fn complete(&self, conn: u64, frame: Vec<u8>) {
        self.completed.lock().unwrap().push((conn, frame));
        self.pending.fetch_sub(1, Ordering::SeqCst);
        self.waker.wake();
    }

    pub(crate) fn take_completed(&self, into: &mut Vec<(u64, Vec<u8>)>) {
        let mut q = self.completed.lock().unwrap();
        into.append(&mut q);
    }

    pub(crate) fn completed_empty(&self) -> bool {
        self.completed.lock().unwrap().is_empty()
    }

    fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.waker.wake();
    }
}

/// A live TCP serving front over one [`Router`].
///
/// Owns the reactor thread and the completion-bridge executor; holds the
/// router alive (via the submit closure) until shutdown. Dropping the
/// server shuts it down gracefully: accepts stop, in-flight completions
/// drain (bounded by [`NetConfig::drain_timeout`]), outboxes flush, then
/// every connection and the listener close and both thread pools join.
pub struct NetServer {
    shared: Arc<NetShared>,
    reactor: Option<std::thread::JoinHandle<()>>,
    exec: Option<Arc<Executor>>,
    local_addr: SocketAddr,
    metrics: Arc<NetMetrics>,
}

impl NetServer {
    /// Bind `cfg.listen` and start serving `router`.
    pub fn start<R: Reclaimer>(router: Arc<Router<R>>, cfg: NetConfig) -> io::Result<NetServer> {
        raise_nofile_limit();
        let listener = TcpListener::bind(cfg.listen)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let wake = WakePair::new()?;
        let metrics = Arc::new(NetMetrics::default());
        registry().lock().unwrap().push(Arc::downgrade(&metrics));
        let shared = Arc::new(NetShared {
            completed: Mutex::new(Vec::new()),
            pending: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            metrics: metrics.clone(),
            waker: wake.waker(),
        });
        let exec = Arc::new(Executor::new(cfg.exec_threads));
        let submit: Submit = {
            let shared = shared.clone();
            let exec = exec.clone();
            Box::new(move |conn, rid, key| {
                let fut = router.submit_async(key);
                let shared = shared.clone();
                // Fire-and-forget: dropping the JoinHandle detaches. The
                // task is the completion slot's waiter; fulfilment (or
                // router shutdown) resolves the future, the task encodes
                // and hands the frame back to the reactor.
                drop(exec.spawn(async move {
                    let mut frame = Vec::new();
                    match fut.await {
                        Ok(resp) => proto::encode_response(&mut frame, rid, &resp),
                        Err(_) => proto::encode_error(&mut frame, rid, proto::Status::Dropped),
                    }
                    shared.complete(conn, frame);
                }));
            })
        };
        let reactor = Reactor::new(listener, wake, shared.clone(), cfg, submit);
        let handle = std::thread::Builder::new()
            .name("emr-net-reactor".into())
            .spawn(move || reactor.run())?;
        Ok(NetServer {
            shared,
            reactor: Some(handle),
            exec: Some(exec),
            local_addr,
            metrics,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// This listener's counters (process-wide totals: [`net_stats`]).
    pub fn metrics(&self) -> NetStats {
        self.metrics.snapshot()
    }

    /// Graceful shutdown; idempotent, also run by `Drop`. Blocks until the
    /// reactor has drained (or timed out) and both thread pools joined.
    pub fn shutdown(&mut self) {
        self.shared.request_shutdown();
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
        // The executor drops last: any bridge task that outlived the drain
        // deadline is cancelled here (dropping a SubmitFuture mid-flight is
        // safe — DESIGN.md §6), and its pool threads join.
        self.exec = None;
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

//! The **async submission front-end** (DESIGN.md §6): the completion-slot
//! handshake between a submitter and the shard that answers it, plus the
//! connection multiplexer ([`mux`]) that drives thousands of logical
//! clients per executor thread.
//!
//! ## The completion handshake
//!
//! Every submitted request owns one **completion slot** shared between two
//! sides:
//!
//! * the **fulfiller** ([`CompletionSender`], crate-internal) travels
//!   inside the queued `Request` through the shard worker and — on a miss —
//!   the router's batcher. Exactly one of two things happens to it:
//!   [`CompletionSender::send`] publishes the [`Response`] and wakes the
//!   waiting task, or it is dropped (shutdown drain, engine failure,
//!   batcher gone) which **closes** the slot so the waiter resolves with an
//!   error instead of hanging. This replaces the seed's one-shot
//!   `mpsc::Receiver` per request.
//! * the **waiter** is either a [`SubmitFuture`] (parked on a
//!   [`std::task::Waker`], driven by [`crate::runtime::exec`]) or its
//!   blocking wrapper [`SubmitHandle`] (`recv_timeout` over the same
//!   future, so `Router::submit` is literally `submit_async` + block-on).
//!
//! The population of open slots per shard — its *completion queue* — is
//! observable as the `in_flight` gauge in
//! [`crate::coordinator::metrics::MetricsSnapshot`]; E17 plots it as the
//! back-pressure signal.
//!
//! ## Cancellation
//!
//! Dropping a [`SubmitFuture`] mid-flight is safe and cheap: the slot is
//! reference-counted, so the shard worker simply fulfils a slot nobody
//! reads and the memory is freed when the fulfiller side drops. Nothing is
//! leaked and the shard worker never blocks on an abandoned waiter (see
//! `rust/tests/async_frontend.rs` for the churn test).

pub mod mux;
pub mod net;

use super::Response;
use crate::anyhow;
use crate::runtime::exec;
use crate::util::error::Result;
use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Waker};
use std::time::{Duration, Instant};

/// How long [`SubmitHandle::recv`] waits before declaring the reply lost.
/// Generous: a healthy fleet answers in microseconds-to-milliseconds; only
/// a wedged shard or a dropped reply ever reaches this.
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// Which request front-end drives a serving load (`repro serve` and the
/// `compute_cache` example share this, so the accepted CLI names — and any
/// future variant, e.g. a network listener — live in one place).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Frontend {
    /// One blocking OS thread per client (the seed's shape).
    Thread,
    /// Logical clients multiplexed as tasks over [`mux`] (DESIGN.md §6).
    Async,
    /// Real TCP connections through the [`net`] reactor (DESIGN.md §8).
    Net,
}

impl Frontend {
    /// The accepted `--frontend` names, for error messages — keep in sync
    /// with [`parse`](Frontend::parse).
    pub const NAMES: &'static str = "thread|async|net";

    /// Parse a CLI `--frontend` value: `thread` (default) | `async` | `net`.
    pub fn parse(s: &str) -> Option<Frontend> {
        match s.to_ascii_lowercase().as_str() {
            "thread" | "threads" => Some(Frontend::Thread),
            "async" | "mux" => Some(Frontend::Async),
            "net" | "tcp" | "socket" => Some(Frontend::Net),
            _ => None,
        }
    }
}

struct SlotState {
    response: Option<Response>,
    waker: Option<Waker>,
    /// Set when the fulfiller dropped without answering (or the response
    /// was already consumed): the waiter resolves with an error.
    closed: bool,
}

/// One request's completion slot (shared, reference-counted).
struct Slot {
    state: Mutex<SlotState>,
}

impl Slot {
    fn fulfil(&self, response: Option<Response>) {
        let waker = {
            let mut s = self.state.lock().unwrap();
            match response {
                Some(r) => s.response = Some(r),
                None => s.closed = true,
            }
            s.waker.take()
        };
        // Wake outside the slot lock: the waker may push onto an executor
        // run queue or unpark a thread.
        if let Some(w) = waker {
            w.wake();
        }
    }
}

/// Fulfiller side of a completion slot; lives inside the queued `Request`.
/// Dropping it without [`send`](Self::send) closes the slot (the waiter
/// observes "server dropped request" instead of blocking forever).
pub(crate) struct CompletionSender {
    slot: Arc<Slot>,
    sent: bool,
}

impl CompletionSender {
    /// Publish the response and wake the waiting task.
    pub(crate) fn send(mut self, response: Response) {
        self.sent = true;
        self.slot.fulfil(Some(response));
    }
}

impl Drop for CompletionSender {
    fn drop(&mut self) {
        if !self.sent {
            self.slot.fulfil(None);
        }
    }
}

/// Create a linked (fulfiller, waiter) pair for one request.
pub(crate) fn completion_pair() -> (CompletionSender, SubmitFuture) {
    let slot = Arc::new(Slot {
        state: Mutex::new(SlotState { response: None, waker: None, closed: false }),
    });
    (CompletionSender { slot: slot.clone(), sent: false }, SubmitFuture { slot })
}

/// Waiter side of a submitted request: resolves to the [`Response`] when a
/// shard worker (hit) or the batcher (computed miss) fulfils the slot, or
/// to an error when the server drops the request (shutdown, engine
/// failure). Returned by `Router::submit_async`; safe to drop mid-flight
/// (see the module docs on cancellation).
pub struct SubmitFuture {
    slot: Arc<Slot>,
}

impl SubmitFuture {
    /// A future that is already closed (submit raced a shutdown): polling
    /// or `recv`-ing it errors immediately instead of waiting.
    pub(crate) fn rejected() -> Self {
        Self {
            slot: Arc::new(Slot {
                state: Mutex::new(SlotState { response: None, waker: None, closed: true }),
            }),
        }
    }

    /// Non-blocking probe: `Some` once the slot has been fulfilled or
    /// closed. Consumes the response on success.
    pub fn try_take(&mut self) -> Option<Result<Response>> {
        let mut s = self.slot.state.lock().unwrap();
        if let Some(r) = s.response.take() {
            s.closed = true; // fused: a second take errors rather than hangs
            return Some(Ok(r));
        }
        if s.closed {
            return Some(Err(anyhow!("server dropped request")));
        }
        None
    }
}

impl Future for SubmitFuture {
    type Output = Result<Response>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let mut s = self.slot.state.lock().unwrap();
        if let Some(r) = s.response.take() {
            s.closed = true; // fused: polling after Ready errors, never hangs
            return Poll::Ready(Ok(r));
        }
        if s.closed {
            return Poll::Ready(Err(anyhow!("server dropped request")));
        }
        // Register/refresh the waker (the task may migrate between polls).
        s.waker = Some(cx.waker().clone());
        Poll::Pending
    }
}

/// Blocking wrapper over a [`SubmitFuture`] — what `Router::submit`
/// returns. Unlike the seed's bare `mpsc::Receiver`, every wait is
/// deadline-bounded: a lost reply surfaces as a timeout error, never an
/// eternal block.
pub struct SubmitHandle {
    fut: SubmitFuture,
}

impl SubmitHandle {
    pub(crate) fn new(fut: SubmitFuture) -> Self {
        Self { fut }
    }

    /// Wait for the response with the [`DEFAULT_RECV_TIMEOUT`].
    pub fn recv(self) -> Result<Response> {
        self.recv_timeout(DEFAULT_RECV_TIMEOUT)
    }

    /// Wait for the response, giving up after `timeout`. On timeout the
    /// in-flight request is abandoned (the shard still answers its slot;
    /// nothing leaks — module docs on cancellation).
    pub fn recv_timeout(self, timeout: Duration) -> Result<Response> {
        match exec::block_on_deadline(self.fut, Instant::now() + timeout) {
            Some(r) => r,
            None => {
                Err(anyhow!("request timed out after {timeout:?} (reply lost or shard wedged)"))
            }
        }
    }

    /// Non-blocking probe: `Some` once the response (or the drop error) is
    /// available.
    pub fn try_recv(&mut self) -> Option<Result<Response>> {
        self.fut.try_take()
    }

    /// The underlying future, for callers that started blocking and want
    /// to finish async.
    pub fn into_future(self) -> SubmitFuture {
        self.fut
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::DIM;

    fn resp() -> Response {
        Response { data: Box::new([0.5; DIM]), hit: true, latency_ns: 1 }
    }

    #[test]
    fn frontend_parse_accepts_every_variant_and_rejects_junk() {
        for (s, want) in [
            ("thread", Frontend::Thread),
            ("threads", Frontend::Thread),
            ("THREAD", Frontend::Thread),
            ("async", Frontend::Async),
            ("mux", Frontend::Async),
            ("net", Frontend::Net),
            ("tcp", Frontend::Net),
            ("socket", Frontend::Net),
            ("Net", Frontend::Net),
        ] {
            assert_eq!(Frontend::parse(s), Some(want), "{s}");
        }
        for s in ["", "sync", "epoll", "thread ", "network"] {
            assert_eq!(Frontend::parse(s), None, "{s:?}");
        }
        // The error-message listing names every canonical variant.
        for name in Frontend::NAMES.split('|') {
            assert!(Frontend::parse(name).is_some(), "NAMES entry {name:?} must parse");
        }
    }

    #[test]
    fn send_then_recv() {
        let (tx, fut) = completion_pair();
        tx.send(resp());
        let got = SubmitHandle::new(fut).recv().unwrap();
        assert!(got.hit);
        assert_eq!(got.data[0], 0.5);
    }

    #[test]
    fn dropped_sender_closes_the_slot() {
        let (tx, fut) = completion_pair();
        drop(tx);
        assert!(SubmitHandle::new(fut).recv().is_err());
    }

    #[test]
    fn recv_timeout_bounds_a_lost_reply() {
        let (_tx, fut) = completion_pair(); // sender alive but never sends
        let t0 = Instant::now();
        let err = SubmitHandle::new(fut).recv_timeout(Duration::from_millis(30));
        assert!(err.is_err());
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert!(err.unwrap_err().to_string().contains("timed out"));
    }

    #[test]
    fn fulfil_from_another_thread_wakes_the_waiter() {
        let (tx, fut) = completion_pair();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            tx.send(resp());
        });
        let got = SubmitHandle::new(fut).recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(got.hit);
        t.join().unwrap();
    }

    #[test]
    fn try_recv_probes_without_blocking() {
        let (tx, fut) = completion_pair();
        let mut h = SubmitHandle::new(fut);
        assert!(h.try_recv().is_none());
        tx.send(resp());
        assert!(matches!(h.try_recv(), Some(Ok(_))));
        // Fused: a second take errors instead of hanging.
        assert!(matches!(h.try_recv(), Some(Err(_))));
    }

    #[test]
    fn rejected_future_errors_immediately() {
        let t0 = Instant::now();
        assert!(SubmitHandle::new(SubmitFuture::rejected()).recv().is_err());
        assert!(t0.elapsed() < Duration::from_secs(1), "rejection must not wait the timeout");
    }

    #[test]
    fn dropping_the_future_midflight_is_harmless() {
        let (tx, fut) = completion_pair();
        drop(fut);
        tx.send(resp()); // fulfilling an abandoned slot is a no-op
    }
}

//! Coordinator metrics: request counters, batch shape, and the paper's
//! reclamation-efficiency signal (unreclaimed nodes) sampled per snapshot.
//!
//! Since the router refactor the counters live at two levels: each
//! [`super::Shard`] owns a [`Metrics`] for its request/hit/miss/eviction
//! counters (snapshotted with its *own domain's* unreclaimed count via
//! [`Metrics::snapshot_with`]), and the [`super::Router`] owns one for the
//! fleet-wide batch counters, rolling shard snapshots up with
//! [`MetricsSnapshot::add_counters`].

use crate::util::cache_pad::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};

/// Live counters (relaxed; exact at quiescence).
#[derive(Default)]
pub struct Metrics {
    pub requests: CachePadded<AtomicU64>,
    pub hits: CachePadded<AtomicU64>,
    pub misses: CachePadded<AtomicU64>,
    pub batches: CachePadded<AtomicU64>,
    pub batched_keys: CachePadded<AtomicU64>,
    pub evictions_observed: CachePadded<AtomicU64>,
}

/// Point-in-time view of the [`Metrics`].
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub hits: u64,
    pub misses: u64,
    pub batches: u64,
    pub batched_keys: u64,
    pub unreclaimed_nodes: u64,
}

impl Metrics {
    /// Snapshot with the **process-wide** unreclaimed count (the pre-shard
    /// behaviour; diagnostics that don't care about domain scoping).
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.snapshot_with(crate::alloc::unreclaimed())
    }

    /// Snapshot with an explicitly scoped unreclaimed count (a shard passes
    /// its own domain's, the router an aggregate over distinct domains).
    pub fn snapshot_with(&self, unreclaimed_nodes: u64) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_keys: self.batched_keys.load(Ordering::Relaxed),
            unreclaimed_nodes,
        }
    }
}

impl MetricsSnapshot {
    /// Sum another snapshot's **counters** into this one (requests, hits,
    /// misses, batches, batched_keys). `unreclaimed_nodes` is deliberately
    /// left untouched: domains may be shared between shards, so the caller
    /// must aggregate it over *distinct* domains (see `Router::metrics`).
    pub fn add_counters(&mut self, other: &MetricsSnapshot) {
        self.requests += other.requests;
        self.hits += other.hits;
        self.misses += other.misses;
        self.batches += other.batches;
        self.batched_keys += other.batched_keys;
    }

    /// Cache hit rate in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests as f64
        }
    }

    /// Mean executed batch size.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_keys as f64 / self.batches as f64
        }
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} hits={} ({:.1}%) misses={} batches={} (mean size {:.1}) unreclaimed={}",
            self.requests,
            self.hits,
            self.hit_rate() * 100.0,
            self.misses,
            self.batches,
            self.mean_batch(),
            self.unreclaimed_nodes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_math() {
        let m = Metrics::default();
        m.requests.store(10, Ordering::Relaxed);
        m.hits.store(7, Ordering::Relaxed);
        m.misses.store(3, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        m.batched_keys.store(6, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.hit_rate() - 0.7).abs() < 1e-9);
        assert!((s.mean_batch() - 3.0).abs() < 1e-9);
        let text = s.to_string();
        assert!(text.contains("requests=10"));
    }

    #[test]
    fn empty_snapshot_is_safe() {
        let s = MetricsSnapshot::default();
        assert_eq!(s.hit_rate(), 0.0);
        assert_eq!(s.mean_batch(), 0.0);
    }

    #[test]
    fn rollup_sums_counters_but_not_unreclaimed() {
        let m = Metrics::default();
        m.requests.store(5, Ordering::Relaxed);
        m.hits.store(2, Ordering::Relaxed);
        let a = m.snapshot_with(100);
        assert_eq!(a.unreclaimed_nodes, 100, "scoped count passes through");
        let mut agg = MetricsSnapshot::default();
        agg.add_counters(&a);
        agg.add_counters(&a);
        assert_eq!(agg.requests, 10);
        assert_eq!(agg.hits, 4);
        assert_eq!(agg.unreclaimed_nodes, 0, "caller owns unreclaimed aggregation");
    }
}

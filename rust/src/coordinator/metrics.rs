//! Coordinator metrics: request counters, batch shape, and the paper's
//! reclamation-efficiency signal (unreclaimed nodes) sampled per snapshot.

use crate::util::cache_pad::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};

/// Live counters (relaxed; exact at quiescence).
#[derive(Default)]
pub struct Metrics {
    pub requests: CachePadded<AtomicU64>,
    pub hits: CachePadded<AtomicU64>,
    pub misses: CachePadded<AtomicU64>,
    pub batches: CachePadded<AtomicU64>,
    pub batched_keys: CachePadded<AtomicU64>,
    pub evictions_observed: CachePadded<AtomicU64>,
}

/// Point-in-time view of the [`Metrics`].
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub hits: u64,
    pub misses: u64,
    pub batches: u64,
    pub batched_keys: u64,
    pub unreclaimed_nodes: u64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_keys: self.batched_keys.load(Ordering::Relaxed),
            unreclaimed_nodes: crate::alloc::unreclaimed(),
        }
    }
}

impl MetricsSnapshot {
    /// Cache hit rate in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests as f64
        }
    }

    /// Mean executed batch size.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_keys as f64 / self.batches as f64
        }
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} hits={} ({:.1}%) misses={} batches={} (mean size {:.1}) unreclaimed={}",
            self.requests,
            self.hits,
            self.hit_rate() * 100.0,
            self.misses,
            self.batches,
            self.mean_batch(),
            self.unreclaimed_nodes,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_math() {
        let m = Metrics::default();
        m.requests.store(10, Ordering::Relaxed);
        m.hits.store(7, Ordering::Relaxed);
        m.misses.store(3, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        m.batched_keys.store(6, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.hit_rate() - 0.7).abs() < 1e-9);
        assert!((s.mean_batch() - 3.0).abs() < 1e-9);
        let text = s.to_string();
        assert!(text.contains("requests=10"));
    }

    #[test]
    fn empty_snapshot_is_safe() {
        let s = MetricsSnapshot::default();
        assert_eq!(s.hit_rate(), 0.0);
        assert_eq!(s.mean_batch(), 0.0);
    }
}

//! Coordinator metrics: request counters, batch shape, and the paper's
//! reclamation-efficiency signal (unreclaimed nodes) sampled per snapshot.
//!
//! Since the router refactor the counters live at two levels: each
//! [`super::Shard`] owns a [`Metrics`] for its request/hit/miss/eviction
//! counters (snapshotted with its *own domain's* unreclaimed count via
//! [`Metrics::snapshot_with`]), and the [`super::Router`] owns one
//! [`GroupMetrics`] per **engine group** (DESIGN.md §9) for that group's
//! batcher — dispatches, batch occupancy, engine errors — rolled up (summed
//! over groups) into the fleet [`MetricsSnapshot`] alongside the shard
//! counters ([`MetricsSnapshot::add_counters`]), and exposed per group as
//! [`GroupSnapshot`]s via `Router::group_metrics`.

use crate::util::cache_pad::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Live counters (relaxed; exact at quiescence), plus two **gauges** the
/// async front-end exposes for back-pressure plots (E17):
///
/// * `queue_depth` — requests sitting in the shard's queue right now
///   (submitted, not yet dequeued by a worker);
/// * `in_flight` — open completion slots: requests submitted and not yet
///   answered or dropped. Tracked by RAII tokens riding inside each
///   request, so every exit path (hit, computed, shutdown drain, engine
///   failure) decrements exactly once. A cancelled `SubmitFuture` does
///   *not* decrement — its abandoned request still occupies the pipeline
///   until a worker answers it, which is exactly what back-pressure
///   should see.
#[derive(Default)]
pub struct Metrics {
    pub requests: CachePadded<AtomicU64>,
    pub hits: CachePadded<AtomicU64>,
    pub misses: CachePadded<AtomicU64>,
    pub batches: CachePadded<AtomicU64>,
    pub batched_keys: CachePadded<AtomicU64>,
    pub evictions_observed: CachePadded<AtomicU64>,
    pub queue_depth: CachePadded<AtomicU64>,
    in_flight: Arc<CachePadded<AtomicU64>>,
}

impl Metrics {
    /// Open an in-flight token: the gauge rises now and falls when the
    /// token drops (wherever the request dies).
    pub(crate) fn in_flight_token(&self) -> InFlightToken {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        InFlightToken(self.in_flight.clone())
    }

    /// Requests currently in flight (submitted, unanswered).
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }
}

/// RAII leg of the `in_flight` gauge (see [`Metrics`]); carried by each
/// queued request.
pub(crate) struct InFlightToken(Arc<CachePadded<AtomicU64>>);

impl Drop for InFlightToken {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Live counters of one **engine group**'s batcher (DESIGN.md §9): batch
/// dispatches, batch occupancy (distinct keys per dispatch —
/// `batched_keys / batches` is the group's mean batch size), and engine
/// failures. One instance per group, owned by the [`super::Router`],
/// written only by that group's batcher thread.
#[derive(Default)]
pub struct GroupMetrics {
    /// Batches this group's engine dispatched.
    pub batches: CachePadded<AtomicU64>,
    /// Distinct keys across those dispatches (occupancy numerator).
    pub batched_keys: CachePadded<AtomicU64>,
    /// `engine.execute` failures: each one closes the affected requests'
    /// completion slots (waiters error out — the net front answers
    /// `Status::Dropped` — instead of hanging until timeout).
    pub engine_errors: CachePadded<AtomicU64>,
}

impl GroupMetrics {
    /// Point-in-time view, tagged with the group id and its member shards.
    pub fn snapshot(&self, group: usize, shards: Vec<usize>) -> GroupSnapshot {
        GroupSnapshot {
            group,
            shards,
            batches: self.batches.load(Ordering::Relaxed),
            batched_keys: self.batched_keys.load(Ordering::Relaxed),
            engine_errors: self.engine_errors.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of one engine group's batcher counters.
#[derive(Clone, Debug, Default)]
pub struct GroupSnapshot {
    /// Group index in the router's fleet.
    pub group: usize,
    /// Global indices of the shards this group owns.
    pub shards: Vec<usize>,
    pub batches: u64,
    pub batched_keys: u64,
    pub engine_errors: u64,
}

impl GroupSnapshot {
    /// Mean executed batch size (occupancy) of this group's engine.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_keys as f64 / self.batches as f64
        }
    }
}

impl std::fmt::Display for GroupSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "group {} (shards {:?}): batches={} (mean size {:.1}) engine_errors={}",
            self.group,
            self.shards,
            self.batches,
            self.mean_batch(),
            self.engine_errors,
        )
    }
}

/// Point-in-time view of the [`Metrics`].
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub hits: u64,
    pub misses: u64,
    pub batches: u64,
    pub batched_keys: u64,
    /// Engine groups serving the fleet (a config echo so a rolled-up line
    /// is self-describing; per-shard snapshots report 0).
    pub engine_groups: u64,
    /// `engine.execute` failures summed over every group's batcher (see
    /// [`GroupMetrics::engine_errors`]).
    pub engine_errors: u64,
    pub unreclaimed_nodes: u64,
    /// Gauge: requests queued, not yet picked up by a worker.
    pub queue_depth: u64,
    /// Gauge: requests submitted, not yet answered (open completion slots).
    pub in_flight: u64,
    /// Magazine-layer counters (process-wide, like `unreclaimed_nodes` —
    /// set once by `Router::metrics`, never summed by [`Self::add_counters`]):
    /// node allocations served from a thread-local magazine vs fallen
    /// through to the global free-list, and depot chain exchanges
    /// (each flush/refill moves ~cap slots with one CAS).
    pub mag_alloc_hits: u64,
    pub mag_alloc_misses: u64,
    pub mag_depot_flushes: u64,
    pub mag_depot_refills: u64,
    /// Network-listener counters (process-wide across all live
    /// `frontend::net` listeners — same single-set discipline as `mag_*`:
    /// `Router::metrics` copies them once from
    /// [`crate::coordinator::frontend::net::net_stats`], and
    /// [`Self::add_counters`] never sums them).
    pub net_accepted: u64,
    /// Gauge: currently-open TCP connections.
    pub net_active: u64,
    pub net_closed: u64,
    pub net_protocol_errors: u64,
    pub net_bytes_in: u64,
    pub net_bytes_out: u64,
    /// Flight-recorder counters (process-wide — set once by
    /// `Router::metrics` from [`crate::trace::stats`], never summed):
    /// per-thread trace rings created, and events recorded across them
    /// (monotonic, includes overwritten events).
    pub trace_rings: u64,
    pub trace_recorded: u64,
}

impl Metrics {
    /// Snapshot with the **process-wide** unreclaimed count (the pre-shard
    /// behaviour; diagnostics that don't care about domain scoping).
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.snapshot_with(crate::alloc::unreclaimed())
    }

    /// Snapshot with an explicitly scoped unreclaimed count (a shard passes
    /// its own domain's, the router an aggregate over distinct domains).
    pub fn snapshot_with(&self, unreclaimed_nodes: u64) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_keys: self.batched_keys.load(Ordering::Relaxed),
            engine_groups: 0,
            engine_errors: 0,
            unreclaimed_nodes,
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            mag_alloc_hits: 0,
            mag_alloc_misses: 0,
            mag_depot_flushes: 0,
            mag_depot_refills: 0,
            net_accepted: 0,
            net_active: 0,
            net_closed: 0,
            net_protocol_errors: 0,
            net_bytes_in: 0,
            net_bytes_out: 0,
            trace_rings: 0,
            trace_recorded: 0,
        }
    }
}

impl MetricsSnapshot {
    /// Sum another snapshot's **counters and gauges** into this one
    /// (requests, hits, misses, batches, batched_keys, queue_depth,
    /// in_flight — per-shard gauges sum to the fleet gauge).
    /// `unreclaimed_nodes` is deliberately left untouched: domains may be
    /// shared between shards, so the caller must aggregate it over
    /// *distinct* domains (see `Router::metrics`). The `mag_*` counters are
    /// likewise untouched — they are process-wide (threads serve many
    /// shards), so `Router::metrics` sets them exactly once from
    /// [`crate::alloc::magazine_stats`].
    pub fn add_counters(&mut self, other: &MetricsSnapshot) {
        self.requests += other.requests;
        self.hits += other.hits;
        self.misses += other.misses;
        self.batches += other.batches;
        self.batched_keys += other.batched_keys;
        self.engine_errors += other.engine_errors;
        self.queue_depth += other.queue_depth;
        self.in_flight += other.in_flight;
    }

    /// Cache hit rate in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests as f64
        }
    }

    /// Mean executed batch size.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_keys as f64 / self.batches as f64
        }
    }

    /// Copy the magazine-layer counters out of an allocator stats snapshot
    /// (`Router::metrics` calls this once, post roll-up — the same single-set
    /// discipline as `unreclaimed_nodes`).
    pub fn set_magazine_stats(&mut self, s: &crate::alloc::MagazineStats) {
        self.mag_alloc_hits = s.alloc_hits;
        self.mag_alloc_misses = s.alloc_misses;
        self.mag_depot_flushes = s.depot_flushes;
        self.mag_depot_refills = s.depot_refills;
    }

    /// Magazine hit rate over node allocations, in [0, 1].
    pub fn mag_hit_rate(&self) -> f64 {
        let total = self.mag_alloc_hits + self.mag_alloc_misses;
        if total == 0 {
            0.0
        } else {
            self.mag_alloc_hits as f64 / total as f64
        }
    }

    /// Copy the listener counters out of a [`net_stats`] aggregate
    /// (`Router::metrics` calls this once, post roll-up — the same
    /// single-set discipline as `unreclaimed_nodes` and `mag_*`).
    ///
    /// [`net_stats`]: crate::coordinator::frontend::net::net_stats
    pub fn set_net_stats(&mut self, s: &crate::coordinator::frontend::net::NetStats) {
        self.net_accepted = s.accepted;
        self.net_active = s.active;
        self.net_closed = s.closed;
        self.net_protocol_errors = s.protocol_errors;
        self.net_bytes_in = s.bytes_in;
        self.net_bytes_out = s.bytes_out;
    }

    /// Copy the flight-recorder counters out of a [`crate::trace::stats`]
    /// aggregate (`Router::metrics` calls this once, post roll-up — the
    /// same single-set discipline as `unreclaimed_nodes`, `mag_*` and
    /// `net_*`).
    pub fn set_trace_stats(&mut self, s: &crate::trace::TraceStats) {
        self.trace_rings = s.rings;
        self.trace_recorded = s.recorded;
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} hits={} ({:.1}%) misses={} batches={} (mean size {:.1}) \
             engine_errors={} unreclaimed={} queued={} in_flight={} \
             mag_hits={} mag_misses={} ({:.1}%) depot_flushes={} depot_refills={}",
            self.requests,
            self.hits,
            self.hit_rate() * 100.0,
            self.misses,
            self.batches,
            self.mean_batch(),
            self.engine_errors,
            self.unreclaimed_nodes,
            self.queue_depth,
            self.in_flight,
            self.mag_alloc_hits,
            self.mag_alloc_misses,
            self.mag_hit_rate() * 100.0,
            self.mag_depot_flushes,
            self.mag_depot_refills,
        )?;
        // Listener block only when a net front has existed — keeps the
        // common (socketless) snapshot line unchanged.
        if self.net_accepted > 0 || self.net_active > 0 {
            write!(
                f,
                " net_accepted={} net_active={} net_closed={} net_proto_errs={} \
                 net_in={}B net_out={}B",
                self.net_accepted,
                self.net_active,
                self.net_closed,
                self.net_protocol_errors,
                self.net_bytes_in,
                self.net_bytes_out,
            )?;
        }
        // Likewise the recorder block: only when tracing has recorded
        // something (trace-off snapshots keep the historical line).
        if self.trace_recorded > 0 {
            write!(f, " trace_rings={} trace_events={}", self.trace_rings, self.trace_recorded)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_math() {
        let m = Metrics::default();
        m.requests.store(10, Ordering::Relaxed);
        m.hits.store(7, Ordering::Relaxed);
        m.misses.store(3, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        m.batched_keys.store(6, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.hit_rate() - 0.7).abs() < 1e-9);
        assert!((s.mean_batch() - 3.0).abs() < 1e-9);
        let text = s.to_string();
        assert!(text.contains("requests=10"));
    }

    #[test]
    fn empty_snapshot_is_safe() {
        let s = MetricsSnapshot::default();
        assert_eq!(s.hit_rate(), 0.0);
        assert_eq!(s.mean_batch(), 0.0);
    }

    #[test]
    fn in_flight_token_is_raii() {
        let m = Metrics::default();
        assert_eq!(m.in_flight(), 0);
        let t1 = m.in_flight_token();
        let t2 = m.in_flight_token();
        assert_eq!(m.in_flight(), 2);
        drop(t1);
        assert_eq!(m.in_flight(), 1);
        drop(t2);
        assert_eq!(m.in_flight(), 0);
    }

    #[test]
    fn rollup_sums_counters_but_not_unreclaimed() {
        let m = Metrics::default();
        m.requests.store(5, Ordering::Relaxed);
        m.hits.store(2, Ordering::Relaxed);
        let a = m.snapshot_with(100);
        assert_eq!(a.unreclaimed_nodes, 100, "scoped count passes through");
        let mut agg = MetricsSnapshot::default();
        agg.add_counters(&a);
        agg.add_counters(&a);
        assert_eq!(agg.requests, 10);
        assert_eq!(agg.hits, 4);
        assert_eq!(agg.unreclaimed_nodes, 0, "caller owns unreclaimed aggregation");
    }

    #[test]
    fn magazine_counters_set_once_not_summed() {
        let stats = crate::alloc::MagazineStats {
            alloc_hits: 30,
            alloc_misses: 10,
            free_hits: 40,
            depot_flushes: 2,
            depot_refills: 1,
        };
        let mut s = MetricsSnapshot::default();
        s.set_magazine_stats(&stats);
        assert_eq!(s.mag_alloc_hits, 30);
        assert!((s.mag_hit_rate() - 0.75).abs() < 1e-9);
        // Roll-up must not double the process-wide magazine counters.
        let mut agg = MetricsSnapshot::default();
        agg.add_counters(&s);
        agg.add_counters(&s);
        assert_eq!(agg.mag_alloc_hits, 0, "router sets mag_* once, post roll-up");
        let text = s.to_string();
        assert!(text.contains("mag_hits=30"));
        assert!(text.contains("depot_flushes=2"));
    }

    #[test]
    fn group_snapshot_math_and_display() {
        let g = GroupMetrics::default();
        g.batches.store(4, Ordering::Relaxed);
        g.batched_keys.store(10, Ordering::Relaxed);
        g.engine_errors.store(1, Ordering::Relaxed);
        let s = g.snapshot(2, vec![2, 5]);
        assert_eq!(s.group, 2);
        assert_eq!(s.shards, vec![2, 5]);
        assert!((s.mean_batch() - 2.5).abs() < 1e-9);
        let text = s.to_string();
        assert!(text.contains("group 2"));
        assert!(text.contains("batches=4"));
        assert!(text.contains("engine_errors=1"));
        // Empty group is safe to display.
        assert_eq!(GroupSnapshot::default().mean_batch(), 0.0);
    }

    #[test]
    fn engine_errors_sum_in_rollup() {
        let mut a = MetricsSnapshot::default();
        a.engine_errors = 2;
        let mut agg = MetricsSnapshot::default();
        agg.add_counters(&a);
        agg.add_counters(&a);
        assert_eq!(agg.engine_errors, 4);
        a.engine_errors = 0;
        let text = a.to_string();
        assert!(text.contains("engine_errors=0"), "always printed: {text}");
    }

    #[test]
    fn net_counters_set_once_not_summed() {
        let stats = crate::coordinator::frontend::net::NetStats {
            accepted: 12,
            active: 3,
            closed: 9,
            protocol_errors: 1,
            bytes_in: 640,
            bytes_out: 10_240,
            idle_evicted: 2,
        };
        let mut s = MetricsSnapshot::default();
        s.set_net_stats(&stats);
        assert_eq!(s.net_accepted, 12);
        assert_eq!(s.net_active, 3);
        // Roll-up must not double the process-wide listener counters.
        let mut agg = MetricsSnapshot::default();
        agg.add_counters(&s);
        agg.add_counters(&s);
        assert_eq!(agg.net_accepted, 0, "router sets net_* once, post roll-up");
        let text = s.to_string();
        assert!(text.contains("net_accepted=12"));
        assert!(text.contains("net_proto_errs=1"));
        // A socketless snapshot keeps the historical line shape.
        let plain = MetricsSnapshot::default().to_string();
        assert!(!plain.contains("net_accepted"));
    }

    #[test]
    fn trace_counters_set_once_not_summed() {
        let stats = crate::trace::TraceStats { rings: 4, recorded: 123 };
        let mut s = MetricsSnapshot::default();
        s.set_trace_stats(&stats);
        assert_eq!(s.trace_rings, 4);
        assert_eq!(s.trace_recorded, 123);
        // Roll-up must not double the process-wide recorder counters.
        let mut agg = MetricsSnapshot::default();
        agg.add_counters(&s);
        agg.add_counters(&s);
        assert_eq!(agg.trace_recorded, 0, "router sets trace_* once, post roll-up");
        let text = s.to_string();
        assert!(text.contains("trace_events=123"));
        // An untraced snapshot keeps the historical line shape.
        let plain = MetricsSnapshot::default().to_string();
        assert!(!plain.contains("trace_events"));
    }
}

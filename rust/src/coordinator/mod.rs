//! The compute-cache coordinator: the paper's HashMap benchmark made real,
//! and scaled out into a fleet.
//!
//! The paper motivates its HashMap workload as "the calculation in a
//! complex simulation where partial results are stored in a hash-map for
//! later reuse" (§4.1). This module *is* that system, in the vLLM-router
//! shape, split into two layers (DESIGN.md §coordinator-sharding):
//!
//! * [`Shard`] — one serving unit: its own reclamation domain (by
//!   default), bounded FIFO-evicting lock-free cache, lock-free request
//!   queue and worker pool. Everything on the request path is this
//!   crate's own lock-free data structures, reclaimed by the scheme `R` —
//!   the coordinator dogfoods the library.
//! * [`Router`] — the front-end: owns N shards, routes `submit(key)` by a
//!   deterministic key hash ([`router::shard_for_key`]), and partitions the
//!   fleet into **engine groups** ([`ServerConfig::groups`], DESIGN.md §9):
//!   each group owns a subset of shards plus its *own* batcher/engine
//!   thread and miss channel, so misses are served group-locally.
//!   `PjRtClient` is not `Send`, so each group's engine is created on that
//!   group's batcher thread — engine-per-group is how compute parallelizes.
//!   `shards = 1, groups = 1` reproduces the old single-server (and
//!   single-batcher) behaviour exactly.
//!
//! Two domain modes ([`ServerConfig::shared_domain`]): **domain-per-shard**
//! (default) keeps shards fully isolated — two shards never share retire
//! lists, epochs or hazard registries, so reclamation overhead scales with
//! per-shard thread count, not fleet size; **shared-domain** runs the whole
//! fleet on one domain, the single-domain baseline the `shard_scaling`
//! bench compares against.
//!
//! The batcher's compute side is a [`Backend`]: real PJRT artifacts
//! ([`Backend::Pjrt`]) or a deterministic in-process stand-in
//! ([`Backend::Synthetic`]) so benches, CI smokes and tests exercise the
//! full fleet without artifacts.
//!
//! Requests enter through the **async submission front-end** ([`frontend`],
//! DESIGN.md §6): `submit_async(key)` returns a [`SubmitFuture`] fulfilled
//! through a per-request completion slot, `submit(key)` is its blocking
//! [`SubmitHandle`] wrapper (deadline-bounded `recv`), and
//! [`frontend::mux`] multiplexes thousands of logical clients per executor
//! thread over the same path — the many-tasks-on-few-threads regime the
//! E17 `async_scaling` figure measures.

pub mod frontend;
pub mod metrics;
pub mod router;
pub mod shard;

pub use frontend::{SubmitFuture, SubmitHandle};
pub use router::Router;
pub use shard::Shard;

use crate::runtime::DIM;
use std::path::PathBuf;
use std::time::Duration;

/// The historical single-server name; since the router refactor a
/// `CacheServer` *is* a router (of one shard, unless configured larger).
pub type CacheServer<R> = Router<R>;

/// The poison key of the trace crash-test path (`serve --crash-test`):
/// when [`enable_crash_test`] has been called, a shard worker that
/// dequeues a request for this key panics, exercising the flight
/// recorder's panic-hook snapshot end to end. Inert unless armed — a
/// production client sending `u32::MAX` hits the normal cache path.
pub const CRASH_TEST_KEY: u32 = u32::MAX;

static CRASH_TEST: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Arm the [`CRASH_TEST_KEY`] worker-panic injection (process-wide;
/// test/CI tooling only).
pub fn enable_crash_test() {
    CRASH_TEST.store(true, std::sync::atomic::Ordering::Relaxed);
}

pub(crate) fn crash_test_enabled() -> bool {
    CRASH_TEST.load(std::sync::atomic::Ordering::Relaxed)
}

/// A computed partial result: 256 f32 = 1024 bytes, the paper's payload.
pub type Payload = [f32; DIM];

/// Which compute engine the router's batcher drives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled PJRT artifacts from [`ServerConfig::artifact_dir`]
    /// (fails fast when missing; requires the `pjrt` feature).
    Pjrt,
    /// Deterministic in-process compute
    /// ([`crate::bench_fw::workload::compute_payload`]) — the artifact-free
    /// path for benches, CI smokes and tests.
    Synthetic {
        /// Cap on distinct keys per dispatched batch (the role the largest
        /// compiled executable plays for [`Backend::Pjrt`]).
        max_batch: usize,
    },
    /// Fault injection for tests: like [`Backend::Synthetic`], but every
    /// `execute` fails — exercises the batcher's engine-error path
    /// (`engine_errors` counter + slot close, so waiters resolve with an
    /// error instead of timing out).
    #[doc(hidden)]
    SyntheticFailing,
    /// Stall injection for tests: like [`Backend::Synthetic`], but a batch
    /// containing `key` sleeps `delay_ms` before computing — a wedged
    /// engine, which makes cross-group miss isolation observable (a stalled
    /// group's batcher must not delay another group's misses).
    #[doc(hidden)]
    SyntheticStall {
        key: u32,
        delay_ms: u64,
    },
}

impl Backend {
    /// Default batch bound for the synthetic engine (mirrors the largest
    /// AOT-compiled batch size).
    pub const SYNTHETIC_MAX_BATCH: usize = 32;

    /// A synthetic backend with the default batch bound.
    pub fn synthetic() -> Self {
        Backend::Synthetic { max_batch: Self::SYNTHETIC_MAX_BATCH }
    }

    /// Parse a CLI name: `pjrt` | `synthetic`.
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "pjrt" => Some(Backend::Pjrt),
            "synthetic" | "syn" => Some(Backend::synthetic()),
            _ => None,
        }
    }
}

/// Server configuration (defaults = the paper's HashMap parameters, one
/// shard — the old single-server shape).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Hash buckets per shard (paper: 2048).
    pub buckets: usize,
    /// Max cached entries per shard (paper: 10000).
    pub capacity: usize,
    /// Worker threads per shard serving its request queue.
    pub workers: usize,
    /// Number of shards the router fans out over (min 1).
    pub shards: usize,
    /// Number of **engine groups** the shards are partitioned into (min 1,
    /// effectively capped at the shard count): each group owns its own
    /// batcher/engine thread and miss channel, so miss compute parallelizes
    /// across groups (DESIGN.md §9). `groups = 1` is the historical
    /// single-batcher fleet.
    pub groups: usize,
    /// One fleet-wide reclamation domain instead of one per shard.
    pub shared_domain: bool,
    /// The batcher's compute engine.
    pub backend: Backend,
    /// How long the batcher waits to fill a batch after the first miss.
    pub batch_wait: Duration,
    /// Artifact directory for the PJRT engine.
    pub artifact_dir: PathBuf,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            buckets: 2048,
            capacity: 10_000,
            workers: 2,
            shards: 1,
            groups: 1,
            shared_domain: false,
            backend: Backend::Pjrt,
            batch_wait: Duration::from_micros(200),
            artifact_dir: crate::runtime::default_artifact_dir(),
        }
    }
}

impl ServerConfig {
    /// Builder: set the shard count (min 1).
    pub fn with_shards(mut self, n: usize) -> Self {
        self.shards = n.max(1);
        self
    }

    /// Builder: set the engine-group count (min 1; the router caps it at
    /// the shard count, since a group without shards would idle).
    pub fn with_groups(mut self, n: usize) -> Self {
        self.groups = n.max(1);
        self
    }

    /// Builder: one shared fleet-wide domain instead of domain-per-shard.
    pub fn with_shared_domain(mut self, yes: bool) -> Self {
        self.shared_domain = yes;
        self
    }

    /// Builder: select the compute backend.
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }
}

/// A response to one compute request.
#[derive(Clone, Debug)]
pub struct Response {
    /// The partial result.
    pub data: Box<Payload>,
    /// Served from cache?
    pub hit: bool,
    /// Submit-to-reply latency.
    pub latency_ns: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_fw::workload::compute_payload;
    use crate::reclaim::ebr::Ebr;
    use crate::reclaim::stamp::StampIt;

    fn tiny_synthetic() -> ServerConfig {
        ServerConfig {
            workers: 2,
            capacity: 64,
            buckets: 32,
            ..ServerConfig::default()
        }
        .with_backend(Backend::synthetic())
    }

    #[test]
    fn server_basic_roundtrip() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let server = CacheServer::<StampIt>::start(ServerConfig {
            workers: 2,
            capacity: 64,
            buckets: 32,
            ..ServerConfig::default()
        })
        .unwrap();

        // First request: miss, computed.
        let r1 = server.request(42).unwrap();
        assert!(!r1.hit);
        assert!(r1.data.iter().all(|v| v.is_finite() && v.abs() <= 1.0));

        // Second request for the same key: hit, identical data.
        let r2 = server.request(42).unwrap();
        assert!(r2.hit, "second request must be served from cache");
        assert_eq!(r1.data[..], r2.data[..]);

        // Distinct key → distinct result.
        let r3 = server.request(43).unwrap();
        assert_ne!(r1.data[..], r3.data[..]);

        let m = server.metrics();
        assert_eq!(m.requests, 3);
        assert_eq!(m.hits, 1);
        assert_eq!(m.misses, 2);
        server.shutdown();
    }

    #[test]
    fn capacity_is_bounded_under_churn() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let server = CacheServer::<StampIt>::start(ServerConfig {
            workers: 2,
            capacity: 16,
            buckets: 8,
            ..ServerConfig::default()
        })
        .unwrap();
        for key in 0..64u32 {
            let _ = server.request(key).unwrap();
        }
        assert!(
            server.cache_len() <= 16 + 4,
            "eviction must bound the cache: {}",
            server.cache_len()
        );
        server.shutdown();
    }

    #[test]
    fn synthetic_backend_serves_without_artifacts() {
        // The artifact-free path: full router + shard + batcher stack, with
        // responses matching the deterministic compute function exactly.
        let server = Router::<StampIt>::start(tiny_synthetic()).unwrap();
        let r1 = server.request(7).unwrap();
        assert!(!r1.hit);
        let want = compute_payload(7);
        assert_eq!(r1.data[..], want[..], "synthetic result must be compute_payload(key)");
        let r2 = server.request(7).unwrap();
        assert!(r2.hit);
        assert_eq!(r2.data[..], want[..]);
        server.shutdown();
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        // Regression (satellite): submits onto a stopped server must error
        // out instead of blocking forever on workers that have exited.
        let server = Router::<Ebr>::start(tiny_synthetic()).unwrap();
        let _ = server.request(1).unwrap();
        server.shutdown();
        let err = server.request(2);
        assert!(err.is_err(), "request on a stopped server must fail, not hang");
        // And a raw submit handle is already closed (errors immediately,
        // without waiting out the recv timeout).
        let t0 = std::time::Instant::now();
        assert!(server.submit(3).recv().is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
        // Same on the async path: the future is born rejected.
        assert!(emr_block_on(server.submit_async(4)).is_err());
    }

    /// Local alias so the test reads naturally.
    fn emr_block_on<F: std::future::Future>(f: F) -> F::Output {
        crate::runtime::exec::block_on(f)
    }

    #[test]
    fn single_shard_router_matches_cache_server_shape() {
        // `with_shards(1)` is the old server: everything lands on shard 0.
        let server = Router::<StampIt>::start(tiny_synthetic().with_shards(1)).unwrap();
        assert_eq!(server.shard_count(), 1);
        for key in [0u32, 1, 7, 0xFFFF_FFFF] {
            assert_eq!(server.shard_of(key), 0);
        }
        let _ = server.request(11).unwrap();
        let per_shard = server.shard_metrics();
        assert_eq!(per_shard.len(), 1);
        assert_eq!(per_shard[0].requests, 1);
        assert_eq!(server.metrics().requests, 1);
        server.shutdown();
    }

    #[test]
    fn multi_shard_router_spreads_and_aggregates() {
        let server = Router::<StampIt>::start(tiny_synthetic().with_shards(4)).unwrap();
        let n = 256u32;
        for key in 0..n {
            let r = server.request(key).unwrap();
            assert_eq!(r.data[..], compute_payload(key as u64)[..]);
        }
        let agg = server.metrics();
        assert_eq!(agg.requests, n as u64);
        assert_eq!(agg.hits + agg.misses, n as u64);
        let per_shard = server.shard_metrics();
        assert_eq!(per_shard.len(), 4);
        assert_eq!(per_shard.iter().map(|m| m.requests).sum::<u64>(), n as u64);
        // The key hash must actually spread load.
        assert!(
            per_shard.iter().all(|m| m.requests > 0),
            "every shard should see traffic: {:?}",
            per_shard.iter().map(|m| m.requests).collect::<Vec<_>>()
        );
        server.shutdown();
    }

    #[test]
    fn grouped_router_roundtrip() {
        // shards=4, groups=2: group 0 owns shards {0, 2}, group 1 owns
        // {1, 3} (round-robin). Both batchers must serve, and the rolled-up
        // batch counters must equal the per-group sum.
        let server =
            Router::<StampIt>::start(tiny_synthetic().with_shards(4).with_groups(2)).unwrap();
        assert_eq!(server.group_count(), 2);
        assert_eq!(server.group_shards(0), vec![0, 2]);
        assert_eq!(server.group_shards(1), vec![1, 3]);
        let n = 128u32;
        for key in 0..n {
            let r = server.request(key).unwrap();
            assert_eq!(r.data[..], compute_payload(key as u64)[..]);
            assert_eq!(server.group_of(key), server.group_of_shard(server.shard_of(key)));
        }
        let agg = server.metrics();
        assert_eq!(agg.requests, n as u64);
        assert_eq!(agg.engine_groups, 2);
        assert_eq!(agg.engine_errors, 0);
        let per_group = server.group_metrics();
        assert_eq!(per_group.len(), 2);
        assert!(
            per_group.iter().all(|g| g.batches > 0),
            "both group batchers must have dispatched: {per_group:?}"
        );
        assert_eq!(per_group.iter().map(|g| g.batches).sum::<u64>(), agg.batches);
        assert_eq!(per_group.iter().map(|g| g.batched_keys).sum::<u64>(), agg.batched_keys);
        server.shutdown();
    }

    #[test]
    fn groups_clamp_and_pure_assignment() {
        use super::router::{effective_groups, group_for_shard};
        // Config floor and router cap.
        assert_eq!(ServerConfig::default().with_groups(0).groups, 1);
        assert_eq!(effective_groups(2, 8), 2);
        assert_eq!(effective_groups(8, 3), 3);
        // Pure round-robin assignment, stable by construction.
        assert_eq!(group_for_shard(0, 3), 0);
        assert_eq!(group_for_shard(5, 3), 2);
        // A fleet asking for more groups than shards runs one per shard.
        let server =
            Router::<Ebr>::start(tiny_synthetic().with_shards(2).with_groups(8)).unwrap();
        assert_eq!(server.group_count(), 2);
        let r = server.request(9).unwrap();
        assert_eq!(r.data[..], compute_payload(9)[..]);
        assert_eq!(server.metrics().engine_groups, 2);
        server.shutdown();
    }

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("pjrt"), Some(Backend::Pjrt));
        assert_eq!(Backend::parse("synthetic"), Some(Backend::synthetic()));
        assert_eq!(Backend::parse("bogus"), None);
    }
}

//! The compute-cache coordinator: the paper's HashMap benchmark made real.
//!
//! The paper motivates its HashMap workload as "the calculation in a
//! complex simulation where partial results are stored in a hash-map for
//! later reuse" (§4.1). This module *is* that system, in the vLLM-router
//! shape: clients submit keyed compute requests; worker threads route them
//! through a bounded, FIFO-evicting, lock-free cache; misses are gathered
//! by a dynamic batcher and dispatched to the AOT-compiled JAX/Pallas
//! computation on the PJRT engine thread; results are inserted (evicting
//! old 1024-byte payload nodes through the reclamation scheme) and fanned
//! back out to the waiting requests.
//!
//! Everything on the request path is Rust; the hot structures (request
//! queue **and** cache) are this crate's own lock-free data structures,
//! reclaimed by the scheme `R` — the coordinator dogfoods the library.
//!
//! Every server instance (= one shard of the ROADMAP's sharded north-star)
//! owns its **own reclamation domain**: two servers in one process never
//! share retire lists, epochs or hazard registries, and worker threads use
//! explicit per-thread handles on the hot path (no TLS per operation).

pub mod metrics;

use crate::ds::hashmap::FifoCache;
use crate::ds::queue::Queue;
use crate::reclaim::{Cached, DomainRef, Reclaimer};
use crate::runtime::{Engine, DIM};
use crate::util::error::{Context, Result};
use crate::util::monotonic_ns;
use metrics::{Metrics, MetricsSnapshot};
use std::collections::HashMap as StdHashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// A computed partial result: 256 f32 = 1024 bytes, the paper's payload.
pub type Payload = [f32; DIM];

/// Server configuration (defaults = the paper's HashMap parameters).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Hash buckets (paper: 2048).
    pub buckets: usize,
    /// Max cached entries (paper: 10000).
    pub capacity: usize,
    /// Worker threads serving the request queue.
    pub workers: usize,
    /// How long the batcher waits to fill a batch after the first miss.
    pub batch_wait: Duration,
    /// Artifact directory for the PJRT engine.
    pub artifact_dir: PathBuf,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            buckets: 2048,
            capacity: 10_000,
            workers: 2,
            batch_wait: Duration::from_micros(200),
            artifact_dir: crate::runtime::default_artifact_dir(),
        }
    }
}

/// A response to one compute request.
#[derive(Clone, Debug)]
pub struct Response {
    /// The partial result.
    pub data: Box<Payload>,
    /// Served from cache?
    pub hit: bool,
    /// Submit-to-reply latency.
    pub latency_ns: u64,
}

struct Request {
    key: u32,
    t0: u64,
    reply: mpsc::Sender<Response>,
}

struct Shared<R: Reclaimer> {
    /// This server's private reclamation domain (domain-per-shard).
    domain: DomainRef<R>,
    cache: FifoCache<u32, Payload, R>,
    queue: Queue<Request, R>,
    queued: AtomicUsize,
    shutdown: AtomicBool,
    metrics: Metrics,
}

/// The compute-cache server (paper HashMap benchmark, serving shape).
pub struct CacheServer<R: Reclaimer> {
    shared: Arc<Shared<R>>,
    miss_tx: Mutex<Option<mpsc::Sender<Request>>>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl<R: Reclaimer> CacheServer<R> {
    /// Start workers + batcher + engine in a fresh reclamation domain.
    /// Fails if artifacts are missing.
    pub fn start(cfg: ServerConfig) -> Result<Arc<Self>> {
        Self::start_in(cfg, DomainRef::new_owned())
    }

    /// [`Self::start`] with an explicit domain (shared-shard setups).
    pub fn start_in(cfg: ServerConfig, domain: DomainRef<R>) -> Result<Arc<Self>> {
        let shared = Arc::new(Shared {
            cache: FifoCache::new_in(domain.clone(), cfg.buckets, cfg.capacity),
            queue: Queue::new_in(domain.clone()),
            domain,
            queued: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            metrics: Metrics::default(),
        });
        let (miss_tx, miss_rx) = mpsc::channel::<Request>();

        let mut threads = Vec::new();
        // Batcher thread owns the PJRT engine (PjRtClient is not Send, so
        // it is created on this thread). Readiness is confirmed through a
        // channel so start() fails fast on missing artifacts.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        {
            let shared = shared.clone();
            let dir = cfg.artifact_dir.clone();
            let wait = cfg.batch_wait;
            threads.push(
                std::thread::Builder::new().name("emr-batcher".into()).spawn(move || {
                    let engine = match Engine::load(&dir) {
                        Ok(e) => {
                            let _ = ready_tx.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    batcher_loop(&shared, &engine, miss_rx, wait);
                })?,
            );
        }
        ready_rx.recv().context("batcher thread died")??;

        let server = Arc::new(Self {
            shared: shared.clone(),
            miss_tx: Mutex::new(Some(miss_tx)),
            threads: Mutex::new(threads),
        });
        for w in 0..cfg.workers {
            let shared = shared.clone();
            let miss_tx = server.miss_tx.lock().unwrap().as_ref().unwrap().clone();
            let handle = std::thread::Builder::new()
                .name(format!("emr-worker-{w}"))
                .spawn(move || worker_loop(&shared, miss_tx))?;
            server.threads.lock().unwrap().push(handle);
        }
        Ok(server)
    }

    /// Submit a request; the receiver yields the [`Response`].
    pub fn submit(&self, key: u32) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.shared.queue.enqueue(Cached, Request { key, t0: monotonic_ns(), reply: tx });
        self.shared.queued.fetch_add(1, Ordering::Release);
        rx
    }

    /// Blocking convenience: submit + wait.
    pub fn request(&self, key: u32) -> Result<Response> {
        self.submit(key).recv().context("server dropped request")
    }

    /// Current metrics (+ global unreclaimed-node count).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Entries currently cached.
    pub fn cache_len(&self) -> usize {
        self.shared.cache.len()
    }

    /// Stop all threads; pending requests are drained first.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Dropping the miss sender unblocks the batcher once workers exit.
        let mut threads = std::mem::take(&mut *self.threads.lock().unwrap());
        // Workers exit on the flag; join them first so no more misses are
        // produced, then close the miss channel for the batcher.
        let batcher = if threads.is_empty() { None } else { Some(threads.remove(0)) };
        for t in threads {
            let _ = t.join();
        }
        *self.miss_tx.lock().unwrap() = None;
        if let Some(b) = batcher {
            let _ = b.join();
        }
    }
}

impl<R: Reclaimer> Drop for CacheServer<R> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop<R: Reclaimer>(shared: &Shared<R>, miss_tx: mpsc::Sender<Request>) {
    // One registration for the worker's lifetime: every queue/cache
    // operation below runs TLS-free through this handle.
    let handle = shared.domain.register();
    let mut idle_spins = 0u32;
    loop {
        match shared.queue.dequeue(&handle) {
            Some(req) => {
                idle_spins = 0;
                shared.queued.fetch_sub(1, Ordering::Release);
                // Guarded cache read: the payload is copied out under the
                // guard (the "reuse" path of the paper's simulation).
                let hit = shared.cache.get(&handle, &req.key, |v| Box::new(*v));
                match hit {
                    Some(data) => {
                        shared.metrics.hits.fetch_add(1, Ordering::Relaxed);
                        let _ = req.reply.send(Response {
                            data,
                            hit: true,
                            latency_ns: monotonic_ns() - req.t0,
                        });
                    }
                    None => {
                        shared.metrics.misses.fetch_add(1, Ordering::Relaxed);
                        if miss_tx.send(req).is_err() {
                            return; // batcher gone: shutting down
                        }
                    }
                }
            }
            None => {
                if shared.shutdown.load(Ordering::Acquire)
                    && shared.queued.load(Ordering::Acquire) == 0
                {
                    return;
                }
                // Lock-free queues cannot block; back off politely.
                idle_spins += 1;
                if idle_spins < 32 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(100));
                }
            }
        }
    }
}

fn batcher_loop<R: Reclaimer>(
    shared: &Shared<R>,
    engine: &Engine,
    miss_rx: mpsc::Receiver<Request>,
    batch_wait: Duration,
) {
    let max_batch = engine.max_batch();
    let handle = shared.domain.register();
    let mut waiting: StdHashMap<u32, Vec<Request>> = StdHashMap::new();
    loop {
        // Block for the first miss (with a timeout to notice shutdown).
        match miss_rx.recv_timeout(Duration::from_millis(5)) {
            Ok(req) => {
                waiting.entry(req.key).or_default().push(req);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if waiting.is_empty() {
                    continue;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if waiting.is_empty() {
                    return;
                }
            }
        }
        // Accumulate until the batch is full or the wait window closes.
        let deadline = std::time::Instant::now() + batch_wait;
        while waiting.len() < max_batch {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match miss_rx.recv_timeout(deadline - now) {
                Ok(req) => {
                    waiting.entry(req.key).or_default().push(req);
                }
                Err(_) => break,
            }
        }

        // Dispatch one batch of distinct keys.
        let keys: Vec<u32> = waiting.keys().copied().take(max_batch).collect();
        let seeds: Vec<i32> = keys.iter().map(|&k| k as i32).collect();
        match engine.execute(&seeds) {
            Ok(results) => {
                shared.metrics.batches.fetch_add(1, Ordering::Relaxed);
                shared.metrics.batched_keys.fetch_add(keys.len() as u64, Ordering::Relaxed);
                for (key, row) in keys.iter().zip(results) {
                    let mut payload: Payload = [0.0; DIM];
                    payload.copy_from_slice(&row);
                    // Insert evicts FIFO-oldest beyond capacity — retiring
                    // 1 KiB nodes through the reclamation scheme.
                    if !shared.cache.insert(&handle, *key, payload) {
                        shared.metrics.evictions_observed.fetch_add(1, Ordering::Relaxed);
                    }
                    for req in waiting.remove(key).unwrap_or_default() {
                        let _ = req.reply.send(Response {
                            data: Box::new(payload),
                            hit: false,
                            latency_ns: monotonic_ns() - req.t0,
                        });
                    }
                }
            }
            Err(e) => {
                // Engine failure: drop the affected requests (receivers see
                // a closed channel) and keep serving.
                eprintln!("[batcher] execute failed: {e:#}");
                for key in keys {
                    waiting.remove(&key);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::stamp::StampIt;

    #[test]
    fn server_basic_roundtrip() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let server = CacheServer::<StampIt>::start(ServerConfig {
            workers: 2,
            capacity: 64,
            buckets: 32,
            ..ServerConfig::default()
        })
        .unwrap();

        // First request: miss, computed.
        let r1 = server.request(42).unwrap();
        assert!(!r1.hit);
        assert!(r1.data.iter().all(|v| v.is_finite() && v.abs() <= 1.0));

        // Second request for the same key: hit, identical data.
        let r2 = server.request(42).unwrap();
        assert!(r2.hit, "second request must be served from cache");
        assert_eq!(r1.data[..], r2.data[..]);

        // Distinct key → distinct result.
        let r3 = server.request(43).unwrap();
        assert_ne!(r1.data[..], r3.data[..]);

        let m = server.metrics();
        assert_eq!(m.requests, 3);
        assert_eq!(m.hits, 1);
        assert_eq!(m.misses, 2);
        server.shutdown();
    }

    #[test]
    fn capacity_is_bounded_under_churn() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let server = CacheServer::<StampIt>::start(ServerConfig {
            workers: 2,
            capacity: 16,
            buckets: 8,
            ..ServerConfig::default()
        })
        .unwrap();
        for key in 0..64u32 {
            let _ = server.request(key).unwrap();
        }
        assert!(
            server.cache_len() <= 16 + 4,
            "eviction must bound the cache: {}",
            server.cache_len()
        );
        server.shutdown();
    }
}

//! One **shard** of the compute-cache fleet: today's server body — its own
//! reclamation domain (unless the router shares one), FIFO-evicting
//! lock-free cache, lock-free request queue and worker pool. Shards know
//! nothing about routing: the [`super::Router`] hashes keys onto them and
//! fans one shared batcher over their miss channels.

use super::metrics::{Metrics, MetricsSnapshot};
use super::{Payload, Response, ServerConfig};
use crate::ds::hashmap::FifoCache;
use crate::ds::queue::Queue;
use crate::reclaim::{Cached, DomainRef, Reclaimer};
use crate::util::error::Result;
use crate::util::monotonic_ns;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// One queued compute request (crate-internal: shards and the router's
/// batcher exchange these).
pub(crate) struct Request {
    pub(crate) key: u32,
    pub(crate) t0: u64,
    pub(crate) reply: mpsc::Sender<Response>,
}

/// A cache miss traveling from a shard's worker to the router's shared
/// batcher, tagged with the shard it must be answered into.
pub(crate) struct Miss {
    pub(crate) shard: usize,
    pub(crate) req: Request,
}

/// State shared between a shard's workers, the router's batcher, and the
/// front-end handle.
pub(crate) struct ShardShared<R: Reclaimer> {
    /// This shard's reclamation domain (private in domain-per-shard mode,
    /// a clone of the fleet-wide one in shared-domain mode).
    pub(crate) domain: DomainRef<R>,
    pub(crate) cache: FifoCache<u32, Payload, R>,
    pub(crate) queue: Queue<Request, R>,
    queued: AtomicUsize,
    shutdown: AtomicBool,
    /// Submits currently between their shutdown-flag check and their
    /// enqueue. `shutdown()` quiesces on this (Dekker-style pairing with
    /// the flag, see [`Shard::submit`]) so no enqueue can land after the
    /// post-join drain.
    active_submits: AtomicUsize,
    pub(crate) metrics: Metrics,
}

/// One shard: worker pool + cache + queue over one reclamation domain.
/// Started and stopped by its owning [`super::Router`].
pub struct Shard<R: Reclaimer> {
    index: usize,
    shared: Arc<ShardShared<R>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl<R: Reclaimer> Shard<R> {
    /// Spawn this shard's worker pool. Misses flow into `miss_tx` (the
    /// router's single shared batcher).
    pub(crate) fn start(
        index: usize,
        cfg: &ServerConfig,
        domain: DomainRef<R>,
        miss_tx: mpsc::Sender<Miss>,
    ) -> Result<Self> {
        let shared = Arc::new(ShardShared {
            cache: FifoCache::new_in(domain.clone(), cfg.buckets, cfg.capacity),
            queue: Queue::new_in(domain.clone()),
            domain,
            queued: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            active_submits: AtomicUsize::new(0),
            metrics: Metrics::default(),
        });
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let worker_shared = shared.clone();
            let miss_tx = miss_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("emr-s{index}-w{w}"))
                .spawn(move || worker_loop(index, &worker_shared, miss_tx));
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // Stop the workers already running before bailing.
                    shared.shutdown.store(true, Ordering::Release);
                    for t in workers {
                        let _ = t.join();
                    }
                    return Err(e.into());
                }
            }
        }
        Ok(Self { index, shared, workers: Mutex::new(workers) })
    }

    /// This shard's position in the router's fleet.
    pub fn index(&self) -> usize {
        self.index
    }

    /// Submit a request to this shard; the receiver yields the [`Response`].
    ///
    /// After [`shutdown`](Self::shutdown) the receiver comes back already
    /// closed (`recv` errors immediately) instead of blocking forever on
    /// workers that have exited — the stopped-server fix.
    pub fn submit(&self, key: u32) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        // Dekker-style pairing with shutdown(): mark this submit in-flight
        // *before* checking the flag (both SeqCst). Either we observe the
        // flag and reject, or shutdown()'s quiesce loop observes our
        // marker and waits for the enqueue below — so an enqueue can never
        // land after the post-join drain and leave its receiver hanging.
        self.shared.active_submits.fetch_add(1, Ordering::SeqCst);
        if self.shared.shutdown.load(Ordering::SeqCst) {
            self.shared.active_submits.fetch_sub(1, Ordering::Release);
            // Stopped: reject by dropping the sender (closed channel).
            return rx;
        }
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.shared.queue.enqueue(Cached, Request { key, t0: monotonic_ns(), reply: tx });
        self.shared.queued.fetch_add(1, Ordering::Release);
        // Release: the enqueue happens-before shutdown() sees the count
        // drop, hence before the workers are joined and the queue drained.
        self.shared.active_submits.fetch_sub(1, Ordering::Release);
        rx
    }

    pub(crate) fn shared(&self) -> &Arc<ShardShared<R>> {
        &self.shared
    }

    /// This shard's reclamation domain.
    pub fn domain(&self) -> &DomainRef<R> {
        &self.shared.domain
    }

    /// Entries currently cached in this shard.
    pub fn cache_len(&self) -> usize {
        self.shared.cache.len()
    }

    /// This shard's counters, with the unreclaimed-node count scoped to
    /// its own domain (in shared-domain mode every shard reports the same
    /// fleet-wide domain count).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot_with(self.shared.domain.domain().unreclaimed())
    }

    /// Stop this shard's workers. Requests already queued are drained and
    /// served first; anything that raced past the shutdown flag afterwards
    /// is rejected (its reply sender is dropped, so the receiver observes
    /// a closed channel instead of blocking forever).
    pub(crate) fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Quiesce submits that raced past the flag check (see submit()):
        // once this count reads 0 after the SeqCst flag store, every later
        // submit rejects, so no new enqueue can appear below. The load must
        // be SeqCst to close the store-buffering outcome (an Acquire load
        // is outside the SC order and could miss a SeqCst fetch_add); it
        // still carries Acquire, so the Release decrement's enqueue
        // happens-before the drain.
        while self.shared.active_submits.load(Ordering::SeqCst) != 0 {
            std::thread::yield_now();
        }
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for t in workers {
            let _ = t.join();
        }
        // Workers are gone; nothing will answer what is still queued.
        let handle = self.shared.domain.register();
        while let Some(req) = self.shared.queue.dequeue(&handle) {
            self.shared.queued.fetch_sub(1, Ordering::Release);
            drop(req); // dropping the reply sender closes the channel
        }
    }
}

fn worker_loop<R: Reclaimer>(index: usize, shared: &ShardShared<R>, miss_tx: mpsc::Sender<Miss>) {
    // One registration for the worker's lifetime: every queue/cache
    // operation below runs TLS-free through this handle — one registered
    // handle serves a request's whole cache/queue path.
    let handle = shared.domain.register();
    let mut idle_spins = 0u32;
    loop {
        match shared.queue.dequeue(&handle) {
            Some(req) => {
                idle_spins = 0;
                shared.queued.fetch_sub(1, Ordering::Release);
                // Guarded cache read: the payload is copied out under the
                // guard (the "reuse" path of the paper's simulation).
                let hit = shared.cache.get(&handle, &req.key, |v| Box::new(*v));
                match hit {
                    Some(data) => {
                        shared.metrics.hits.fetch_add(1, Ordering::Relaxed);
                        let _ = req.reply.send(Response {
                            data,
                            hit: true,
                            latency_ns: monotonic_ns() - req.t0,
                        });
                    }
                    None => {
                        shared.metrics.misses.fetch_add(1, Ordering::Relaxed);
                        if miss_tx.send(Miss { shard: index, req }).is_err() {
                            return; // batcher gone: shutting down
                        }
                    }
                }
            }
            None => {
                if shared.shutdown.load(Ordering::Acquire)
                    && shared.queued.load(Ordering::Acquire) == 0
                {
                    return;
                }
                // Lock-free queues cannot block; back off politely.
                idle_spins += 1;
                if idle_spins < 32 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(100));
                }
            }
        }
    }
}

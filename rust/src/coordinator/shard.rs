//! One **shard** of the compute-cache fleet: today's server body — its own
//! reclamation domain (unless the router shares one), FIFO-evicting
//! lock-free cache, lock-free request queue and worker pool. Shards know
//! nothing about routing: the [`super::Router`] hashes keys onto them,
//! partitions them into engine groups (DESIGN.md §9), and gives each shard
//! its group's miss channel — misses flow to the group's batcher, tagged
//! with the shard's group-local slot.
//!
//! Since the async front-end (DESIGN.md §6) the native submission path is
//! [`Shard::submit_async`]: every queued [`Request`] carries the fulfiller
//! half of a completion slot ([`CompletionSender`]) instead of an
//! `mpsc::Sender`, so the waiter can be a parked task on the executor just
//! as well as a blocked OS thread — and dropping the request *anywhere*
//! (shutdown drain, engine failure) closes the slot instead of leaking a
//! receiver that blocks forever.

use super::frontend::{completion_pair, CompletionSender, SubmitFuture, SubmitHandle};
use super::metrics::{InFlightToken, Metrics, MetricsSnapshot};
use super::{Payload, Response, ServerConfig};
use crate::ds::hashmap::FifoCache;
use crate::ds::queue::Queue;
use crate::reclaim::{Cached, DomainRef, Reclaimer};
use crate::util::error::Result;
use crate::util::monotonic_ns;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// One queued compute request (crate-internal: shards and the router's
/// batcher exchange these).
pub(crate) struct Request {
    pub(crate) key: u32,
    pub(crate) t0: u64,
    /// Flight-recorder correlation id pairing this request's
    /// `shard.submit` event with its eventual `shard.complete` (0 when
    /// tracing was off at submit time — no complete event is emitted).
    pub(crate) trace_id: u32,
    /// RAII leg of the shard's `in_flight` gauge: rides with the request
    /// through every path (hit, batcher, drain) and drops exactly once.
    /// Declared BEFORE `reply` deliberately: struct fields drop in
    /// declaration order, so on every plain `drop(req)` path (shutdown
    /// drain, engine failure) the gauge closes before the slot-close wakes
    /// the waiter — the same ordering the answer paths enforce by hand,
    /// preserving the `in_flight ≤ shards × budget` invariant.
    pub(crate) _in_flight: InFlightToken,
    /// Fulfiller half of the submitter's completion slot; dropping it
    /// unanswered closes the slot (the waiter errors instead of hanging).
    pub(crate) reply: CompletionSender,
}

/// A cache miss traveling from a shard's worker to its **group's** batcher,
/// tagged with the shard's group-local slot (its index in the batcher's
/// member list) so the batcher knows which shard to answer into.
pub(crate) struct Miss {
    pub(crate) slot: usize,
    pub(crate) req: Request,
}

/// State shared between a shard's workers, its group's batcher, and the
/// front-end handle.
pub(crate) struct ShardShared<R: Reclaimer> {
    /// This shard's reclamation domain (private in domain-per-shard mode,
    /// a clone of the fleet-wide one in shared-domain mode).
    pub(crate) domain: DomainRef<R>,
    pub(crate) cache: FifoCache<u32, Payload, R>,
    /// The request queue. Its population is tracked in ONE place — the
    /// `metrics.queue_depth` gauge (incremented before enqueue, decremented
    /// after dequeue) — which both the workers' exit condition and the E17
    /// back-pressure plots read; no parallel counter to keep in sync.
    pub(crate) queue: Queue<Request, R>,
    shutdown: AtomicBool,
    /// Submits currently between their shutdown-flag check and their
    /// enqueue. `shutdown()` quiesces on this (Dekker-style pairing with
    /// the flag, see [`Shard::submit`]) so no enqueue can land after the
    /// post-join drain.
    active_submits: AtomicUsize,
    pub(crate) metrics: Metrics,
}

/// One shard: worker pool + cache + queue over one reclamation domain.
/// Started and stopped by its owning [`super::Router`].
pub struct Shard<R: Reclaimer> {
    index: usize,
    shared: Arc<ShardShared<R>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl<R: Reclaimer> Shard<R> {
    /// Spawn this shard's worker pool. Misses flow into `miss_tx` — this
    /// shard's **group** batcher — tagged with `slot`, the shard's index in
    /// that group's member list (the router computes both; see
    /// [`super::router::group_for_shard`]).
    pub(crate) fn start(
        index: usize,
        cfg: &ServerConfig,
        domain: DomainRef<R>,
        miss_tx: mpsc::Sender<Miss>,
        slot: usize,
    ) -> Result<Self> {
        let shared = Arc::new(ShardShared {
            cache: FifoCache::new_in(domain.clone(), cfg.buckets, cfg.capacity),
            queue: Queue::new_in(domain.clone()),
            domain,
            shutdown: AtomicBool::new(false),
            active_submits: AtomicUsize::new(0),
            metrics: Metrics::default(),
        });
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let worker_shared = shared.clone();
            let miss_tx = miss_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("emr-s{index}-w{w}"))
                .spawn(move || worker_loop(slot, &worker_shared, miss_tx));
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // Stop the workers already running before bailing.
                    shared.shutdown.store(true, Ordering::Release);
                    for t in workers {
                        let _ = t.join();
                    }
                    return Err(e.into());
                }
            }
        }
        Ok(Self { index, shared, workers: Mutex::new(workers) })
    }

    /// This shard's position in the router's fleet.
    pub fn index(&self) -> usize {
        self.index
    }

    /// Submit a request on the async path: the returned [`SubmitFuture`]
    /// resolves when a worker (hit) or the group's batcher (computed miss)
    /// fulfils the completion slot. Safe to drop mid-flight (cancellation —
    /// the shard fulfils a slot nobody reads; nothing leaks or wedges).
    ///
    /// After [`shutdown`](Self::shutdown) the future comes back already
    /// closed (polling it errors immediately) instead of waiting forever on
    /// workers that have exited — the stopped-server fix.
    pub fn submit_async(&self, key: u32) -> SubmitFuture {
        // Dekker-style pairing with shutdown(): mark this submit in-flight
        // *before* checking the flag (both SeqCst). Either we observe the
        // flag and reject, or shutdown()'s quiesce loop observes our
        // marker and waits for the enqueue below — so an enqueue can never
        // land after the post-join drain and leave its waiter hanging.
        self.shared.active_submits.fetch_add(1, Ordering::SeqCst);
        if self.shared.shutdown.load(Ordering::SeqCst) {
            self.shared.active_submits.fetch_sub(1, Ordering::Release);
            // Stopped: reject with an already-closed slot.
            return SubmitFuture::rejected();
        }
        let (tx, fut) = completion_pair();
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        // Flight recorder: correlation id + submit event. Trace-off cost
        // is the one `enabled()` branch (ids are only minted under it).
        let trace_id = if crate::trace::enabled() { crate::trace::next_request_id() } else { 0 };
        if trace_id != 0 {
            crate::trace::event!("shard.submit", trace_id);
        }
        // Incremented BEFORE the enqueue: a dequeuing worker's decrement is
        // then always preceded by its matching increment, so the u64 gauge
        // can never transiently underflow in a snapshot.
        self.shared.metrics.queue_depth.fetch_add(1, Ordering::Release);
        self.shared.queue.enqueue(
            Cached,
            Request {
                key,
                t0: monotonic_ns(),
                trace_id,
                reply: tx,
                _in_flight: self.shared.metrics.in_flight_token(),
            },
        );
        // Release: the enqueue happens-before shutdown() sees the count
        // drop, hence before the workers are joined and the queue drained.
        self.shared.active_submits.fetch_sub(1, Ordering::Release);
        fut
    }

    /// Blocking wrapper over [`Self::submit_async`]: the returned
    /// [`SubmitHandle`] waits with a deadline (`recv_timeout`), so a lost
    /// reply surfaces as an error instead of an eternal block.
    pub fn submit(&self, key: u32) -> SubmitHandle {
        SubmitHandle::new(self.submit_async(key))
    }

    pub(crate) fn shared(&self) -> &Arc<ShardShared<R>> {
        &self.shared
    }

    /// This shard's reclamation domain.
    pub fn domain(&self) -> &DomainRef<R> {
        &self.shared.domain
    }

    /// Entries currently cached in this shard.
    pub fn cache_len(&self) -> usize {
        self.shared.cache.len()
    }

    /// This shard's counters, with the unreclaimed-node count scoped to
    /// its own domain (in shared-domain mode every shard reports the same
    /// fleet-wide domain count).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot_with(self.shared.domain.domain().unreclaimed())
    }

    /// Stop this shard's workers. Requests already queued are drained and
    /// served first; anything that raced past the shutdown flag afterwards
    /// is rejected (its completion-slot fulfiller is dropped, so the waiter
    /// observes a closed slot instead of blocking forever).
    pub(crate) fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Quiesce submits that raced past the flag check (see submit()):
        // once this count reads 0 after the SeqCst flag store, every later
        // submit rejects, so no new enqueue can appear below. The load must
        // be SeqCst to close the store-buffering outcome (an Acquire load
        // is outside the SC order and could miss a SeqCst fetch_add); it
        // still carries Acquire, so the Release decrement's enqueue
        // happens-before the drain.
        while self.shared.active_submits.load(Ordering::SeqCst) != 0 {
            std::thread::yield_now();
        }
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for t in workers {
            let _ = t.join();
        }
        // Workers are gone; nothing will answer what is still queued.
        let handle = self.shared.domain.register();
        while let Some(req) = self.shared.queue.dequeue(&handle) {
            self.shared.metrics.queue_depth.fetch_sub(1, Ordering::Release);
            drop(req); // dropping the fulfiller closes the completion slot
        }
    }
}

fn worker_loop<R: Reclaimer>(slot: usize, shared: &ShardShared<R>, miss_tx: mpsc::Sender<Miss>) {
    // One registration for the worker's lifetime: every queue/cache
    // operation below runs TLS-free through this handle — one registered
    // handle serves a request's whole cache/queue path.
    let handle = shared.domain.register();
    let mut idle_spins = 0u32;
    loop {
        match shared.queue.dequeue(&handle) {
            Some(req) => {
                idle_spins = 0;
                shared.metrics.queue_depth.fetch_sub(1, Ordering::Release);
                // Crash-test injection (`serve --crash-test`): a worker
                // that dequeues the poison key panics right here, so the
                // trace panic hook's dump demonstrably survives a dying
                // worker. Unwinding drops the request, which closes its
                // completion slot — the submitter errors promptly.
                if req.key == super::CRASH_TEST_KEY && super::crash_test_enabled() {
                    panic!("crash-test: injected worker panic (slot {slot})");
                }
                // Guarded cache read: the payload is copied out under the
                // guard (the "reuse" path of the paper's simulation).
                let hit = shared.cache.get(&handle, &req.key, |v| Box::new(*v));
                match hit {
                    Some(data) => {
                        shared.metrics.hits.fetch_add(1, Ordering::Relaxed);
                        let Request { t0, trace_id, reply, _in_flight: token, .. } = req;
                        // Close the in-flight gauge BEFORE the send wakes
                        // the waiter: the waiter may release a budget permit
                        // that admits the next request, and the gauge must
                        // never read above shards × budget (the bound the
                        // back-pressure test asserts).
                        drop(token);
                        if trace_id != 0 {
                            crate::trace::event!("shard.complete", trace_id);
                        }
                        reply.send(Response {
                            data,
                            hit: true,
                            latency_ns: monotonic_ns() - t0,
                        });
                    }
                    None => {
                        shared.metrics.misses.fetch_add(1, Ordering::Relaxed);
                        if miss_tx.send(Miss { slot, req }).is_err() {
                            return; // batcher gone: shutting down
                        }
                    }
                }
            }
            None => {
                if shared.shutdown.load(Ordering::Acquire)
                    && shared.metrics.queue_depth.load(Ordering::Acquire) == 0
                {
                    return;
                }
                // Lock-free queues cannot block; back off politely.
                idle_spins += 1;
                if idle_spins < 32 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(100));
                }
            }
        }
    }
}

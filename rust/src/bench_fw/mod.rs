//! Benchmark framework: regenerates every table/figure of the paper's
//! evaluation (§4, Appendix A).
//!
//! * [`runner`] — timed throughput trials with the paper's metric: each
//!   thread's active runtime ÷ its operation count, averaged over threads.
//! * [`workload`] — the three benchmark workloads (Queue, List, HashMap)
//!   with the paper's parameters.
//! * [`sampler`] — unreclaimed-node time series (50 samples per trial),
//!   the §4.4 reclamation-efficiency measurement.
//! * [`report`] — aligned tables, CSV output, and the Table-1-style
//!   environment dump.
//! * [`figures`] — one entry point per paper figure, plus the post-paper
//!   serving/robustness figures (E16 shard scaling, E17 async mux, E18
//!   net front, E19 stalled-guard adversary, E20 allocator ablation);
//!   shared by the `repro` CLI and the `cargo bench` targets.

pub mod figures;
pub mod report;
pub mod runner;
pub mod sampler;
pub mod workload;

use crate::alloc::Policy;
use crate::reclaim::SchemeId;
use crate::util::cli::Args;
use std::time::Duration;

/// Parameters shared by all benchmarks. Defaults are CI-scale; `--paper`
/// switches to the paper's trial counts and durations (§4.1: 30 trials of
/// 8 s; efficiency plots: 5 trials).
#[derive(Clone, Debug)]
pub struct BenchParams {
    /// Thread counts to sweep.
    pub threads: Vec<usize>,
    /// Trials per configuration (all within one process, like the paper).
    pub trials: usize,
    /// Seconds per trial.
    pub secs: f64,
    /// Schemes to compare.
    pub schemes: Vec<SchemeId>,
    /// Node allocator (pool = jemalloc-like, system = libc; App. A.3).
    pub alloc: Policy,
    /// Per-thread magazine capacity for pool allocations (`--magazines
    /// on|off|<cap>`): 0 disables the layer, the default is
    /// [`crate::alloc::DEFAULT_MAGAZINE_CAP`]. E20 ablation axis.
    pub magazine_cap: usize,
    /// Per-thread flight-recorder ring capacity (`--trace on|off|<cap>`):
    /// 0 disables recording (trace-off is one relaxed-atomic branch per
    /// instrumentation site), the default is
    /// [`crate::trace::DEFAULT_RING_CAP`]. The trace-overhead ablation
    /// axis; applied per cell via [`crate::trace::apply_knob`].
    pub trace_cap: usize,
    /// Operations spanned by one region_guard (paper: 100).
    pub region_ops: usize,
    /// List benchmark: initial size (paper: 10; key range = 2×size).
    pub list_size: u64,
    /// List benchmark: update fraction in percent (paper: 20 / 80).
    pub workload_pct: u32,
    /// HashMap benchmark: bucket count (paper: 2048).
    pub map_buckets: usize,
    /// HashMap benchmark: max entries (paper: 10000).
    pub map_capacity: usize,
    /// HashMap benchmark: possible partial results (paper: 30000).
    pub key_space: u64,
    /// Samples per trial in efficiency plots (paper: 50).
    pub samples: usize,
    /// Shard counts to sweep in the `shard_scaling` figure.
    pub shards: Vec<usize>,
    /// Engine-group counts to sweep (`--groups`) in the serving figures
    /// (E16/E17/E18): each group runs its own batcher/engine thread, so
    /// this is the miss-compute parallelism axis (DESIGN.md §9).
    pub groups: Vec<usize>,
    /// Logical-client counts swept by the E17 `async_scaling` figure.
    pub mux_clients: Vec<usize>,
    /// Concurrent TCP-connection counts swept by the E18 `net_scaling`
    /// figure (`--conns`).
    pub net_conns: Vec<usize>,
    /// Executor threads the async/net front-ends run on (E17/E18).
    pub exec_threads: usize,
    /// Write a CSV next to the human-readable table.
    pub csv: Option<String>,
}

impl Default for BenchParams {
    fn default() -> Self {
        Self {
            threads: vec![1, 2, 4],
            trials: 3,
            secs: 0.4,
            schemes: SchemeId::PAPER_SET.to_vec(),
            alloc: Policy::Pool,
            magazine_cap: crate::alloc::DEFAULT_MAGAZINE_CAP,
            trace_cap: crate::trace::DEFAULT_RING_CAP,
            region_ops: 100,
            list_size: 10,
            workload_pct: 20,
            map_buckets: 2048,
            map_capacity: 10_000,
            key_space: 30_000,
            samples: 50,
            shards: vec![1, 2, 4, 8],
            groups: vec![1],
            mux_clients: vec![1_000, 10_000],
            net_conns: vec![100, 1_000],
            exec_threads: 8,
            csv: None,
        }
    }
}

impl BenchParams {
    /// Parse CLI arguments (shared by `repro` and the bench targets).
    pub fn from_args(args: &Args) -> Self {
        let mut p = BenchParams::default();
        if args.flag("paper") {
            // Paper scale (§4.1): 30 × 8 s throughput trials; the
            // efficiency analysis uses 5 × 8 s.
            p.trials = 30;
            p.secs = 8.0;
            p.threads = vec![1, 2, 4, 8, 16, 32, 48];
            // Full E17 sweep: up to 100k logical clients on the mux.
            p.mux_clients = vec![1_000, 10_000, 100_000];
            // Full E18 sweep: the 10k-connection acceptance point.
            p.net_conns = vec![100, 1_000, 10_000];
        }
        p.threads = args.list_or("threads", &p.threads);
        p.trials = args.usize_or("trials", p.trials);
        p.secs = args.f64_or("secs", p.secs);
        if let Some(s) = args.get("schemes") {
            p.schemes = SchemeId::parse_list(s).unwrap_or_else(|| {
                eprintln!("unknown scheme in --schemes {s}");
                std::process::exit(2);
            });
        }
        if let Some(a) = args.get("alloc") {
            p.alloc = Policy::parse(a).unwrap_or_else(|| {
                eprintln!("unknown allocator {a} (pool|system)");
                std::process::exit(2);
            });
        }
        if let Some(m) = args.get("magazines") {
            p.magazine_cap = match m {
                "on" | "true" => crate::alloc::DEFAULT_MAGAZINE_CAP,
                "off" | "false" => 0,
                n => n.parse().unwrap_or_else(|_| {
                    eprintln!("invalid --magazines {n} (on|off|<cap>)");
                    std::process::exit(2);
                }),
            };
        }
        if let Some(t) = args.get("trace") {
            p.trace_cap = crate::trace::parse_knob(t).unwrap_or_else(|| {
                eprintln!("invalid --trace {t} (on|off|<cap>)");
                std::process::exit(2);
            });
        }
        p.region_ops = args.usize_or("region-ops", p.region_ops);
        p.list_size = args.u64_or("list-size", p.list_size);
        p.workload_pct = args.usize_or("workload", p.workload_pct as usize) as u32;
        p.map_buckets = args.usize_or("buckets", p.map_buckets);
        p.map_capacity = args.usize_or("capacity", p.map_capacity);
        p.key_space = args.u64_or("keys", p.key_space);
        p.samples = args.usize_or("samples", p.samples);
        p.shards = args.list_or("shards", &p.shards);
        p.groups = args.list_or("groups", &p.groups);
        p.mux_clients = args.list_or("clients", &p.mux_clients);
        p.net_conns = args.list_or("conns", &p.net_conns);
        p.exec_threads = args.usize_or("exec-threads", p.exec_threads);
        p.csv = args.get("csv").map(String::from);
        p
    }

    /// Trial duration.
    pub fn duration(&self) -> Duration {
        Duration::from_secs_f64(self.secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_ci_scale() {
        let p = BenchParams::default();
        assert!(p.secs < 1.0);
        assert_eq!(p.map_buckets, 2048);
        assert_eq!(p.map_capacity, 10_000);
        assert_eq!(p.key_space, 30_000);
        assert_eq!(p.region_ops, 100);
    }

    #[test]
    fn paper_flag_scales_up() {
        let args = Args::parse_from(["--paper".to_string()]);
        let p = BenchParams::from_args(&args);
        assert_eq!(p.trials, 30);
        assert_eq!(p.secs, 8.0);
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse_from(
            "--threads 2,8 --secs 0.1 --schemes ebr,stamp --alloc system --workload 80"
                .split_whitespace()
                .map(String::from),
        );
        let p = BenchParams::from_args(&args);
        assert_eq!(p.threads, vec![2, 8]);
        assert_eq!(p.schemes, vec![SchemeId::Ebr, SchemeId::Stamp]);
        assert_eq!(p.alloc, Policy::System);
        assert_eq!(p.workload_pct, 80);
    }

    #[test]
    fn groups_axis_parses() {
        let parse = |s: &str| {
            BenchParams::from_args(&Args::parse_from(s.split_whitespace().map(String::from)))
        };
        assert_eq!(parse("").groups, vec![1], "default: the single-batcher fleet");
        assert_eq!(parse("--groups 1,2,4").groups, vec![1, 2, 4]);
    }

    #[test]
    fn magazines_axis_parses() {
        let parse = |s: &str| {
            BenchParams::from_args(&Args::parse_from(s.split_whitespace().map(String::from)))
        };
        assert_eq!(parse("").magazine_cap, crate::alloc::DEFAULT_MAGAZINE_CAP);
        assert_eq!(parse("--magazines on").magazine_cap, crate::alloc::DEFAULT_MAGAZINE_CAP);
        assert_eq!(parse("--magazines off").magazine_cap, 0);
        assert_eq!(parse("--magazines 16").magazine_cap, 16);
    }

    #[test]
    fn trace_axis_parses() {
        let parse = |s: &str| {
            BenchParams::from_args(&Args::parse_from(s.split_whitespace().map(String::from)))
        };
        assert_eq!(parse("").trace_cap, crate::trace::DEFAULT_RING_CAP);
        assert_eq!(parse("--trace on").trace_cap, crate::trace::DEFAULT_RING_CAP);
        assert_eq!(parse("--trace off").trace_cap, 0);
        assert_eq!(parse("--trace 4096").trace_cap, 4096);
    }
}

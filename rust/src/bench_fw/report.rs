//! Output: aligned tables (the paper's rows/series), CSV files for
//! re-plotting, and the Table-1-style testbed description.

use crate::util::stats::fmt_ns;
use std::io::Write;

/// A throughput-sweep table: scheme × thread-count → mean ns/op.
pub struct SweepTable {
    pub title: String,
    pub threads: Vec<usize>,
    /// (scheme name, per-thread-count mean ns/op).
    pub rows: Vec<(String, Vec<f64>)>,
}

impl SweepTable {
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        print!("{:<10}", "scheme");
        for t in &self.threads {
            print!("{:>12}", format!("p={t}"));
        }
        println!();
        for (name, values) in &self.rows {
            print!("{name:<10}");
            for v in values {
                print!("{:>12}", fmt_ns(*v));
            }
            println!();
        }
    }

    /// CSV: `scheme,threads,ns_per_op`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("scheme,threads,ns_per_op\n");
        for (name, values) in &self.rows {
            for (t, v) in self.threads.iter().zip(values) {
                out.push_str(&format!("{name},{t},{v:.3}\n"));
            }
        }
        out
    }
}

/// A time-series table: scheme → (sample index, unreclaimed nodes).
pub struct SeriesTable {
    pub title: String,
    /// (scheme name, series of (index, value)).
    pub rows: Vec<(String, Vec<(usize, f64)>)>,
}

impl SeriesTable {
    /// Print a compact summary: start / mid / end / peak of each series
    /// (full resolution goes to the CSV).
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        println!(
            "{:<10}{:>14}{:>14}{:>14}{:>14}",
            "scheme", "start", "mid", "end", "peak"
        );
        for (name, series) in &self.rows {
            if series.is_empty() {
                println!("{name:<10}{:>14}", "-");
                continue;
            }
            let vals: Vec<f64> = series.iter().map(|(_, v)| *v).collect();
            let peak = vals.iter().cloned().fold(f64::MIN, f64::max);
            println!(
                "{name:<10}{:>14.0}{:>14.0}{:>14.0}{:>14.0}",
                vals[0],
                vals[vals.len() / 2],
                vals[vals.len() - 1],
                peak
            );
        }
    }

    /// CSV: `scheme,sample,value`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("scheme,sample,value\n");
        for (name, series) in &self.rows {
            for (i, v) in series {
                out.push_str(&format!("{name},{i},{v:.1}\n"));
            }
        }
        out
    }
}

/// Write CSV content if a path was requested.
pub fn maybe_write_csv(path: &Option<String>, content: &str) {
    if let Some(path) = path {
        match std::fs::File::create(path).and_then(|mut f| f.write_all(content.as_bytes())) {
            Ok(()) => println!("(csv written to {path})"),
            Err(e) => eprintln!("csv write failed ({path}): {e}"),
        }
    }
}

/// Table-1 analogue: describe this testbed.
pub fn print_environment() {
    println!("== Environment (Table 1 analogue) ==");
    println!("{:<18}{}", "Hardware threads", crate::util::num_cpus());
    for (label, path) in
        [("CPU model", "/proc/cpuinfo"), ("MemTotal", "/proc/meminfo"), ("OS", "/proc/version")]
    {
        let value = std::fs::read_to_string(path)
            .ok()
            .and_then(|s| match label {
                "CPU model" => s
                    .lines()
                    .find(|l| l.starts_with("model name"))
                    .map(|l| l.split(':').nth(1).unwrap_or("?").trim().to_string()),
                "MemTotal" => s
                    .lines()
                    .find(|l| l.starts_with("MemTotal"))
                    .map(|l| l.split(':').nth(1).unwrap_or("?").trim().to_string()),
                _ => s.lines().next().map(|l| l.trim().to_string()),
            })
            .unwrap_or_else(|| "unknown".into());
        println!("{label:<18}{value}");
    }
    println!("{:<18}rustc 1.95 (release, thin-LTO)", "Compiler");
    println!(
        "{:<18}{} (pool = jemalloc-like type-stable slabs)",
        "Allocator",
        crate::alloc::policy().name()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_table_csv_shape() {
        let t = SweepTable {
            title: "test".into(),
            threads: vec![1, 2],
            rows: vec![("A".into(), vec![10.0, 20.0]), ("B".into(), vec![30.0, 40.0])],
        };
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.contains("A,2,20.000"));
        t.print(); // must not panic
    }

    #[test]
    fn series_table_csv_shape() {
        let t = SeriesTable {
            title: "eff".into(),
            rows: vec![("A".into(), vec![(0, 5.0), (1, 6.0)])],
        };
        let csv = t.to_csv();
        assert!(csv.contains("A,1,6.0"));
        t.print();
    }

    #[test]
    fn environment_prints() {
        print_environment();
    }
}

//! One entry point per paper figure / experiment (DESIGN.md §3).
//!
//! Each function prints the same rows/series the paper reports and
//! optionally writes a CSV. Shared by the `repro` CLI and the
//! `cargo bench` targets (`rust/benches/*.rs`).
//!
//! Every configuration (scheme × thread count) builds its structures in a
//! fresh reclamation domain, so no state leaks between configurations; a
//! structure retained across trials *within* one configuration (the
//! HashMap warm-up behaviour, Fig. 7) keeps its domain — the paper's
//! deliberate same-process warm-up, now scoped to exactly one sweep cell.

use super::report::{maybe_write_csv, SeriesTable, SweepTable};
use super::runner::{run_trial, ConfigResult};
use super::sampler::sample_during;
use super::workload::*;
use super::BenchParams;
use crate::dispatch_scheme;
use crate::reclaim::{DomainRef, Reclaimer};
use crate::util::rng::Xoshiro256;
use crate::util::stats::fmt_ns;

/// Which benchmark workload a figure runs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Workload {
    Queue,
    List,
    HashMap,
}

impl Workload {
    pub fn name(self) -> &'static str {
        match self {
            Workload::Queue => "Queue",
            Workload::List => "List",
            Workload::HashMap => "HashMap",
        }
    }
}

/// Run one scheme's thread sweep for `workload`; returns mean ns/op per
/// thread count. Each thread count runs against structures in a fresh
/// domain (dropped — and drained — when the configuration ends).
fn sweep_one<R: Reclaimer>(p: &BenchParams, workload: Workload) -> Vec<f64> {
    crate::alloc::set_policy(p.alloc);
    crate::alloc::set_magazine_cap(p.magazine_cap);
    p.threads
        .iter()
        .map(|&threads| {
            let mut cfg = ConfigResult::default();
            match workload {
                Workload::Queue => {
                    let q = prefill_queue::<R>(p);
                    for trial in 0..p.trials {
                        cfg.push(&run_trial(threads, p.duration(), |tid, stop| {
                            queue_worker(&q, p, tid, trial, stop)
                        }));
                    }
                }
                Workload::List => {
                    let list = prefill_list::<R>(p);
                    for trial in 0..p.trials {
                        cfg.push(&run_trial(threads, p.duration(), |tid, stop| {
                            list_worker(&list, p, tid, trial, stop)
                        }));
                    }
                }
                Workload::HashMap => {
                    // Retained across trials within a configuration — the
                    // paper's deliberate same-process warm-up behaviour.
                    let cache = make_cache::<R>(p);
                    for trial in 0..p.trials {
                        cfg.push(&run_trial(threads, p.duration(), |tid, stop| {
                            hashmap_worker(&cache, p, tid, trial, stop)
                        }));
                    }
                }
            }
            // Structures (and their domains) drop here: `Domain::drop`
            // drains every parked node before the next configuration.
            cfg.mean_ns_per_op()
        })
        .collect()
}

/// Build the Figures 3/4/5 (12/13/14 with `--alloc system`) throughput
/// sweep table without printing it — the JSON-recording bench target
/// (`fig12_19_alloc`) consumes the rows directly; [`fig_throughput`] is
/// the printing wrapper.
pub fn throughput_table(p: &BenchParams, workload: Workload) -> SweepTable {
    let extra = match workload {
        Workload::List => format!(
            " ({} elements, {}% updates)",
            p.list_size, p.workload_pct
        ),
        Workload::HashMap => format!(
            " ({} buckets, cap {}, {} keys)",
            p.map_buckets, p.map_capacity, p.key_space
        ),
        Workload::Queue => String::new(),
    };
    let mut table = SweepTable {
        title: format!(
            "{} benchmark{extra} — avg runtime per operation [alloc={}]",
            workload.name(),
            p.alloc.name()
        ),
        threads: p.threads.clone(),
        rows: Vec::new(),
    };
    for &scheme in &p.schemes {
        // The paper omits LFRC from the List plot (Fig. 4: "performs
        // exceedingly poor") but we still run it when asked explicitly.
        let row = dispatch_scheme!(scheme, sweep_one, p, workload);
        table.rows.push((scheme.name().to_string(), row));
    }
    table
}

/// Figures 3/4/5 (and 12/13/14 with `--alloc system`): throughput sweeps.
pub fn fig_throughput(p: &BenchParams, workload: Workload) {
    let table = throughput_table(p, workload);
    table.print();
    maybe_write_csv(&p.csv, &table.to_csv());
}

/// One scheme's efficiency run: `p.trials` trials at the max thread count,
/// 50 samples each, structure (and domain) retained across trials. Returns
/// the series of (sample index, unreclaimed-above-baseline).
fn efficiency_one<R: Reclaimer>(p: &BenchParams, workload: Workload) -> Vec<(usize, f64)> {
    crate::alloc::set_policy(p.alloc);
    crate::alloc::set_magazine_cap(p.magazine_cap);
    // Fresh domain per scheme run: baseline the global counter first.
    let baseline = crate::alloc::unreclaimed();
    let threads = *p.threads.iter().max().unwrap_or(&2);
    let mut series = Vec::with_capacity(p.trials * p.samples);

    match workload {
        Workload::Queue => {
            let q = prefill_queue::<R>(p);
            for trial in 0..p.trials {
                let offset = trial * p.samples;
                let (samples, _) = sample_during(p.samples, p.duration(), offset, |stop| {
                    std::thread::scope(|scope| {
                        for tid in 0..threads {
                            let q = &q;
                            scope.spawn(move || queue_worker(q, p, tid, trial, stop));
                        }
                    })
                });
                for s in samples {
                    series.push((s.index, s.unreclaimed.saturating_sub(baseline) as f64));
                }
            }
        }
        Workload::List => {
            let list = prefill_list::<R>(p);
            for trial in 0..p.trials {
                let offset = trial * p.samples;
                let (samples, _) = sample_during(p.samples, p.duration(), offset, |stop| {
                    std::thread::scope(|scope| {
                        for tid in 0..threads {
                            let list = &list;
                            scope.spawn(move || list_worker(list, p, tid, trial, stop));
                        }
                    })
                });
                for s in samples {
                    series.push((s.index, s.unreclaimed.saturating_sub(baseline) as f64));
                }
            }
        }
        Workload::HashMap => {
            let cache = make_cache::<R>(p);
            for trial in 0..p.trials {
                let offset = trial * p.samples;
                let (samples, _) = sample_during(p.samples, p.duration(), offset, |stop| {
                    std::thread::scope(|scope| {
                        for tid in 0..threads {
                            let cache = &cache;
                            scope.spawn(move || hashmap_worker(cache, p, tid, trial, stop));
                        }
                    })
                });
                for s in samples {
                    series.push((s.index, s.unreclaimed.saturating_sub(baseline) as f64));
                }
            }
        }
    }
    series
}

/// Build the Figures 6/8–11 (16–19 with `--alloc system`) efficiency
/// series table without printing it (see [`throughput_table`] for why).
pub fn efficiency_table(p: &BenchParams, workload: Workload) -> SeriesTable {
    let threads = *p.threads.iter().max().unwrap_or(&2);
    let mut table = SeriesTable {
        title: format!(
            "{} reclamation efficiency — unreclaimed nodes over {} trials × {} samples, \
             p={} [alloc={}]",
            workload.name(),
            p.trials,
            p.samples,
            threads,
            p.alloc.name()
        ),
        rows: Vec::new(),
    };
    for &scheme in &p.schemes {
        let series = dispatch_scheme!(scheme, efficiency_one, p, workload);
        table.rows.push((scheme.name().to_string(), series));
    }
    table
}

/// Figures 6 and 8–11 (16–19 with `--alloc system`): unreclaimed nodes over
/// time.
pub fn fig_efficiency(p: &BenchParams, workload: Workload) {
    let table = efficiency_table(p, workload);
    table.print();
    maybe_write_csv(&p.csv, &table.to_csv());
}

/// One scheme's warm-up run (Fig. 7/15): runtime per op per trial, cache
/// (and its domain) retained across trials.
fn trials_one<R: Reclaimer>(p: &BenchParams) -> Vec<f64> {
    crate::alloc::set_policy(p.alloc);
    crate::alloc::set_magazine_cap(p.magazine_cap);
    let threads = *p.threads.iter().max().unwrap_or(&2);
    let cache = make_cache::<R>(p);
    let mut per_trial = Vec::with_capacity(p.trials);
    for trial in 0..p.trials {
        let r = run_trial(threads, p.duration(), |tid, stop| {
            hashmap_worker(&cache, p, tid, trial, stop)
        });
        per_trial.push(r.avg_ns_per_op);
    }
    per_trial
}

/// Figure 7 (15): development of HashMap runtime over trials.
pub fn fig7_trials(p: &BenchParams) {
    let threads = *p.threads.iter().max().unwrap_or(&2);
    let mut table = SweepTable {
        title: format!(
            "HashMap runtime over trials (warm-up; p={threads}) — avg ns/op per trial [alloc={}]",
            p.alloc.name()
        ),
        threads: (1..=p.trials).collect(),
        rows: Vec::new(),
    };
    for &scheme in &p.schemes {
        let row = dispatch_scheme!(scheme, trials_one, p);
        table.rows.push((scheme.name().to_string(), row));
    }
    // Rename header semantics: columns are trial indices here.
    println!("\n(columns are trial numbers, not thread counts)");
    table.print();
    maybe_write_csv(&p.csv, &table.to_csv());
}

/// E13: cost of a region enter/exit cycle per scheme vs thread count. Each
/// thread registers one handle with a fresh domain and cycles through it —
/// the TLS-free fast path this refactor targets (the seed paid a
/// thread-local + `RefCell` lookup per cycle).
fn region_cycle_one<R: Reclaimer>(p: &BenchParams) -> Vec<f64> {
    p.threads
        .iter()
        .map(|&threads| {
            let domain = DomainRef::<R>::new_owned();
            let mut cfg = ConfigResult::default();
            for _ in 0..p.trials {
                let domain = &domain;
                cfg.push(&run_trial(threads, p.duration(), |_tid, stop| {
                    let h = domain.register();
                    let mut ops = 0u64;
                    while !stop.load(std::sync::atomic::Ordering::Acquire) {
                        let region = crate::reclaim::Region::enter(&h);
                        std::hint::black_box(&region);
                        drop(region);
                        ops += 1;
                    }
                    ops
                }));
            }
            cfg.mean_ns_per_op()
        })
        .collect()
}

/// E13 (Propositions 2/3): region enter+exit microbenchmark.
pub fn micro_region(p: &BenchParams) {
    let mut table = SweepTable {
        title: "region enter+exit cycle cost (cached handle, no TLS)".into(),
        threads: p.threads.clone(),
        rows: Vec::new(),
    };
    for &scheme in &p.schemes {
        let row = dispatch_scheme!(scheme, region_cycle_one, p);
        table.rows.push((scheme.name().to_string(), row));
    }
    table.print();
    maybe_write_csv(&p.csv, &table.to_csv());
}

/// E14: Stamp Pool push/remove cycle cost vs thread count.
pub fn micro_stamp_pool(p: &BenchParams) {
    use crate::reclaim::stamp::pool::StampPool;
    let mut table = SweepTable {
        title: "Stamp Pool push+remove cycle cost".into(),
        threads: p.threads.clone(),
        rows: Vec::new(),
    };
    let row: Vec<f64> = p
        .threads
        .iter()
        .map(|&threads| {
            let pool = StampPool::new(threads + 2);
            let mut cfg = ConfigResult::default();
            for _ in 0..p.trials {
                cfg.push(&run_trial(threads, p.duration(), |_tid, stop| {
                    let b = pool.alloc_block();
                    let mut ops = 0u64;
                    while !stop.load(std::sync::atomic::Ordering::Acquire) {
                        pool.push(b);
                        pool.remove(b);
                        ops += 1;
                    }
                    pool.free_block(b);
                    ops
                }));
            }
            cfg.mean_ns_per_op()
        })
        .collect();
    table.rows.push(("StampPool".into(), row));
    table.print();
    maybe_write_csv(&p.csv, &table.to_csv());
    println!(
        "(expected: roughly flat in p — the paper's 'expected average runtime … is constant')"
    );
}

/// One scheme's steady-state node-churn sweep with the magazine capacity
/// pinned to `cap`: every op is an `Owned::new` + immediate `retire_owned`
/// (the retire→reuse cycle the magazine layer closes), with a periodic
/// `flush` so deferred schemes actually reclaim — and thereby refill the
/// allocator — inside the loop. Mean ns per cycle, per thread count.
fn churn_one<R: Reclaimer>(p: &BenchParams, cap: usize) -> Vec<f64> {
    crate::alloc::set_policy(p.alloc);
    crate::alloc::set_magazine_cap(cap);
    p.threads
        .iter()
        .map(|&threads| {
            let domain = DomainRef::<R>::new_owned();
            let mut cfg = ConfigResult::default();
            for _ in 0..p.trials {
                let domain = &domain;
                cfg.push(&run_trial(threads, p.duration(), |_tid, stop| {
                    let h = domain.register();
                    let mut ops = 0u64;
                    while !stop.load(std::sync::atomic::Ordering::Acquire) {
                        h.retire_owned(crate::reclaim::Owned::<u64, R>::new(ops));
                        ops += 1;
                        if ops % 64 == 0 {
                            h.flush();
                        }
                    }
                    h.flush();
                    ops
                }));
            }
            cfg.mean_ns_per_op()
        })
        .collect()
}

/// The capacity `--magazines on` (and the gate's "on" arm) resolves to:
/// the explicit `--magazines <cap>` value if one was given, else the
/// default.
fn resolved_mag_cap(p: &BenchParams) -> usize {
    if p.magazine_cap == 0 {
        crate::alloc::DEFAULT_MAGAZINE_CAP
    } else {
        p.magazine_cap
    }
}

/// E20: steady-state node churn (alloc+retire cycle) per scheme, magazines
/// **on vs off** — the ISSUE-6 win condition made visible: with the
/// retire→reuse loop closed in TLS, per-op cost should drop and keep
/// dropping relative to "off" as threads (and free-list contention) grow.
pub fn micro_alloc(p: &BenchParams) {
    let on_cap = resolved_mag_cap(p);
    let mut table = SweepTable {
        title: format!(
            "node churn: Owned::new + retire_owned cycle — magazines on (cap {on_cap}) \
             vs off [alloc={}]",
            p.alloc.name()
        ),
        threads: p.threads.clone(),
        rows: Vec::new(),
    };
    let before = crate::alloc::magazine_stats();
    for &scheme in &p.schemes {
        for (label, cap) in [("on", on_cap), ("off", 0usize)] {
            let row = dispatch_scheme!(scheme, churn_one, p, cap);
            table.rows.push((format!("{} mag={label}", scheme.name()), row));
        }
    }
    crate::alloc::set_magazine_cap(crate::alloc::DEFAULT_MAGAZINE_CAP);
    table.print();
    let after = crate::alloc::magazine_stats();
    println!(
        "magazine traffic this figure: hits={} misses={} depot_flushes={} depot_refills={} \
         (pool footprint {} KiB)",
        after.alloc_hits - before.alloc_hits,
        after.alloc_misses - before.alloc_misses,
        after.depot_flushes - before.depot_flushes,
        after.depot_refills - before.depot_refills,
        crate::alloc::pool::footprint_bytes() / 1024,
    );
    maybe_write_csv(&p.csv, &table.to_csv());
}

/// E20 CI regression gate. Verifies, in order:
///
/// 1. **magazines pay for themselves** — on the ≥4-thread churn, the
///    magazines-on cycle is not slower than magazines-off beyond 10%
///    (relative, machine-independent; always enforced — the tentpole's
///    acceptance criterion with slack for noisy shared runners);
/// 2. **churn-cost regression** — per-scheme magazines-on cycle cost,
///    normalized by [`calibration_ns`], has not regressed >20% against the
///    runner-recorded baseline (`rust/ci/runner_alloc_baseline.csv`).
///
/// With `record`, (re)writes the baseline file instead of gating against
/// it. Returns false when any gate fails.
pub fn micro_alloc_gate(p: &BenchParams, baseline: Option<&str>, record: Option<&str>) -> bool {
    // The win condition is contention relief, so gate at ≥4 threads even
    // if the sweep list is smaller.
    let threads = (*p.threads.iter().max().unwrap_or(&4)).max(4);
    let gate_p = BenchParams { threads: vec![threads], ..p.clone() };
    let on_cap = resolved_mag_cap(p);
    let calib = calibration_ns();
    println!("== micro_alloc gate (p={threads}, cap {on_cap}, calibration: {calib:.3} ns/mix64) ==");

    let mut ok = true;
    let mut measured: Vec<(String, f64)> = Vec::new();
    println!("{:<10}{:>12}{:>12}{:>10}", "scheme", "on ns/op", "off ns/op", "speedup");
    for &scheme in &p.schemes {
        let on = dispatch_scheme!(scheme, churn_one, &gate_p, on_cap)[0];
        let off = dispatch_scheme!(scheme, churn_one, &gate_p, 0usize)[0];
        println!(
            "{:<10}{:>12}{:>12}{:>9.2}x",
            scheme.name(),
            fmt_ns(on),
            fmt_ns(off),
            off / on.max(1e-9)
        );
        if on > off * 1.10 {
            eprintln!(
                "GATE FAIL: magazines-on churn slower than off for {} \
                 ({on:.1} ns vs {off:.1} ns at p={threads})",
                scheme.name()
            );
            ok = false;
        }
        measured.push((format!("alloc:{}", scheme.name()), on / calib));
    }
    crate::alloc::set_magazine_cap(crate::alloc::DEFAULT_MAGAZINE_CAP);

    if let Some(path) = record {
        let mut out = String::from(
            "# micro_alloc baseline: magazines-on node-churn cycle cost per scheme,\n\
             # in units of the calibration loop (ns per dependent mix64 step) so the\n\
             # file transfers across hosts of different absolute speed.\n\
             # Re-record: cargo bench --bench micro_alloc -- --record <this file>\n",
        );
        for (name, ratio) in &measured {
            out.push_str(&format!("{name},{ratio:.2}\n"));
        }
        if let Err(e) = std::fs::write(path, out) {
            eprintln!("cannot write baseline {path}: {e}");
            return false;
        }
        println!("baseline recorded to {path}");
        return ok;
    }

    if let Some(path) = baseline {
        match std::fs::read_to_string(path) {
            Ok(content) => {
                ok &= check_baseline(&measured, &content);
            }
            Err(e) => {
                eprintln!("cannot read baseline {path}: {e} — failing the gate");
                ok = false;
            }
        }
    }
    ok
}

/// One shard-scaling measurement cell. Public so the `shard_scaling`
/// bench target can flatten the sweep into `BENCH_fig_shard_scaling.json`
/// and gate the groups-axis speedup.
pub struct ShardCell {
    /// [`Reclaimer::NAME`] of the scheme under test.
    pub scheme: &'static str,
    /// Domain mode: `"dom/shard"` or `"shared-dom"`.
    pub mode: &'static str,
    pub shards: usize,
    /// Engine groups the fleet actually ran (post-clamp).
    pub groups: usize,
    pub ops_per_sec: f64,
    pub hit_rate: f64,
    /// Trace-derived request latency percentiles: submit→complete pairs
    /// harvested from the flight recorder by a [`crate::trace::LatencyRecorder`]
    /// (0 under `--trace off`).
    pub trace_p50_ns: u64,
    pub trace_p99_ns: u64,
    pub trace_p999_ns: u64,
    /// Submit/complete pairs behind those percentiles.
    pub trace_pairs: u64,
    /// Batch dispatches summed over every group's engine.
    pub batches: u64,
    pub unreclaimed: u64,
    pub shard_requests: Vec<u64>,
    pub shard_unreclaimed: Vec<u64>,
    /// Batch dispatches per engine group (index = group id): the direct
    /// evidence every group's batcher carried load.
    pub group_batches: Vec<u64>,
}

/// Run one (scheme, shard count, group count, domain mode) cell of the
/// shard-scaling figure: the **full Router stack** (shards, worker pools,
/// per-group batchers) on the synthetic backend — artifact-free — under a
/// skewed client load (80% of requests on a hot set, so per-shard load is
/// uneven: the reclamation-robustness axis of the Hyaline comparison).
fn shard_scaling_cell<R: Reclaimer>(
    p: &BenchParams,
    shards: usize,
    groups: usize,
    shared_domain: bool,
) -> ShardCell {
    use crate::coordinator::{Backend, Router, ServerConfig};
    let shards = shards.max(1); // tolerate a 0 in --shards like with_shards does
    let clients = *p.threads.iter().max().unwrap_or(&4);
    crate::trace::apply_knob(p.trace_cap);
    let server = Router::<R>::start(
        ServerConfig {
            // One worker per shard: the sweep varies shard count, not total
            // thread budget per shard. Capacity/buckets are split so the
            // fleet-wide cache stays comparable across shard counts.
            workers: 1,
            buckets: (p.map_buckets / shards).max(64),
            capacity: (p.map_capacity / shards).max(64),
            ..ServerConfig::default()
        }
        .with_shards(shards)
        .with_groups(groups)
        .with_shared_domain(shared_domain)
        .with_backend(Backend::synthetic()),
    )
    .expect("router start (synthetic backend)");
    // Flight-recorder harvest: pairs shard.submit/shard.complete events
    // into the cell's p50/p99/p999 while the load runs (a no-op under
    // `--trace off` — nothing is emitted to pair).
    let recorder = crate::trace::LatencyRecorder::spawn(std::time::Duration::from_millis(2));
    let mut cfg = ConfigResult::default();
    for trial in 0..p.trials {
        let server = &server;
        cfg.push(&run_trial(clients, p.duration(), |tid, stop| {
            let mut rng = Xoshiro256::new(0x5CA1E ^ ((trial as u64) << 32) ^ tid as u64);
            let mut ops = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Acquire) {
                let key = rng.skewed_key(p.key_space, 80);
                let _ = server.request(key).expect("router request");
                ops += 1;
            }
            ops
        }));
    }
    let lat = recorder.stop();
    let agg = server.metrics();
    let per_shard = server.shard_metrics();
    let cell = ShardCell {
        scheme: R::NAME,
        mode: if shared_domain { "shared-dom" } else { "dom/shard" },
        shards,
        groups: server.group_count(),
        ops_per_sec: cfg.mean_ops_per_sec(),
        hit_rate: agg.hit_rate(),
        trace_p50_ns: lat.p50_ns,
        trace_p99_ns: lat.p99_ns,
        trace_p999_ns: lat.p999_ns,
        trace_pairs: lat.pairs,
        batches: agg.batches,
        unreclaimed: agg.unreclaimed_nodes,
        shard_requests: per_shard.iter().map(|m| m.requests).collect(),
        shard_unreclaimed: per_shard.iter().map(|m| m.unreclaimed_nodes).collect(),
        group_batches: server.group_metrics().iter().map(|g| g.batches).collect(),
    };
    server.shutdown();
    cell
}

/// E16: shard-scaling figure (ROADMAP "sharded coordinator"): Router
/// throughput and unreclaimed-node population vs shard count (1/2/4/8 by
/// default), **domain-per-shard vs one-shared-domain**, per scheme — and,
/// with `--groups`, vs engine-group count (the miss-compute parallelism
/// axis; group counts exceeding a shard count are skipped, since the
/// router would clamp them to a duplicate of the `groups = shards` cell).
/// Returns the cells so the `shard_scaling` bench target can write
/// `BENCH_fig_shard_scaling.json` and gate the groups speedup. See
/// EXPERIMENTS.md §E16 for the recipe and expected shapes.
pub fn fig_shard_scaling(p: &BenchParams) -> Vec<ShardCell> {
    let clients = *p.threads.iter().max().unwrap_or(&4);
    println!(
        "\n== shard scaling — Router on synthetic backend \
         ({clients} clients, 1 worker/shard, 80% hot-set traffic) =="
    );
    let sweep_groups = p.groups != vec![1];
    let mut csv = String::from(
        "scheme,mode,shards,groups,req_per_s,hit_pct,batches,unreclaimed,\
         trace_p50_ns,trace_p99_ns,trace_p999_ns,trace_pairs,\
         per_shard_requests,per_shard_unreclaimed,per_group_batches\n",
    );
    let mut all: Vec<ShardCell> = Vec::new();
    // Rows are (scheme, mode, groups); columns are shard counts. A `None`
    // marks a skipped groups > shards combo.
    let mut rows: Vec<(String, Vec<Option<usize>>)> = Vec::new();
    for &scheme in &p.schemes {
        for shared in [false, true] {
            let mode = if shared { "shared-dom" } else { "dom/shard" };
            for &g in &p.groups {
                let g = g.max(1);
                let label = if sweep_groups {
                    format!("{} {mode} g{g}", scheme.name())
                } else {
                    format!("{} {mode}", scheme.name())
                };
                let mut cells: Vec<Option<usize>> = Vec::new();
                for &s in &p.shards {
                    if g > s.max(1) {
                        println!(
                            "  {label:<22} shards={s}: skipped (groups {g} > shards, \
                             would clamp to a duplicate cell)"
                        );
                        cells.push(None);
                        continue;
                    }
                    let cell = dispatch_scheme!(scheme, shard_scaling_cell, p, s, g, shared);
                    println!(
                        "  {label:<22} shards={s}: {:>9.0} req/s  hit {:>5.1}%  \
                         trace p50={} p99={} p999={} ({} pairs)  \
                         unreclaimed {:>8}  per-shard req {:?}  unreclaimed {:?}  \
                         per-group batches {:?}",
                        cell.ops_per_sec,
                        cell.hit_rate * 100.0,
                        fmt_ns(cell.trace_p50_ns as f64),
                        fmt_ns(cell.trace_p99_ns as f64),
                        fmt_ns(cell.trace_p999_ns as f64),
                        cell.trace_pairs,
                        cell.unreclaimed,
                        cell.shard_requests,
                        cell.shard_unreclaimed,
                        cell.group_batches,
                    );
                    csv.push_str(&format!(
                        "{},{mode},{s},{g},{:.0},{:.2},{},{},{},{},{},{},{},{},{}\n",
                        scheme.name(),
                        cell.ops_per_sec,
                        cell.hit_rate * 100.0,
                        cell.batches,
                        cell.unreclaimed,
                        cell.trace_p50_ns,
                        cell.trace_p99_ns,
                        cell.trace_p999_ns,
                        cell.trace_pairs,
                        join_u64(&cell.shard_requests),
                        join_u64(&cell.shard_unreclaimed),
                        join_u64(&cell.group_batches),
                    ));
                    cells.push(Some(all.len()));
                    all.push(cell);
                }
                rows.push((label, cells));
            }
        }
    }
    // Summary tables: throughput and end-of-run unreclaimed vs shard count.
    for (what, pick) in [
        ("router throughput [req/s]", 0usize),
        ("end-of-run unreclaimed nodes", 1usize),
    ] {
        println!("\n== {what} (columns are shard counts) ==");
        print!("{:<22}", "scheme/mode");
        for s in &p.shards {
            print!("{:>12}", format!("shards={s}"));
        }
        println!();
        for (label, cells) in &rows {
            print!("{label:<22}");
            for c in cells {
                match c {
                    Some(i) if pick == 0 => print!("{:>12.0}", all[*i].ops_per_sec),
                    Some(i) => print!("{:>12}", all[*i].unreclaimed),
                    None => print!("{:>12}", "-"),
                }
            }
            println!();
        }
    }
    maybe_write_csv(&p.csv, &csv);
    if sweep_groups {
        println!(
            "(expected: req/s grows with groups at fixed shards — each group's \
             batcher dispatches its own engine in parallel — flattening once \
             groups reach the miss-compute parallelism the load can use)"
        );
    }
    all
}

/// Join counts with `;` (CSV cell of a per-shard breakdown).
fn join_u64(v: &[u64]) -> String {
    v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(";")
}

/// One async-scaling measurement cell (E17). Public so the `async_scaling`
/// bench target can flatten the sweep into `BENCH_fig_async_scaling.json`.
pub struct AsyncCell {
    /// [`Reclaimer::NAME`] of the scheme under test.
    pub scheme: &'static str,
    /// Front-end mode: `"mux"` or `"thread"`.
    pub mode: &'static str,
    /// Logical clients this cell drove.
    pub clients: usize,
    /// Engine groups the fleet ran (post-clamp; the `--groups` axis).
    pub groups: usize,
    /// OS threads actually driving clients (executor threads on the mux,
    /// client threads — possibly capped — on thread-per-request).
    pub threads_used: usize,
    pub req_per_s: f64,
    /// Client-observed latency percentiles (submit → reply, ns).
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Trace-derived request latency percentiles: submit→complete pairs
    /// harvested from the flight recorder (0 under `--trace off`).
    pub trace_p50_ns: u64,
    pub trace_p99_ns: u64,
    pub trace_p999_ns: u64,
    /// Submit/complete pairs behind those percentiles.
    pub trace_pairs: u64,
    pub errors: u64,
    /// End-of-run pending-retire population across the fleet's domains.
    pub unreclaimed: u64,
    /// Peak of the fleet-wide `queue_depth` gauge, sampled during the run.
    pub peak_queue_depth: u64,
    /// Peak of the fleet-wide `in_flight` gauge (open completion slots).
    pub peak_in_flight: u64,
}

/// E17 fixes the fleet shape (the sweep varies *client* concurrency):
/// 4 shards × 1 worker, so the front-end — not the shard pool — is what
/// scales.
const E17_SHARDS: usize = 4;
/// Requests each logical client issues, sequentially.
const E17_REQS_PER_CLIENT: usize = 10;
/// Thread-per-request cannot reach 100k OS threads; beyond this cap the
/// same *total* request count is spread over capped threads (and the cell
/// reports the cap — no silent truncation, see the figure output).
const E17_THREAD_CAP: usize = 256;
/// Per-shard in-flight budget the mux runs under (the back-pressure bound
/// `peak_in_flight` is plotted against).
const E17_IN_FLIGHT_BUDGET: usize = 256;

/// Run one (scheme, client count, front-end mode) cell of the E17 figure:
/// the full Router stack on the synthetic backend under the same skewed
/// load as E16 (80% of requests on a 1% hot set), driven either by
/// `clients` logical tasks multiplexed on `p.exec_threads` executor
/// threads (`asynchronous`) or by one OS thread per client (capped at
/// [`E17_THREAD_CAP`]).
fn async_scaling_cell<R: Reclaimer>(
    p: &BenchParams,
    clients: usize,
    asynchronous: bool,
    groups: usize,
) -> AsyncCell {
    use crate::coordinator::frontend::mux::{self, MuxConfig};
    use crate::coordinator::{Backend, Router, ServerConfig};
    use crate::runtime::exec::Executor;
    use crate::util::monotonic_ns;
    use crate::util::stats::LogHistogram;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    crate::trace::apply_knob(p.trace_cap);
    let server = Router::<R>::start(
        ServerConfig {
            workers: 1,
            buckets: (p.map_buckets / E17_SHARDS).max(64),
            capacity: (p.map_capacity / E17_SHARDS).max(64),
            ..ServerConfig::default()
        }
        .with_shards(E17_SHARDS)
        .with_groups(groups)
        .with_backend(Backend::synthetic()),
    )
    .expect("router start (synthetic backend)");

    // Gauge sampler: the back-pressure signal E17 plots. Polls the rolled-up
    // metrics while the load runs and keeps the peaks.
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let server = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let (mut peak_q, mut peak_if) = (0u64, 0u64);
            while !stop.load(Ordering::Acquire) {
                let m = server.metrics();
                peak_q = peak_q.max(m.queue_depth);
                peak_if = peak_if.max(m.in_flight);
                std::thread::sleep(std::time::Duration::from_micros(500));
            }
            (peak_q, peak_if)
        })
    };

    // Flight-recorder harvest: pairs shard.submit/shard.complete events
    // into trace-derived percentiles while the load runs (a no-op under
    // `--trace off`).
    let recorder = crate::trace::LatencyRecorder::spawn(std::time::Duration::from_millis(2));
    let (threads_used, issued, errors, lat, wall_ns) = if asynchronous {
        let exec = Executor::new(p.exec_threads);
        let report = mux::drive(
            &exec,
            server.clone(),
            &MuxConfig {
                clients,
                requests_per_client: E17_REQS_PER_CLIENT,
                key_space: p.key_space,
                hot_pct: 80,
                shard_in_flight: E17_IN_FLIGHT_BUDGET,
                seed: 0xE17,
            },
        );
        let lat = report.latency_hist();
        (exec.threads(), report.served() + report.errors, report.errors, lat, report.wall_ns)
    } else {
        // Thread-per-request: `clients` OS threads (capped), EXACTLY the
        // same total request count as the mux cell (the first
        // `total % threads` threads issue one extra), same skewed stream.
        let threads = clients.clamp(1, E17_THREAD_CAP);
        let total = clients * E17_REQS_PER_CLIENT;
        let t0 = monotonic_ns();
        let per_client: Vec<(LogHistogram, u64)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|c| {
                    let server = &server;
                    let quota = total / threads + usize::from(c < total % threads);
                    scope.spawn(move || {
                        let mut rng = Xoshiro256::new(0xE17 ^ crate::util::rng::mix64(c as u64));
                        let mut lat = LogHistogram::new();
                        let mut errors = 0u64;
                        for _ in 0..quota {
                            let key = rng.skewed_key(p.key_space, 80);
                            match server.request(key) {
                                Ok(resp) => lat.record(resp.latency_ns),
                                Err(_) => errors += 1,
                            }
                        }
                        (lat, errors)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let wall_ns = monotonic_ns() - t0;
        let errors: u64 = per_client.iter().map(|(_, e)| e).sum();
        let mut lat = LogHistogram::new();
        for (h, _) in &per_client {
            lat.merge(h);
        }
        (threads, total as u64, errors, lat, wall_ns)
    };
    let tlat = recorder.stop();

    stop.store(true, Ordering::Release);
    let (peak_queue_depth, peak_in_flight) = sampler.join().unwrap();
    let unreclaimed = server.metrics().unreclaimed_nodes;
    let groups_ran = server.group_count();
    server.shutdown();

    AsyncCell {
        scheme: R::NAME,
        mode: if asynchronous { "mux" } else { "thread" },
        clients,
        groups: groups_ran,
        threads_used,
        req_per_s: (issued - errors) as f64 / (wall_ns as f64 / 1e9),
        p50_ns: lat.percentile(50.0) as f64,
        p99_ns: lat.percentile(99.0) as f64,
        trace_p50_ns: tlat.p50_ns,
        trace_p99_ns: tlat.p99_ns,
        trace_p999_ns: tlat.p999_ns,
        trace_pairs: tlat.pairs,
        errors,
        unreclaimed,
        peak_queue_depth,
        peak_in_flight,
    }
}

/// E17: async-scaling figure (ROADMAP "async front-end"): throughput,
/// latency and reclamation gauges of **thread-per-request vs the async
/// multiplexed front-end** as logical-client concurrency grows
/// (1k/10k/100k), per scheme, on the synthetic backend — artifact-free.
/// Returns the cells so the `async_scaling` bench target can write
/// `BENCH_fig_async_scaling.json`. See EXPERIMENTS.md §E17 for the recipe
/// and expected shapes.
pub fn fig_async_scaling(p: &BenchParams) -> Vec<AsyncCell> {
    println!(
        "\n== async scaling — {} shard(s) × 1 worker, synthetic backend, \
         {} req/client, 80% hot-set traffic ==\n\
         modes: mux = async front-end on {} executor threads \
         (per-shard budget {}); thread = one OS thread per client \
         (capped at {})",
        E17_SHARDS, E17_REQS_PER_CLIENT, p.exec_threads, E17_IN_FLIGHT_BUDGET, E17_THREAD_CAP
    );
    let mut csv = String::from(
        "scheme,mode,clients,groups,os_threads,req_per_s,p50_ns,p99_ns,\
         trace_p50_ns,trace_p99_ns,trace_p999_ns,trace_pairs,errors,\
         unreclaimed,peak_queue_depth,peak_in_flight\n",
    );
    let mut cells = Vec::new();
    for &scheme in &p.schemes {
        for &g in &p.groups {
            let g = g.max(1);
            if g > E17_SHARDS {
                println!(
                    "  {:<10} groups={g}: skipped (fixed {E17_SHARDS}-shard fleet \
                     would clamp it to a duplicate cell)",
                    scheme.name()
                );
                continue;
            }
            for &clients in &p.mux_clients {
                for asynchronous in [false, true] {
                    let mode = if asynchronous { "mux" } else { "thread" };
                    let cell =
                        dispatch_scheme!(scheme, async_scaling_cell, p, clients, asynchronous, g);
                    println!(
                        "  {:<10} {mode:<7} clients={clients:<7} groups={g} threads={:<4} \
                         {:>9.0} req/s  p50={:<9} p99={:<9} trace p50={:<9} p99={:<9} \
                         p999={:<9} errors={:<3} \
                         unreclaimed={:<7} peak_q={:<6} peak_inflight={}",
                        scheme.name(),
                        cell.threads_used,
                        cell.req_per_s,
                        fmt_ns(cell.p50_ns),
                        fmt_ns(cell.p99_ns),
                        fmt_ns(cell.trace_p50_ns as f64),
                        fmt_ns(cell.trace_p99_ns as f64),
                        fmt_ns(cell.trace_p999_ns as f64),
                        cell.errors,
                        cell.unreclaimed,
                        cell.peak_queue_depth,
                        cell.peak_in_flight,
                    );
                    csv.push_str(&format!(
                        "{},{mode},{clients},{g},{},{:.0},{:.0},{:.0},{},{},{},{},{},{},{},{}\n",
                        scheme.name(),
                        cell.threads_used,
                        cell.req_per_s,
                        cell.p50_ns,
                        cell.p99_ns,
                        cell.trace_p50_ns,
                        cell.trace_p99_ns,
                        cell.trace_p999_ns,
                        cell.trace_pairs,
                        cell.errors,
                        cell.unreclaimed,
                        cell.peak_queue_depth,
                        cell.peak_in_flight,
                    ));
                    cells.push(cell);
                }
            }
        }
    }
    maybe_write_csv(&p.csv, &csv);
    println!(
        "(expected: mux throughput holds as clients grow — parked tasks are heap \
         allocations, not OS threads — while thread-per-request saturates at the \
         thread cap; peak_in_flight stays within shards × budget on the mux)"
    );
    cells
}

/// One net-scaling measurement cell (E18). Public so the `net_scaling`
/// bench target can flatten the sweep into `BENCH_fig_net_scaling.json`.
pub struct NetCell {
    /// [`Reclaimer::NAME`] of the scheme under test.
    pub scheme: &'static str,
    pub conns: usize,
    /// Engine groups the fleet ran (post-clamp; the `--groups` axis).
    pub groups: usize,
    pub req_per_s: f64,
    /// Client-observed round-trip latency percentiles (socket to socket).
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Trace-derived *server-side* request latency percentiles:
    /// submit→complete pairs harvested from the flight recorder (0 under
    /// `--trace off`). The gap to `p50_ns`/`p99_ns` is the wire + reactor
    /// + bridge overhead.
    pub trace_p50_ns: u64,
    pub trace_p99_ns: u64,
    pub trace_p999_ns: u64,
    /// Submit/complete pairs behind those percentiles.
    pub trace_pairs: u64,
    /// Client-observed failures: connect errors, premature closes,
    /// non-`Ok` statuses, unanswered requests at the progress deadline.
    pub errors: u64,
    /// Server-counted malformed/oversized frames (acceptance: 0).
    pub protocol_errors: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// End-of-run pending-retire population across the fleet's domains.
    pub unreclaimed: u64,
    /// Peak of the `active_connections` listener gauge during the run.
    pub peak_active: u64,
    /// Peak of the fleet-wide `in_flight` gauge (open completion slots).
    pub peak_in_flight: u64,
}

/// E18 fixes the fleet shape like E17 (4 shards × 1 worker): the sweep
/// varies *connection* concurrency, so the reactor + completion bridge —
/// not the shard pool — is what scales.
const E18_SHARDS: usize = 4;
/// Requests each connection issues (pipelined up to the storm window).
const E18_REQS_PER_CONN: usize = 10;

/// Run one (scheme, connection count) cell of the E18 figure: the full
/// Router stack on the synthetic backend behind the TCP front
/// (`frontend::net`), stormed over loopback by `conns` real connections
/// pipelining [`E18_REQS_PER_CONN`] requests each under the same skewed
/// load as E16/E17 (80% of requests on a 1% hot set).
fn net_scaling_cell<R: Reclaimer>(p: &BenchParams, conns: usize, groups: usize) -> NetCell {
    use crate::coordinator::frontend::net::client::{storm, StormConfig};
    use crate::coordinator::frontend::net::{NetConfig, NetServer};
    use crate::coordinator::{Backend, Router, ServerConfig};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    crate::trace::apply_knob(p.trace_cap);
    let server = Router::<R>::start(
        ServerConfig {
            workers: 1,
            buckets: (p.map_buckets / E18_SHARDS).max(64),
            capacity: (p.map_capacity / E18_SHARDS).max(64),
            ..ServerConfig::default()
        }
        .with_shards(E18_SHARDS)
        .with_groups(groups)
        .with_backend(Backend::synthetic()),
    )
    .expect("router start (synthetic backend)");
    let mut net = NetServer::start(
        server.clone(),
        NetConfig { exec_threads: p.exec_threads, ..NetConfig::default() },
    )
    .expect("net front start (loopback)");

    // Gauge sampler: connection population and open completion slots are
    // the two back-pressure signals E18 plots against throughput.
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let server = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let (mut peak_active, mut peak_if) = (0u64, 0u64);
            while !stop.load(Ordering::Acquire) {
                let m = server.metrics();
                peak_active = peak_active.max(m.net_active);
                peak_if = peak_if.max(m.in_flight);
                std::thread::sleep(std::time::Duration::from_micros(500));
            }
            (peak_active, peak_if)
        })
    };

    // Flight-recorder harvest: pairs shard.submit/shard.complete events
    // into trace-derived (server-side) percentiles while the storm runs.
    let recorder = crate::trace::LatencyRecorder::spawn(std::time::Duration::from_millis(2));
    let report = storm(
        net.local_addr(),
        &StormConfig {
            conns,
            requests_per_conn: E18_REQS_PER_CONN,
            key_space: p.key_space,
            hot_pct: 80,
            seed: 0xE18,
            ..StormConfig::default()
        },
    );
    let tlat = recorder.stop();

    stop.store(true, Ordering::Release);
    let (peak_active, peak_in_flight) = sampler.join().unwrap();
    let listener = net.metrics();
    // Drain in-flight completions and join the reactor before reading the
    // end-of-run reclamation gauge, so bridge tasks are finished.
    net.shutdown();
    let unreclaimed = server.metrics().unreclaimed_nodes;
    server.shutdown();

    let lat = report.latency_hist();
    NetCell {
        scheme: R::NAME,
        conns,
        groups: server.group_count(),
        req_per_s: report.reqs_per_sec(),
        p50_ns: lat.percentile(50.0) as f64,
        p99_ns: lat.percentile(99.0) as f64,
        trace_p50_ns: tlat.p50_ns,
        trace_p99_ns: tlat.p99_ns,
        trace_p999_ns: tlat.p999_ns,
        trace_pairs: tlat.pairs,
        errors: report.errors,
        protocol_errors: listener.protocol_errors,
        bytes_in: listener.bytes_in,
        bytes_out: listener.bytes_out,
        unreclaimed,
        peak_active,
        peak_in_flight,
    }
}

/// E18: net-scaling figure (ROADMAP "network front"): throughput, latency,
/// protocol health and reclamation gauges of the TCP front as **real
/// loopback connection** concurrency grows, per scheme, on the synthetic
/// backend — artifact-free. Returns the cells so the `net_scaling` bench
/// target can write `BENCH_fig_net_scaling.json`. See EXPERIMENTS.md §E18
/// for the recipe and expected shapes.
pub fn fig_net_scaling(p: &BenchParams) -> Vec<NetCell> {
    println!(
        "\n== net scaling — {} shard(s) × 1 worker, synthetic backend, \
         {} req/conn pipelined, 80% hot-set traffic ==\n\
         front: TCP reactor over loopback, completions bridged on {} \
         executor threads",
        E18_SHARDS, E18_REQS_PER_CONN, p.exec_threads
    );
    let mut csv = String::from(
        "scheme,conns,groups,req_per_s,p50_ns,p99_ns,\
         trace_p50_ns,trace_p99_ns,trace_p999_ns,trace_pairs,\
         errors,protocol_errors,\
         bytes_in,bytes_out,unreclaimed,peak_active,peak_in_flight\n",
    );
    let mut cells = Vec::new();
    for &scheme in &p.schemes {
        for &g in &p.groups {
            let g = g.max(1);
            if g > E18_SHARDS {
                println!(
                    "  {:<10} groups={g}: skipped (fixed {E18_SHARDS}-shard fleet \
                     would clamp it to a duplicate cell)",
                    scheme.name()
                );
                continue;
            }
            for &conns in &p.net_conns {
                let cell = dispatch_scheme!(scheme, net_scaling_cell, p, conns, g);
                println!(
                    "  {:<10} conns={conns:<7} groups={g} {:>9.0} req/s  p50={:<9} p99={:<9} \
                     trace p50={:<9} p99={:<9} p999={:<9} \
                     errors={:<3} proto_errs={:<3} unreclaimed={:<7} peak_active={:<7} \
                     peak_inflight={}",
                    scheme.name(),
                    cell.req_per_s,
                    fmt_ns(cell.p50_ns),
                    fmt_ns(cell.p99_ns),
                    fmt_ns(cell.trace_p50_ns as f64),
                    fmt_ns(cell.trace_p99_ns as f64),
                    fmt_ns(cell.trace_p999_ns as f64),
                    cell.errors,
                    cell.protocol_errors,
                    cell.unreclaimed,
                    cell.peak_active,
                    cell.peak_in_flight,
                );
                csv.push_str(&format!(
                    "{},{conns},{g},{:.0},{:.0},{:.0},{},{},{},{},{},{},{},{},{},{},{}\n",
                    scheme.name(),
                    cell.req_per_s,
                    cell.p50_ns,
                    cell.p99_ns,
                    cell.trace_p50_ns,
                    cell.trace_p99_ns,
                    cell.trace_p999_ns,
                    cell.trace_pairs,
                    cell.errors,
                    cell.protocol_errors,
                    cell.bytes_in,
                    cell.bytes_out,
                    cell.unreclaimed,
                    cell.peak_active,
                    cell.peak_in_flight,
                ));
                cells.push(cell);
            }
        }
    }
    maybe_write_csv(&p.csv, &csv);
    println!(
        "(expected: req/s roughly flat as connections grow — the reactor \
         multiplexes sockets the way the mux multiplexes tasks — with p99 \
         rising once outboxes start pausing reads; unreclaimed stays bounded \
         for stamp/hp and grows with connection count for the epoch schemes \
         when a stalled connection pins an epoch)"
    );
    cells
}

/// ns/op of `f` over ~`secs` of wall time (batched to amortize the clock).
fn time_ns_per_op(secs: f64, mut f: impl FnMut()) -> f64 {
    use crate::util::monotonic_ns;
    let t0 = monotonic_ns();
    let deadline = t0 + (secs * 1e9) as u64;
    let mut ops = 0u64;
    while monotonic_ns() < deadline {
        for _ in 0..64 {
            f();
        }
        ops += 64;
    }
    (monotonic_ns() - t0) as f64 / ops as f64
}

/// Machine-speed calibration for the E13 gate: ns per dependent
/// [`mix64`](crate::util::rng::mix64) step. Region-cycle costs are stored
/// as multiples of this, so a recorded baseline transfers across machines
/// of different absolute speed (EXPERIMENTS.md §E13).
fn calibration_ns() -> f64 {
    use crate::util::monotonic_ns;
    use crate::util::rng::mix64;
    const N: u64 = 4_000_000;
    let t0 = monotonic_ns();
    let mut x = 0x9E37_79B9_7F4A_7C15u64;
    for _ in 0..N {
        x = mix64(x);
    }
    std::hint::black_box(x);
    (monotonic_ns() - t0) as f64 / N as f64
}

/// Single-threaded region enter+exit cycle cost — the Propositions 2/3
/// quantity the E13 gate tracks.
fn region_cycle_ns<R: Reclaimer>(secs: f64) -> f64 {
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    time_ns_per_op(secs, || {
        let region = crate::reclaim::Region::enter(&h);
        std::hint::black_box(&region);
    })
}

/// (raw `GuardPtr` cycle, facade `Guard` cycle): protect+reset against one
/// hot cell. The lifetime-branded facade must not add measurable cost
/// over the raw layer it wraps.
fn guard_cycle_pair_ns<R: Reclaimer>(secs: f64) -> (f64, f64) {
    use crate::reclaim::{Atomic, MarkedPtr, Owned};
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let cell: Atomic<u64, R> = Atomic::new(Owned::new(7));
    let raw = {
        let mut g = crate::reclaim::GuardPtr::<u64, R>::new_in(&h);
        time_ns_per_op(secs, || {
            g.acquire(cell.raw());
            g.reset();
        })
    };
    let facade = {
        let mut g: crate::reclaim::Guard<'_, u64, R> = h.guard();
        time_ns_per_op(secs, || {
            g.protect(&cell);
            g.reset();
        })
    };
    // Unlink + retire the hot node so the owned domain drains clean.
    let last = cell.load(std::sync::atomic::Ordering::Acquire);
    cell.store(MarkedPtr::null(), std::sync::atomic::Ordering::Release);
    // SAFETY: unlinked above; sole retirer, in-domain.
    unsafe { h.retire(last.get()) };
    (raw, facade)
}

/// Regression threshold for the E13 gate: fail on >20% regression.
const GATE_RATIO: f64 = 1.2;

/// Compare measured `(scheme, cycle/calib)` pairs against the contents of
/// a recorded baseline file; returns false on any regression beyond
/// [`GATE_RATIO`]. Pure (no timing, no IO) so the gate logic is
/// deterministically unit-testable.
fn check_baseline(measured: &[(String, f64)], content: &str) -> bool {
    let recorded: std::collections::BTreeMap<String, f64> = content
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .filter_map(|l| {
            let (name, v) = l.split_once(',')?;
            Some((name.trim().to_string(), v.trim().parse().ok()?))
        })
        .collect();
    let mut ok = true;
    for (name, ratio) in measured {
        match recorded.get(name) {
            Some(base) => {
                if *ratio > base * GATE_RATIO {
                    eprintln!(
                        "GATE FAIL: {name} cycle cost {ratio:.2}x calib exceeds \
                         baseline {base:.2} by more than {:.0}%",
                        (GATE_RATIO - 1.0) * 100.0
                    );
                    ok = false;
                }
            }
            None => println!("(no baseline entry for {name}; skipping)"),
        }
    }
    ok
}

/// E13 CI regression gate. Verifies, in order:
///
/// 1. **facade overhead** — the reusable [`crate::reclaim::Guard`] adds no
///    measurable cost over the raw `GuardPtr` cycle it wraps (relative,
///    machine-independent; always enforced);
/// 2. **region-cycle regression** — per-scheme region enter/exit cost,
///    normalized by [`calibration_ns`], has not regressed >20% against the
///    recorded baseline (`rust/ci/micro_region_baseline.csv`).
///
/// With `record`, (re)writes the baseline file instead of gating against
/// it. Returns false when any gate fails.
pub fn micro_region_gate(p: &BenchParams, baseline: Option<&str>, record: Option<&str>) -> bool {
    let secs = p.secs.clamp(0.02, 0.5);
    let calib = calibration_ns();
    println!("== micro_region gate (calibration: {calib:.3} ns/mix64) ==");

    let mut ok = true;
    println!("{:<10}{:>12}{:>14}{:>9}", "scheme", "raw ns/op", "facade ns/op", "delta");
    for &scheme in &p.schemes {
        let (raw, facade) = dispatch_scheme!(scheme, guard_cycle_pair_ns, secs);
        let delta = (facade - raw) / raw.max(0.01) * 100.0;
        println!("{:<10}{:>12}{:>14}{:>8.1}%", scheme.name(), fmt_ns(raw), fmt_ns(facade), delta);
        // Tolerance: 30% + 10 ns absolute slack — wide enough that debug
        // builds and near-zero-cost schemes aren't noise-flaky, tight
        // enough to catch a real wrapper regression (e.g. a reintroduced
        // per-op TLS lookup costs far more than 10 ns).
        if facade > raw * 1.3 + 10.0 {
            eprintln!(
                "GATE FAIL: facade Guard adds cost over raw GuardPtr for {} \
                 ({facade:.1} ns vs {raw:.1} ns)",
                scheme.name()
            );
            ok = false;
        }
    }

    let mut measured: Vec<(String, f64)> = Vec::new();
    for &scheme in &p.schemes {
        let ns = dispatch_scheme!(scheme, region_cycle_ns, secs);
        measured.push((scheme.name().to_string(), ns / calib));
    }
    println!("{:<10}{:>16}", "scheme", "cycle/calib");
    for (name, ratio) in &measured {
        println!("{name:<10}{ratio:>16.2}");
    }

    if let Some(path) = record {
        let mut out = String::from(
            "# micro_region baseline: region enter+exit cycle cost per scheme, in\n\
             # units of the calibration loop (ns per dependent mix64 step) so the\n\
             # file transfers across hosts of different absolute speed.\n\
             # Re-record: cargo bench --bench micro_region -- --record <this file>\n",
        );
        for (name, ratio) in &measured {
            out.push_str(&format!("{name},{ratio:.2}\n"));
        }
        if let Err(e) = std::fs::write(path, out) {
            eprintln!("cannot write baseline {path}: {e}");
            return false;
        }
        println!("baseline recorded to {path}");
        return ok;
    }

    if let Some(path) = baseline {
        match std::fs::read_to_string(path) {
            Ok(content) => {
                ok &= check_baseline(&measured, &content);
            }
            Err(e) => {
                eprintln!("cannot read baseline {path}: {e} — failing the gate");
                ok = false;
            }
        }
    }
    ok
}

/// ns per region cycle with one flight-recorder event per
/// `region_ops`-cycle burst — the event density the serving seams emit at
/// (roughly one submit/complete pair per request, each request spanning
/// many region cycles inside the cache).
fn traced_region_burst_ns<R: Reclaimer>(secs: f64, region_ops: usize) -> f64 {
    let domain = DomainRef::<R>::new_owned();
    let h = domain.register();
    let burst = region_ops.max(1);
    let per_burst = time_ns_per_op(secs, || {
        for _ in 0..burst {
            let region = crate::reclaim::Region::enter(&h);
            std::hint::black_box(&region);
        }
        crate::trace::event!("bench.region_burst");
    });
    per_burst / burst as f64
}

/// Allowed trace-on / trace-off ratio on the region-cycle hot path
/// (ISSUE 9 acceptance: the always-on recorder costs ≤5%).
const TRACE_GATE_RATIO: f64 = 1.05;

/// CI gate for the flight recorder's hot-path cost: region-cycle bursts
/// with one `trace::event!` per burst, measured trace-off then trace-on,
/// per scheme. Fails when trace-on exceeds [`TRACE_GATE_RATIO`]× trace-off
/// (plus 0.5 ns absolute slack so near-zero-cost cycles aren't
/// noise-flaky). Leaves tracing enabled — the recorder is always-on by
/// default and the gate must not change that.
pub fn trace_overhead_gate(p: &BenchParams) -> bool {
    let secs = p.secs.clamp(0.02, 0.5);
    let mut ok = true;
    println!(
        "== trace overhead gate (1 event per {} region cycles; \
         on ≤ {TRACE_GATE_RATIO}× off) ==",
        p.region_ops.max(1)
    );
    println!("{:<10}{:>14}{:>14}{:>9}", "scheme", "off ns/cyc", "on ns/cyc", "ratio");
    for &scheme in &p.schemes {
        crate::trace::set_enabled(false);
        let off = dispatch_scheme!(scheme, traced_region_burst_ns, secs, p.region_ops);
        crate::trace::set_enabled(true);
        let on = dispatch_scheme!(scheme, traced_region_burst_ns, secs, p.region_ops);
        let ratio = on / off.max(1e-9);
        println!("{:<10}{off:>14.2}{on:>14.2}{ratio:>9.3}", scheme.name());
        if on > off * TRACE_GATE_RATIO + 0.5 {
            eprintln!(
                "GATE FAIL: tracing adds >{:.0}% to the region cycle for {} \
                 ({on:.2} ns vs {off:.2} ns)",
                (TRACE_GATE_RATIO - 1.0) * 100.0,
                scheme.name()
            );
            ok = false;
        }
    }
    crate::trace::set_enabled(true);
    ok
}

// ---------------------------------------------------------------------------
// E19: stall robustness — the async adversary
// ---------------------------------------------------------------------------

/// One E19 stall-robustness measurement cell. Public so the
/// `stall_robustness` bench target can flatten the sweep into
/// `BENCH_fig_stall_robustness.json`.
pub struct StallCell {
    /// [`Reclaimer::NAME`] of the scheme under test.
    pub scheme: &'static str,
    /// `baseline` (no adversary) or `stalled` (leaked-guard task injected).
    pub mode: &'static str,
    pub churn_threads: usize,
    /// Nodes retired by the churn threads during the cell.
    pub retired: u64,
    /// Peak of `Domain::unreclaimed()` sampled during the run — the
    /// robustness metric: bounded for Hyaline/HP, ~`retired` for epochs.
    pub peak_unreclaimed: u64,
    /// `Domain::unreclaimed()` after churn ended and flushing went quiet,
    /// with the stall still live — what the scheme permanently strands.
    pub end_unreclaimed: u64,
    /// Downsampled `unreclaimed` time series (the E19 growth curves).
    pub samples: Vec<u64>,
    /// Guard-across-await lint violations recorded during the cell
    /// (expected ≥ 1 in `stalled` mode — the lint's positive test).
    pub lint_violations: u64,
}

/// Nodes each churn thread retires at most, bounding the memory an
/// epoch scheme strands during the cell (the growth is linear until this
/// cap — the curve shape is visible long before it).
const E19_MAX_RETIRES_PER_THREAD: u64 = 200_000;
/// Churn OS threads retiring into the measured domain.
const E19_CHURN_THREADS: usize = 4;
/// `unreclaimed` gauge sample cadence.
const E19_SAMPLE_US: u64 = 1_000;
/// Series points carried into the CSV/JSON rows.
const E19_SERIES_POINTS: usize = 48;

/// Run one (scheme, mode) cell of the E19 figure: churn threads retire
/// into an owned domain while (in `stalled` mode) an executor task —
/// polled once, never woken again — has registered with that domain,
/// protected a node and leaked its guard. That is the async failure mode
/// ROADMAP item 3 describes: the parked task's protection outlives every
/// await point, so epoch-based schemes stop reclaiming domain-wide, while
/// HP pins a bounded set and Hyaline strands only the batches the stalled
/// reader could actually hold (its birth-era gate skips everything born
/// after the leaked announce).
fn stall_cell<R: Reclaimer>(p: &BenchParams, stalled: bool) -> StallCell {
    use crate::reclaim::facade::lint;
    use crate::reclaim::{Atomic, Owned};
    use crate::runtime::exec::Executor;
    use crate::util::monotonic_ns;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    crate::trace::apply_knob(p.trace_cap);
    let domain = DomainRef::<R>::new_owned();
    let violations_before = lint::violations();

    // The adversary. The guard is leaked from inside a poll (guards are
    // `!Send`, so one cannot literally live in a `Send` future across an
    // await — leaking protection onto the executor thread is how the
    // failure reaches production). The leaked registration deliberately
    // outlives the executor: the stall is permanent, as a never-woken
    // future's would be. This is also the lint's positive test — the task
    // returns `Pending` with one more live guard than it was polled with.
    let exec = if stalled { Some(Executor::new(1)) } else { None };
    let _adversary = exec.as_ref().map(|exec| {
        let armed = Arc::new(AtomicBool::new(false));
        let join = {
            let domain = domain.clone();
            let armed = armed.clone();
            let mut first = true;
            exec.spawn(std::future::poll_fn(move |_cx| {
                if first {
                    first = false;
                    let cell = Box::leak(Box::new(Atomic::<u64, R>::new(Owned::new(0xE19))));
                    let h = Box::leak(Box::new(domain.register()));
                    let mut g = h.guard();
                    let _ = g.protect(cell);
                    armed.store(true, Ordering::Release);
                    std::mem::forget(g);
                }
                std::task::Poll::<()>::Pending
            }))
        };
        // Churn must start only after the stall is in place (in debug
        // builds the lint's assertion downs the task right after arming;
        // the leaked protection persists either way).
        while !armed.load(Ordering::Acquire) {
            std::thread::yield_now();
        }
        join
    });

    // Gauge sampler: the growth curve E19 plots.
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let domain = domain.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let (mut peak, mut series) = (0u64, Vec::new());
            while !stop.load(Ordering::Acquire) {
                let u = domain.domain().unreclaimed();
                peak = peak.max(u);
                series.push(u);
                std::thread::sleep(std::time::Duration::from_micros(E19_SAMPLE_US));
            }
            (peak, series)
        })
    };

    let deadline = monotonic_ns() + (p.secs.max(0.05) * 1e9) as u64;
    let retired: u64 = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..E19_CHURN_THREADS)
            .map(|t| {
                let domain = &domain;
                scope.spawn(move || {
                    let h = domain.register();
                    let mut n = 0u64;
                    while monotonic_ns() < deadline && n < E19_MAX_RETIRES_PER_THREAD {
                        for _ in 0..64 {
                            h.retire_owned(Owned::<u64, R>::new(((t as u64) << 32) | n));
                            n += 1;
                        }
                        h.flush();
                    }
                    n
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });

    // Post-churn: flush until the backlog stops shrinking. With the stall
    // still live this is what the scheme can permanently reclaim — near
    // zero for robust schemes, near the peak for epoch-based ones.
    let h = domain.register();
    let mut last = domain.domain().unreclaimed();
    let mut quiet = 0;
    while quiet < 10 {
        h.flush();
        std::thread::sleep(std::time::Duration::from_micros(500));
        let now = domain.domain().unreclaimed();
        if now >= last {
            quiet += 1;
        } else {
            quiet = 0;
        }
        last = now;
    }
    drop(h);

    stop.store(true, Ordering::Release);
    let (mut peak, series) = sampler.join().unwrap();
    let end_unreclaimed = domain.domain().unreclaimed();
    peak = peak.max(end_unreclaimed);

    let samples = if series.len() <= E19_SERIES_POINTS {
        series
    } else {
        let stride = series.len().div_ceil(E19_SERIES_POINTS);
        series.iter().step_by(stride).copied().collect()
    };

    StallCell {
        scheme: R::NAME,
        mode: if stalled { "stalled" } else { "baseline" },
        churn_threads: E19_CHURN_THREADS,
        retired,
        peak_unreclaimed: peak,
        end_unreclaimed,
        samples,
        lint_violations: lint::violations() - violations_before,
    }
}

/// E19: stall-robustness figure (ROADMAP item 3): `Domain::unreclaimed()`
/// growth per scheme while an injected task holds a guard across a
/// never-woken future. Expected shapes: epoch schemes (ER/NER/QSR/DEBRA)
/// grow to ~everything retired; Stamp-it pins everything younger than the
/// stalled stamp; HP pins a bounded hazard set; Hyaline strands only
/// batches born before the stalled announce. Returns the cells so the
/// `stall_robustness` bench target can write
/// `BENCH_fig_stall_robustness.json`. See EXPERIMENTS.md §E19.
pub fn fig_stall_robustness(p: &BenchParams) -> Vec<StallCell> {
    println!(
        "\n== stall robustness (E19) — {} churn thread(s) retiring into an owned \
         domain, ≤{} retires each, ~{:.2}s; stalled mode leaks a guard from a \
         never-woken executor task ==",
        E19_CHURN_THREADS,
        E19_MAX_RETIRES_PER_THREAD,
        p.secs.max(0.05)
    );
    let mut csv = String::from(
        "scheme,mode,churn_threads,retired,peak_unreclaimed,end_unreclaimed,\
         lint_violations,series\n",
    );
    let mut cells = Vec::new();
    for &scheme in &p.schemes {
        for stalled in [false, true] {
            let cell = dispatch_scheme!(scheme, stall_cell, p, stalled);
            println!(
                "  {:<10} {:<9} retired={:<8} peak_unreclaimed={:<8} \
                 end_unreclaimed={:<8} lint_violations={}",
                scheme.name(),
                cell.mode,
                cell.retired,
                cell.peak_unreclaimed,
                cell.end_unreclaimed,
                cell.lint_violations,
            );
            let series =
                cell.samples.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(" ");
            csv.push_str(&format!(
                "{},{},{},{},{},{},{},{series}\n",
                cell.scheme,
                cell.mode,
                cell.churn_threads,
                cell.retired,
                cell.peak_unreclaimed,
                cell.end_unreclaimed,
                cell.lint_violations,
            ));
            cells.push(cell);
        }
    }
    maybe_write_csv(&p.csv, &csv);
    println!(
        "(expected: baseline peaks stay small for every scheme; under the stall, \
         epoch schemes' end_unreclaimed ≈ retired while Hyaline and HP stay \
         bounded; lint_violations ≥ 1 in every stalled cell)"
    );
    cells
}

/// E19 CI gate: with an injected stalled guard live, Hyaline must stay
/// bounded — peak `unreclaimed` under `bound` — and the guard-across-await
/// lint must have fired (its positive test). Returns false on violation.
pub fn stall_gate(cells: &[StallCell], bound: u64) -> bool {
    let mut ok = true;
    let mut seen = false;
    for c in cells.iter().filter(|c| c.scheme == "Hyaline" && c.mode == "stalled") {
        seen = true;
        if c.peak_unreclaimed > bound {
            eprintln!(
                "GATE FAIL: Hyaline peak unreclaimed {} exceeds bound {bound} \
                 under a stalled guard",
                c.peak_unreclaimed
            );
            ok = false;
        }
        if c.lint_violations == 0 {
            eprintln!("GATE FAIL: guard-across-await lint did not fire in the E19 adversary");
            ok = false;
        }
    }
    if !seen {
        eprintln!("GATE FAIL: no Hyaline stalled cell in the E19 sweep");
        ok = false;
    }
    ok
}

/// A1: Stamp-it global-retire threshold ablation (paper picks 20). Each
/// threshold runs in its own domain with the knob set per-domain.
pub fn abl_threshold(p: &BenchParams) {
    use crate::reclaim::stamp::StampIt;
    let thresholds = [0usize, 1, 5, 20, 100, 100_000];
    let threads = *p.threads.iter().max().unwrap_or(&2);
    println!("\n== Stamp-it threshold ablation (HashMap workload, p={threads}) ==");
    println!("{:<12}{:>14}{:>18}", "threshold", "ns/op", "end unreclaimed");
    for &t in &thresholds {
        let domain = DomainRef::<StampIt>::new_owned();
        domain.domain().state().set_threshold(t);
        let baseline = crate::alloc::unreclaimed();
        let cache = make_cache_in::<StampIt>(domain.clone(), p);
        let mut cfg = ConfigResult::default();
        for trial in 0..p.trials {
            cfg.push(&run_trial(threads, p.duration(), |tid, stop| {
                hashmap_worker(&cache, p, tid, trial, stop)
            }));
        }
        let unreclaimed = crate::alloc::unreclaimed().saturating_sub(baseline);
        println!("{t:<12}{:>14}{:>18}", fmt_ns(cfg.mean_ns_per_op()), unreclaimed);
        // cache + domain drop here; the drain settles the counters before
        // the next threshold's baseline.
    }
}

/// A2: HPR scan-threshold-base ablation (paper: 100 + 2ΣK).
pub fn abl_hp_threshold(p: &BenchParams) {
    use crate::reclaim::hp::Hp;
    let bases = [0usize, 10, 100, 1000];
    let threads = *p.threads.iter().max().unwrap_or(&2);
    println!("\n== HPR threshold-base ablation (Queue workload, p={threads}) ==");
    println!("{:<12}{:>14}{:>18}", "base", "ns/op", "end unreclaimed");
    for &base in &bases {
        let domain = DomainRef::<Hp>::new_owned();
        domain.domain().state().set_threshold_base(base);
        let baseline = crate::alloc::unreclaimed();
        let q = prefill_queue_in::<Hp>(domain.clone(), p);
        let mut cfg = ConfigResult::default();
        for trial in 0..p.trials {
            cfg.push(&run_trial(threads, p.duration(), |tid, stop| {
                queue_worker(&q, p, tid, trial, stop)
            }));
        }
        let unreclaimed = crate::alloc::unreclaimed().saturating_sub(baseline);
        println!("{base:<12}{:>14}{:>18}", fmt_ns(cfg.mean_ns_per_op()), unreclaimed);
    }
}

/// A3: epoch-advance / DEBRA-check period ablation (paper: 100 / 20). The
/// period knob is per-domain, so each (scheme, period) cell is isolated.
pub fn abl_epoch_period(p: &BenchParams) {
    use crate::reclaim::debra::Debra;
    use crate::reclaim::ebr::Ebr;
    use crate::reclaim::epoch_core::EpochDomain;

    fn one<R: Reclaimer<DomainState = EpochDomain>>(
        p: &BenchParams,
        threads: usize,
        period: u32,
    ) -> (f64, u64) {
        let domain = DomainRef::<R>::new_owned();
        domain.domain().state().set_period(period);
        let baseline = crate::alloc::unreclaimed();
        let list = prefill_list_in::<R>(domain.clone(), p);
        let mut cfg = ConfigResult::default();
        for trial in 0..p.trials {
            cfg.push(&run_trial(threads, p.duration(), |tid, stop| {
                list_worker(&list, p, tid, trial, stop)
            }));
        }
        let end = crate::alloc::unreclaimed().saturating_sub(baseline);
        (cfg.mean_ns_per_op(), end)
    }

    let periods = [1u32, 10, 20, 100, 1000];
    let threads = *p.threads.iter().max().unwrap_or(&2);
    println!("\n== Epoch advance/check period ablation (List workload, p={threads}) ==");
    println!("{:<10}{:<10}{:>14}{:>18}", "scheme", "period", "ns/op", "end unreclaimed");
    for &period in &periods {
        let (ns, unreclaimed) = one::<Ebr>(p, threads, period);
        println!("{:<10}{period:<10}{:>14}{unreclaimed:>18}", "ER", fmt_ns(ns));
        let (ns, unreclaimed) = one::<Debra>(p, threads, period);
        println!("{:<10}{period:<10}{:>14}{unreclaimed:>18}", "DEBRA", fmt_ns(ns));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::SchemeId;

    fn tiny() -> BenchParams {
        BenchParams {
            threads: vec![1, 2],
            trials: 1,
            secs: 0.03,
            samples: 5,
            schemes: vec![SchemeId::Ebr, SchemeId::Stamp],
            ..BenchParams::default()
        }
    }

    #[test]
    fn throughput_figures_run() {
        let p = tiny();
        fig_throughput(&p, Workload::Queue);
        fig_throughput(&p, Workload::List);
    }

    #[test]
    fn efficiency_figure_runs() {
        let p = tiny();
        fig_efficiency(&p, Workload::Queue);
    }

    #[test]
    fn micro_figures_run() {
        let p = tiny();
        micro_region(&p);
        micro_stamp_pool(&p);
    }

    #[test]
    fn micro_alloc_figure_runs() {
        // Serialize against the magazine unit tests: micro_alloc toggles
        // the process-global capacity knob per row.
        let _g = crate::alloc::magazine::test_cap_lock();
        let mut p = tiny();
        p.threads = vec![1, 2];
        micro_alloc(&p);
        assert_eq!(
            crate::alloc::magazine_cap(),
            crate::alloc::DEFAULT_MAGAZINE_CAP,
            "figure restores the default capacity"
        );
    }

    #[test]
    fn shard_scaling_figure_runs() {
        // Artifact-free: the Router runs on the synthetic backend.
        let mut p = tiny();
        p.schemes = vec![SchemeId::Stamp];
        p.shards = vec![1, 2];
        p.groups = vec![1, 2];
        p.secs = 0.05;
        let cells = fig_shard_scaling(&p);
        // shards {1,2} × groups {1,2} × two domain modes, minus the
        // skipped groups=2/shards=1 combo in each mode.
        assert_eq!(cells.len(), 6);
        assert!(cells.iter().all(|c| c.groups <= c.shards));
        assert!(
            cells.iter().all(|c| c.group_batches.len() == c.groups),
            "one batch counter per engine group"
        );
    }

    #[test]
    fn baseline_gate_logic() {
        // Deterministic unit test of the comparison logic (the timed
        // halves of the gate run in the CI bench step, where the machine
        // is not saturated by parallel tests).
        let measured = vec![("ER".to_string(), 12.0), ("Stamp-it".to_string(), 50.0)];
        // Within 20% of baseline on both rows: passes.
        assert!(check_baseline(&measured, "# comment\nER,11.0\nStamp-it,60.0\n"));
        // ER regressed beyond 20% (12.0 > 9.0 * 1.2): fails.
        assert!(!check_baseline(&measured, "ER,9.0\nStamp-it,60.0\n"));
        // Missing baseline rows are skipped, not failed.
        assert!(check_baseline(&measured, "ER,11.5\n"));
        // Malformed rows are ignored rather than panicking.
        assert!(check_baseline(&measured, "garbage\nER,not-a-number\nStamp-it,55.0\n"));
    }
}

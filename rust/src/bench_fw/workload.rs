//! The paper's three benchmark workloads (§4.1), generic over the scheme.
//!
//! * **Queue** — Michael–Scott queue, equal enqueue/dequeue probability
//!   ("keeping the size of the ... queue ... roughly unchanged").
//! * **List** — Harris–Michael set of initial size `s`, key range `2s`,
//!   `workload`% updates (half insert, half remove), rest searches.
//! * **HashMap** — calculate-or-reuse of 1024-byte partial results keyed
//!   in `[0, 30000)`, bounded FIFO cache of 10000 entries over 2048
//!   buckets.
//!
//! Queue and List operations run under a `region_guard` spanning
//! `region_ops` (100) operations — the paper's setup for QSR, NER and
//! Stamp-it. The HashMap workload guards per operation (its regions are
//! long-lived anyway: one op touches the map several times).
//!
//! Every structure is built in a **fresh owned domain**
//! ([`crate::reclaim::DomainRef::new_owned`]), so benchmark configurations
//! are isolated from each other (no state leaks between schemes, thread
//! counts or trials beyond what a configuration deliberately retains), and
//! each worker thread registers one explicit handle, passed to every
//! operation as its [`HandleSource`](crate::reclaim::HandleSource) — the
//! TLS-free fast path the facade preserves.

use super::BenchParams;
use crate::ds::hashmap::FifoCache;
use crate::ds::list::List;
use crate::ds::queue::Queue;
use crate::reclaim::{DomainRef, Reclaimer, Region};
use crate::runtime::DIM;
use crate::util::rng::{mix64, Xoshiro256};
use std::sync::atomic::{AtomicBool, Ordering};

/// 1024-byte partial result (the paper's HashMap payload).
pub type SimPayload = [f32; DIM];

/// Deterministically "calculate" a partial result (the stand-in for the
/// simulation compute in throughput benchmarks; the coordinator runs the
/// real PJRT computation instead).
pub fn compute_payload(key: u64) -> SimPayload {
    let mut out = [0.0f32; DIM];
    let mut h = mix64(key ^ 0x5151_5151);
    for (i, v) in out.iter_mut().enumerate() {
        h = mix64(h.wrapping_add(i as u64));
        *v = ((h >> 40) as f32 / (1u64 << 24) as f32) - 0.5;
    }
    out
}

/// Consume a cached payload (the "reuse" path): cheap checksum read.
#[inline]
pub fn consume_payload(p: &SimPayload) -> f32 {
    p.iter().step_by(16).sum()
}

/// One thread's Queue-benchmark loop; returns its op count. Registers one
/// handle with the queue's domain and runs every operation through it.
pub fn queue_worker<R: Reclaimer>(
    q: &Queue<u64, R>,
    params: &BenchParams,
    tid: usize,
    trial: usize,
    stop: &AtomicBool,
) -> u64 {
    let h = q.domain().register();
    let mut rng = Xoshiro256::new(0x9E37 ^ (trial as u64) << 32 ^ tid as u64);
    let mut ops = 0u64;
    while !stop.load(Ordering::Acquire) {
        let _region: Region<R> = Region::enter(&h);
        for _ in 0..params.region_ops {
            if rng.percent(50) {
                q.enqueue(&h, rng.next_u64());
            } else {
                let _ = q.dequeue(&h);
            }
            ops += 1;
        }
    }
    ops
}

/// One thread's List-benchmark loop (workload% updates).
pub fn list_worker<R: Reclaimer>(
    list: &List<u64, (), R>,
    params: &BenchParams,
    tid: usize,
    trial: usize,
    stop: &AtomicBool,
) -> u64 {
    let h = list.domain().register();
    let key_range = params.list_size * 2; // paper: twice the initial size
    let mut rng = Xoshiro256::new(0xA5A5 ^ (trial as u64) << 32 ^ tid as u64);
    let mut ops = 0u64;
    while !stop.load(Ordering::Acquire) {
        let _region: Region<R> = Region::enter(&h);
        for _ in 0..params.region_ops {
            let key = rng.below(key_range);
            if rng.percent(params.workload_pct) {
                // Update: insert and remove with equal probability.
                if rng.percent(50) {
                    list.insert(&h, key, ());
                } else {
                    list.remove(&h, &key);
                }
            } else {
                list.contains(&h, &key);
            }
            ops += 1;
        }
    }
    ops
}

/// One thread's HashMap-benchmark loop: calculate-or-reuse partial results.
pub fn hashmap_worker<R: Reclaimer>(
    cache: &FifoCache<u64, SimPayload, R>,
    params: &BenchParams,
    tid: usize,
    trial: usize,
    stop: &AtomicBool,
) -> u64 {
    let h = cache.domain().register();
    let mut rng = Xoshiro256::new(0xC0DE ^ (trial as u64) << 32 ^ tid as u64);
    let mut ops = 0u64;
    let mut sink = 0.0f32;
    while !stop.load(Ordering::Acquire) {
        let key = rng.below(params.key_space);
        match cache.get(&h, &key, consume_payload) {
            Some(v) => sink += v,
            None => {
                let payload = compute_payload(key);
                sink += consume_payload(&payload);
                cache.insert(&h, key, payload);
            }
        }
        ops += 1;
    }
    std::hint::black_box(sink);
    ops
}

/// Build + prefill a List in `domain` (paper: initial size s from key range
/// 2s — insert every even key).
pub fn prefill_list_in<R: Reclaimer>(
    domain: DomainRef<R>,
    params: &BenchParams,
) -> List<u64, (), R> {
    let list = List::new_in(domain);
    // Explicit handle: the prefill must not pin the per-trial domain in the
    // calling thread's TLS handle cache (the domain should drop — and drain
    // — when the configuration ends).
    let h = list.domain().register();
    for i in 0..params.list_size {
        list.insert(&h, i * 2, ());
    }
    list
}

/// Build + prefill a List in a fresh owned domain.
pub fn prefill_list<R: Reclaimer>(params: &BenchParams) -> List<u64, (), R> {
    prefill_list_in(DomainRef::new_owned(), params)
}

/// Build + prefill a Queue in `domain` (a handful of nodes so dequeues
/// hit).
pub fn prefill_queue_in<R: Reclaimer>(
    domain: DomainRef<R>,
    _params: &BenchParams,
) -> Queue<u64, R> {
    let q = Queue::new_in(domain);
    // Explicit handle — see prefill_list_in.
    let h = q.domain().register();
    for i in 0..64 {
        q.enqueue(&h, i);
    }
    q
}

/// Build + prefill a Queue in a fresh owned domain.
pub fn prefill_queue<R: Reclaimer>(params: &BenchParams) -> Queue<u64, R> {
    prefill_queue_in(DomainRef::new_owned(), params)
}

/// Build the HashMap-benchmark cache in `domain`.
pub fn make_cache_in<R: Reclaimer>(
    domain: DomainRef<R>,
    params: &BenchParams,
) -> FifoCache<u64, SimPayload, R> {
    FifoCache::new_in(domain, params.map_buckets, params.map_capacity)
}

/// Build the HashMap-benchmark cache in a fresh owned domain.
pub fn make_cache<R: Reclaimer>(params: &BenchParams) -> FifoCache<u64, SimPayload, R> {
    make_cache_in(DomainRef::new_owned(), params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reclaim::stamp::StampIt;
    use crate::reclaim::Cached;

    #[test]
    fn payload_compute_is_deterministic_and_spread() {
        let a = compute_payload(1);
        let b = compute_payload(1);
        assert_eq!(a, b);
        let c = compute_payload(2);
        assert_ne!(a, c);
        assert!(consume_payload(&a).is_finite());
        assert_eq!(std::mem::size_of::<SimPayload>(), 1024, "paper's payload size");
    }

    #[test]
    fn workers_run_and_stop() {
        let params = BenchParams { secs: 0.05, ..BenchParams::default() };
        let q = prefill_queue::<StampIt>(&params);
        let list = prefill_list::<StampIt>(&params);
        let cache = make_cache::<StampIt>(&params);
        let stop = AtomicBool::new(false);

        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                stop.store(true, Ordering::Release);
            });
            let q_ops = queue_worker(&q, &params, 0, 0, &stop);
            assert!(q_ops > 0);
        });

        stop.store(false, Ordering::Release);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                stop.store(true, Ordering::Release);
            });
            let l_ops = list_worker(&list, &params, 0, 0, &stop);
            assert!(l_ops > 0);
        });

        stop.store(false, Ordering::Release);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                stop.store(true, Ordering::Release);
            });
            let m_ops = hashmap_worker(&cache, &params, 0, 0, &stop);
            assert!(m_ops > 0);
        });
        assert!(cache.len() <= params.map_capacity + 8);
    }

    #[test]
    fn prefilled_list_has_paper_shape() {
        let params = BenchParams::default();
        let list = prefill_list::<StampIt>(&params);
        assert_eq!(list.len(Cached) as u64, params.list_size);
        assert!(list.contains(Cached, &0));
        assert!(!list.contains(Cached, &1)); // odd keys start absent
    }
}

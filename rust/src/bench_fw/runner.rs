//! Trial runner: spawn `p` threads against a shared structure, run until
//! the timer expires, and compute the paper's metric (§4.1):
//!
//! > "Each thread calculates its average operation runtime by dividing its
//! > active, overall runtime by the total number of operations it
//! > performed. The total average runtime per operation is then calculated
//! > as the average of these per-thread runtime values."

use crate::util::monotonic_ns;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Barrier;
use std::time::Duration;

/// Outcome of one timed trial.
#[derive(Clone, Debug, Default)]
pub struct TrialResult {
    /// Total operations across all threads.
    pub total_ops: u64,
    /// Per-thread average ns/op.
    pub per_thread_ns: Vec<f64>,
    /// The paper's metric: mean of the per-thread averages.
    pub avg_ns_per_op: f64,
    /// Wall-clock length of the trial.
    pub wall_ns: u64,
}

impl TrialResult {
    /// Throughput in operations per second (wall-clock based).
    pub fn ops_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.total_ops as f64 * 1e9 / self.wall_ns as f64
        }
    }
}

/// Run one trial: each thread executes `body(thread_id, &stop)` which must
/// loop until `stop` is set and return its operation count. Threads start
/// together on a barrier; the timer spans the working phase only.
pub fn run_trial<F>(threads: usize, duration: Duration, body: F) -> TrialResult
where
    F: Fn(usize, &AtomicBool) -> u64 + Sync,
{
    let stop = AtomicBool::new(false);
    let barrier = Barrier::new(threads + 1);
    let body = &body;
    let stop_ref = &stop;
    let barrier_ref = &barrier;

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|tid| {
                scope.spawn(move || {
                    barrier_ref.wait();
                    let t0 = monotonic_ns();
                    let ops = body(tid, stop_ref);
                    let active_ns = monotonic_ns() - t0;
                    (ops, active_ns)
                })
            })
            .collect();

        barrier_ref.wait();
        let wall_start = monotonic_ns();
        std::thread::sleep(duration);
        stop.store(true, Ordering::Release);

        let mut per_thread_ns = Vec::with_capacity(threads);
        let mut total_ops = 0;
        for h in handles {
            let (ops, active_ns) = h.join().unwrap();
            total_ops += ops;
            if ops > 0 {
                per_thread_ns.push(active_ns as f64 / ops as f64);
            }
        }
        let wall_ns = monotonic_ns() - wall_start;
        let avg = if per_thread_ns.is_empty() {
            0.0
        } else {
            per_thread_ns.iter().sum::<f64>() / per_thread_ns.len() as f64
        };
        TrialResult { total_ops, per_thread_ns, avg_ns_per_op: avg, wall_ns }
    })
}

/// Aggregate over the trial sequence of one configuration.
#[derive(Clone, Debug, Default)]
pub struct ConfigResult {
    /// Per-trial avg ns/op (the paper plots their distribution).
    pub trial_ns_per_op: Vec<f64>,
    /// Per-trial throughput.
    pub trial_ops_per_sec: Vec<f64>,
}

impl ConfigResult {
    pub fn push(&mut self, t: &TrialResult) {
        self.trial_ns_per_op.push(t.avg_ns_per_op);
        self.trial_ops_per_sec.push(t.ops_per_sec());
    }

    /// Mean over trials of the paper metric.
    pub fn mean_ns_per_op(&self) -> f64 {
        if self.trial_ns_per_op.is_empty() {
            0.0
        } else {
            self.trial_ns_per_op.iter().sum::<f64>() / self.trial_ns_per_op.len() as f64
        }
    }

    pub fn mean_ops_per_sec(&self) -> f64 {
        if self.trial_ops_per_sec.is_empty() {
            0.0
        } else {
            self.trial_ops_per_sec.iter().sum::<f64>() / self.trial_ops_per_sec.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn trial_counts_ops_and_stops() {
        let counter = AtomicU64::new(0);
        let r = run_trial(3, Duration::from_millis(50), |_tid, stop| {
            let mut ops = 0;
            while !stop.load(Ordering::Acquire) {
                counter.fetch_add(1, Ordering::Relaxed);
                ops += 1;
            }
            ops
        });
        assert_eq!(r.total_ops, counter.load(Ordering::Relaxed));
        assert!(r.total_ops > 0);
        assert_eq!(r.per_thread_ns.len(), 3);
        assert!(r.avg_ns_per_op > 0.0);
        assert!(r.ops_per_sec() > 0.0);
        // Wall clock ≈ requested duration (generous bound for CI noise).
        assert!(r.wall_ns >= 50_000_000);
    }

    #[test]
    fn config_result_aggregates() {
        let mut c = ConfigResult::default();
        c.push(&TrialResult {
            total_ops: 100,
            per_thread_ns: vec![10.0],
            avg_ns_per_op: 10.0,
            wall_ns: 1_000,
        });
        c.push(&TrialResult {
            total_ops: 100,
            per_thread_ns: vec![20.0],
            avg_ns_per_op: 20.0,
            wall_ns: 1_000,
        });
        assert!((c.mean_ns_per_op() - 15.0).abs() < 1e-9);
    }
}

//! Reclamation-efficiency sampling (paper §4.4): track the number of
//! unreclaimed nodes (`allocated − reclaimed`) over time — "a smaller
//! number of unreclaimed nodes means that the reclamation scheme works
//! more efficiently". 50 samples are collected per trial.

use crate::util::monotonic_ns;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

/// One sampled point.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    /// Sample index across the whole run (the paper's x-axis).
    pub index: usize,
    /// Nanoseconds since the run started.
    pub t_ns: u64,
    /// Unreclaimed nodes at this instant.
    pub unreclaimed: u64,
}

/// Collect `count` evenly spaced samples of the global unreclaimed-node
/// counter over `duration`, while `body` runs. Returns (samples, body()).
pub fn sample_during<T>(
    count: usize,
    duration: Duration,
    index_offset: usize,
    body: impl FnOnce(&AtomicBool) -> T,
) -> (Vec<Sample>, T) {
    let stop = AtomicBool::new(false);
    let interval = duration / count.max(1) as u32;
    std::thread::scope(|scope| {
        let stop_ref = &stop;
        let sampler = scope.spawn(move || {
            let t0 = monotonic_ns();
            let mut samples = Vec::with_capacity(count);
            for i in 0..count {
                if stop_ref.load(Ordering::Acquire) {
                    break;
                }
                std::thread::sleep(interval);
                samples.push(Sample {
                    index: index_offset + i,
                    t_ns: monotonic_ns() - t0,
                    unreclaimed: crate::alloc::unreclaimed(),
                });
            }
            // Sampling spans the trial: once all samples are in, the trial
            // is over — release the workers.
            stop_ref.store(true, Ordering::Release);
            samples
        });
        let out = body(&stop);
        stop.store(true, Ordering::Release);
        let samples = sampler.join().unwrap();
        (samples, out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_requested_samples() {
        let (samples, out) = sample_during(10, Duration::from_millis(50), 5, |stop| {
            while !stop.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
            42
        });
        assert_eq!(out, 42);
        assert!(!samples.is_empty());
        assert!(samples.len() <= 10);
        assert_eq!(samples[0].index, 5, "index offset must apply");
        assert!(samples.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
    }
}

//! `repro` — the leader binary: runs the paper's benchmarks and the
//! compute-cache coordinator from one CLI.
//!
//! ```text
//! repro env                                   # Table 1 analogue
//! repro bench queue|list|hashmap [opts]       # Figures 3/4/5 (12/13/14)
//! repro efficiency queue|list|hashmap [opts]  # Figures 6, 8-11 (16-19)
//! repro trials [opts]                         # Figure 7 (15)
//! repro micro region|stamp-pool|alloc [opts]  # E13/E14/E20
//! repro ablation threshold|hp|epoch [opts]    # A1/A2/A3
//! repro serve [--scheme stamp] [--requests N] # coordinator (E15)
//!             [--shards N] [--shared-domain] [--backend pjrt|synthetic]
//!             [--frontend thread|async|net] [--clients N] [--exec-threads T]
//!             [--listen ADDR]
//! repro shard-scaling [opts]                  # E16 (artifact-free)
//! repro async-scaling [opts]                  # E17 (artifact-free)
//! repro net-scaling [opts]                    # E18 (loopback TCP storm)
//! repro trace view PATH [--json]              # decode a flight-recorder dump
//!
//! common options:
//!   --threads 1,2,4   --trials N   --secs S   --schemes all|ebr,stamp,...
//!   --alloc pool|system   --magazines on|off|CAP   --trace on|off|CAP
//!   --workload PCT   --csv out.csv   --paper
//! ```

use emr::bench_fw::figures::{self, Workload};
use emr::bench_fw::{report, BenchParams};
use emr::coordinator::frontend::mux::{self, MuxConfig};
use emr::coordinator::frontend::net::client::{storm, StormConfig};
use emr::coordinator::frontend::net::{NetConfig, NetServer};
use emr::coordinator::frontend::Frontend;
use emr::coordinator::{Backend, CacheServer, ServerConfig};
use emr::dispatch_scheme;
use emr::reclaim::{Reclaimer, SchemeId};
use emr::runtime::exec::Executor;
use emr::util::cli::Args;
use emr::util::rng::Xoshiro256;
use emr::util::stats::LogHistogram;

fn main() {
    let args = Args::parse();
    let params = BenchParams::from_args(&args);
    let mut positional = args.positional.iter().map(String::as_str);
    match positional.next() {
        Some("env") => report::print_environment(),
        Some("bench") => match positional.next() {
            Some("queue") => figures::fig_throughput(&params, Workload::Queue),
            Some("list") => figures::fig_throughput(&params, Workload::List),
            Some("hashmap") => figures::fig_throughput(&params, Workload::HashMap),
            other => usage(&format!("bench {:?}", other)),
        },
        Some("efficiency") => match positional.next() {
            Some("queue") => figures::fig_efficiency(&params, Workload::Queue),
            Some("list") => figures::fig_efficiency(&params, Workload::List),
            Some("hashmap") => figures::fig_efficiency(&params, Workload::HashMap),
            other => usage(&format!("efficiency {:?}", other)),
        },
        Some("trials") => figures::fig7_trials(&params),
        Some("micro") => match positional.next() {
            Some("region") => figures::micro_region(&params),
            Some("stamp-pool") => figures::micro_stamp_pool(&params),
            Some("alloc") => figures::micro_alloc(&params),
            other => usage(&format!("micro {:?}", other)),
        },
        Some("ablation") => match positional.next() {
            Some("threshold") => figures::abl_threshold(&params),
            Some("hp") => figures::abl_hp_threshold(&params),
            Some("epoch") => figures::abl_epoch_period(&params),
            other => usage(&format!("ablation {:?}", other)),
        },
        Some("serve") => serve(&args),
        Some("trace") => match positional.next() {
            Some("view") => trace_view(positional.next(), &args),
            other => usage(&format!("trace {:?}", other)),
        },
        Some("shard-scaling") => {
            // The returned cells feed `BENCH_fig_shard_scaling.json` in the
            // bench target; the CLI path just prints the tables.
            figures::fig_shard_scaling(&params);
        }
        Some("async-scaling") => {
            // The returned cells feed `BENCH_fig_async_scaling.json` in the
            // bench target; the CLI path just prints the tables.
            figures::fig_async_scaling(&params);
        }
        Some("net-scaling") => {
            // The returned cells feed `BENCH_fig_net_scaling.json` in the
            // bench target; the CLI path just prints the tables.
            figures::fig_net_scaling(&params);
        }
        Some("stall-robustness") => {
            // The returned cells feed `BENCH_fig_stall_robustness.json` in
            // the bench target; the CLI path just prints the tables.
            figures::fig_stall_robustness(&params);
        }
        _ => usage(""),
    }
}

/// `repro trace view PATH [--json]`: decode a flight-recorder dump (a
/// crash snapshot or any [`emr::trace::write_snapshot`] output) to text
/// or JSON on stdout.
fn trace_view(path: Option<&str>, args: &Args) {
    let Some(path) = path else {
        eprintln!("usage: repro trace view PATH [--json]");
        std::process::exit(2);
    };
    match emr::trace::read_dump(std::path::Path::new(path)) {
        Ok(dump) => {
            if args.flag("json") {
                print!("{}", dump.to_json());
            } else {
                println!(
                    "# {} events, {} labels ({})",
                    dump.events.len(),
                    dump.labels.len(),
                    path
                );
                print!("{}", dump.to_text());
            }
        }
        Err(e) => {
            eprintln!("cannot read trace dump {path}: {e}");
            std::process::exit(1);
        }
    }
}

/// E15/E17: run the coordinator on a synthetic client load and report
/// latency/throughput (the end-to-end driver; also see
/// `examples/compute_cache.rs`).
///
/// `--frontend thread` (default) is the seed's shape: one blocking OS
/// thread per client. `--frontend async` multiplexes `--clients N` logical
/// clients as tasks on `--exec-threads T` executor threads over
/// `Router::submit_async` — the regime the async front-end exists for.
/// `--frontend net` binds `--listen ADDR` and drives `--clients N` real
/// loopback TCP connections through the reactor (DESIGN.md §8); any
/// client-observed error or protocol violation exits non-zero, which is
/// the CI smoke contract.
fn serve(args: &Args) {
    let scheme = SchemeId::parse(args.get_or("scheme", "stamp")).unwrap_or_else(|| {
        eprintln!("unknown --scheme");
        std::process::exit(2);
    });
    let frontend = Frontend::parse(args.get_or("frontend", "thread")).unwrap_or_else(|| {
        eprintln!("unknown --frontend ({})", Frontend::NAMES);
        std::process::exit(2);
    });
    let clients = args.usize_or("clients", 4);
    let requests = args.usize_or("requests", 2000);
    let key_space = args.u64_or("keys", 30_000);
    let capacity = args.usize_or("capacity", 10_000);
    let shards = args.usize_or("shards", 1);
    let groups = args.usize_or("groups", 1);
    let shared_domain = args.flag("shared-domain");
    let backend = Backend::parse(args.get_or("backend", "pjrt")).unwrap_or_else(|| {
        eprintln!("unknown --backend (pjrt|synthetic)");
        std::process::exit(2);
    });
    // Flight recorder: `--trace on|off|<cap>` (default: on), and the crash
    // hook is installed up front so any panic — injected or real — leaves
    // a dump under --trace-dir.
    if let Some(t) = args.get("trace") {
        let cap = emr::trace::parse_knob(t).unwrap_or_else(|| {
            eprintln!("invalid --trace {t} (on|off|<cap>)");
            std::process::exit(2);
        });
        emr::trace::apply_knob(cap);
    }
    let trace_dir = args.get_or("trace-dir", ".").to_string();
    emr::trace::install_panic_hook(&trace_dir);
    let crash_test = args.flag("crash-test");

    struct ServeOpts {
        frontend: Frontend,
        exec_threads: usize,
        in_flight: usize,
        clients: usize,
        requests: usize,
        key_space: u64,
        listen: std::net::SocketAddr,
        cfg: ServerConfig,
        /// `--crash-test`: after the load, arm the worker-panic injection
        /// and submit the poison key — the dying worker must leave a trace
        /// dump and the request must error (not hang).
        crash_test: bool,
    }

    fn finish<R: Reclaimer>(
        server: &CacheServer<R>,
        clients: usize,
        requests: usize,
        served: usize,
        wall_s: f64,
        hist: &LogHistogram,
        crash_test: bool,
    ) {
        println!("\n== compute-cache serve ({}) ==", R::NAME);
        println!("clients={clients} requests/client={requests} wall={wall_s:.2}s");
        println!(
            "throughput: {:.0} req/s   latency p50={} p95={} p99={} max={}",
            served as f64 / wall_s,
            emr::util::stats::fmt_ns(hist.percentile(50.0) as f64),
            emr::util::stats::fmt_ns(hist.percentile(95.0) as f64),
            emr::util::stats::fmt_ns(hist.percentile(99.0) as f64),
            emr::util::stats::fmt_ns(hist.max() as f64),
        );
        println!("{}", server.metrics());
        if server.shard_count() > 1 {
            for (i, sm) in server.shard_metrics().iter().enumerate() {
                println!("  shard {i}: {sm}");
            }
        }
        if server.group_count() > 1 {
            for gm in server.group_metrics() {
                println!("  {gm}");
            }
        }
        println!("cache entries at end: {}", server.cache_len());
        if crash_test {
            // Arm the injection only now, with the rings full of a real
            // run's events, so the panic hook's dump is a meaningful one.
            emr::coordinator::enable_crash_test();
            match server.request(emr::coordinator::CRASH_TEST_KEY) {
                Err(_) => println!(
                    "crash-test: worker panicked as injected; request errored promptly"
                ),
                Ok(_) => {
                    eprintln!("crash-test: poison request unexpectedly succeeded");
                    std::process::exit(1);
                }
            }
        }
        server.shutdown();
    }

    fn run<R: Reclaimer>(o: ServeOpts) {
        let ServeOpts {
            frontend,
            exec_threads,
            in_flight,
            clients,
            requests,
            key_space,
            listen,
            cfg,
            crash_test,
        } = o;
        let shards = cfg.shards;
        let server = CacheServer::<R>::start(cfg).unwrap_or_else(|e| {
            eprintln!("server start failed: {e:#}");
            std::process::exit(1);
        });
        let groups = server.group_count();
        match frontend {
            Frontend::Thread => {
                println!(
                    "serving with scheme {} ({} shard(s), {} engine group(s), \
                     thread-per-client) …",
                    R::NAME,
                    shards,
                    groups
                );
                let t0 = emr::util::monotonic_ns();
                let latencies: Vec<LogHistogram> = std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..clients)
                        .map(|c| {
                            let server = &server;
                            scope.spawn(move || {
                                let mut rng = Xoshiro256::new(0xE2E ^ c as u64);
                                let mut lat = LogHistogram::new();
                                for _ in 0..requests {
                                    let key = rng.below(key_space) as u32;
                                    let resp = server.request(key).expect("request failed");
                                    lat.record(resp.latency_ns);
                                }
                                lat
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
                let wall_s = (emr::util::monotonic_ns() - t0) as f64 / 1e9;
                let mut all = LogHistogram::new();
                for h in &latencies {
                    all.merge(h);
                }
                finish(&server, clients, requests, clients * requests, wall_s, &all, crash_test);
            }
            Frontend::Async => {
                println!(
                    "serving with scheme {} ({} shard(s), {} engine group(s), async mux: \
                     {} logical clients on {} executor threads) …",
                    R::NAME,
                    shards,
                    groups,
                    clients,
                    exec_threads
                );
                let exec = Executor::new(exec_threads);
                let report = mux::drive(
                    &exec,
                    server.clone(),
                    &MuxConfig {
                        clients,
                        requests_per_client: requests,
                        key_space,
                        // Uniform draw, like the thread front-end above (the
                        // E17 figure is the one that skews traffic).
                        hot_pct: 0,
                        shard_in_flight: in_flight,
                        seed: 0xE2E,
                    },
                );
                let wall_s = report.wall_ns as f64 / 1e9;
                if report.errors > 0 {
                    eprintln!("warning: {} request(s) errored", report.errors);
                }
                let all = report.latency_hist();
                finish(&server, clients, requests, report.served() as usize, wall_s, &all, crash_test);
            }
            Frontend::Net => {
                println!(
                    "serving with scheme {} ({} shard(s), {} engine group(s), TCP front: \
                     {} connections bridged on {} executor threads) …",
                    R::NAME,
                    shards,
                    groups,
                    clients,
                    exec_threads
                );
                let mut net = NetServer::start(
                    server.clone(),
                    NetConfig { listen, exec_threads, ..NetConfig::default() },
                )
                .unwrap_or_else(|e| {
                    eprintln!("net front start failed: {e}");
                    std::process::exit(1);
                });
                println!("listening on {}", net.local_addr());
                let report = storm(
                    net.local_addr(),
                    &StormConfig {
                        conns: clients,
                        requests_per_conn: requests,
                        key_space,
                        // Uniform draw, like the other front-ends here (E18
                        // is the figure that skews traffic).
                        hot_pct: 0,
                        seed: 0xE2E,
                        ..StormConfig::default()
                    },
                );
                // Drain in-flight completions and flush before reporting;
                // keep `net` alive so its listener counters stay registered
                // for the `server.metrics()` line inside `finish`.
                net.shutdown();
                let wall_s = report.wall_ns as f64 / 1e9;
                let all = report.latency_hist();
                finish(&server, clients, requests, report.received as usize, wall_s, &all, crash_test);
                // The CI smoke contract: every request answered, zero
                // protocol errors.
                if report.errors > 0 {
                    eprintln!("error: {} request(s) failed or went unanswered", report.errors);
                    std::process::exit(1);
                }
            }
        }
    }
    let cfg = ServerConfig { capacity, workers: 2, ..ServerConfig::default() }
        .with_shards(shards)
        .with_groups(groups)
        .with_shared_domain(shared_domain)
        .with_backend(backend);
    let listen: std::net::SocketAddr =
        args.get_or("listen", "127.0.0.1:0").parse().unwrap_or_else(|_| {
            eprintln!("bad --listen (expected ADDR:PORT, e.g. 127.0.0.1:7070)");
            std::process::exit(2);
        });
    let opts = ServeOpts {
        frontend,
        exec_threads: args.usize_or("exec-threads", 8),
        in_flight: args.usize_or("in-flight", 256),
        clients,
        requests,
        key_space,
        listen,
        cfg,
        crash_test,
    };
    dispatch_scheme!(scheme, run, opts);
}

fn usage(context: &str) -> ! {
    if !context.is_empty() {
        eprintln!("unknown command: {context}\n");
    }
    eprintln!(
        "usage: repro <command>\n\
         \n\
         commands:\n\
         \x20 env                                  testbed description (Table 1)\n\
         \x20 bench queue|list|hashmap             throughput sweeps (Figs 3-5, 12-14)\n\
         \x20 efficiency queue|list|hashmap        unreclaimed-node series (Figs 6, 8-11, 16-19)\n\
         \x20 trials                               warm-up over trials (Figs 7, 15)\n\
         \x20 micro region|stamp-pool|alloc        microbenchmarks (E13/E14/E20)\n\
         \x20 ablation threshold|hp|epoch          design-choice ablations (A1-A3)\n\
         \x20 serve                                compute-cache coordinator (E15)\n\
         \x20   [--shards N] [--groups N] [--shared-domain] [--backend pjrt|synthetic]\n\
         \x20   [--frontend thread|async|net] [--clients N] [--exec-threads T] [--in-flight B]\n\
         \x20   [--listen ADDR:PORT]               (net front; port 0 = ephemeral)\n\
         \x20   [--trace-dir DIR] [--crash-test]   (flight recorder: crash dumps, panic injection)\n\
         \x20 shard-scaling                        router shard sweep, artifact-free (E16)\n\
         \x20 async-scaling                        async-mux vs thread-per-request, artifact-free (E17)\n\
         \x20 net-scaling                          TCP connection storm over loopback (E18)\n\
         \x20 stall-robustness                     stalled-guard adversary per scheme (E19)\n\
         \x20 trace view PATH [--json]             decode a flight-recorder dump\n\
         \n\
         common options: --threads 1,2,4 --trials N --secs S --schemes all\n\
         \x20               --alloc pool|system --magazines on|off|CAP --trace on|off|CAP\n\
         \x20               --workload PCT --csv FILE --paper"
    );
    std::process::exit(2)
}

//! Cache-line padding, replacing the `crossbeam_utils::CachePadded`
//! dependency (the crate is std-only; see `Cargo.toml`).
//!
//! 128-byte alignment covers the two-line prefetcher pairs on recent x86
//! and the 128-byte lines on apple-silicon-class aarch64 — the same
//! conservative choice crossbeam makes on these targets.

use std::ops::{Deref, DerefMut};

/// Pads and aligns `T` to 128 bytes so two `CachePadded` values never share
/// a cache line (false-sharing avoidance for hot atomics).
#[derive(Default, Debug)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap `value` in its own cache line.
    pub const fn new(value: T) -> Self {
        Self { value }
    }

    /// Unwrap.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;

    #[inline]
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn alignment_and_size() {
        assert_eq!(std::mem::align_of::<CachePadded<AtomicU64>>(), 128);
        assert!(std::mem::size_of::<CachePadded<AtomicU64>>() >= 128);
        let a = [CachePadded::new(0u64), CachePadded::new(1u64)];
        let d = &a[1] as *const _ as usize - &a[0] as *const _ as usize;
        assert!(d >= 128, "neighbours must not share a line");
    }

    #[test]
    fn deref_roundtrip() {
        let mut c = CachePadded::new(41u32);
        *c += 1;
        assert_eq!(*c, 42);
        assert_eq!(c.into_inner(), 42);
    }
}

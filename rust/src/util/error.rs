//! Minimal `anyhow`-shaped error handling (the crate is std-only).
//!
//! Provides the subset the coordinator/runtime layers use: a boxed dynamic
//! [`Error`], the [`anyhow!`]/[`bail!`] macros and a [`Context`] extension
//! trait for `Result` and `Option`.
//!
//! [`anyhow!`]: crate::anyhow
//! [`bail!`]: crate::bail

use std::fmt;

/// Boxed dynamic error (what `anyhow::Error` is, minus the backtrace).
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message layered over a source error (what `.context(...)` produces).
#[derive(Debug)]
pub struct ContextError {
    msg: String,
    source: Option<Error>,
}

impl ContextError {
    /// A leaf error carrying only a message (the `anyhow!` constructor).
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into(), source: None }
    }

    /// Wrap `source` with a higher-level message.
    pub fn wrap(msg: impl Into<String>, source: Error) -> Self {
        Self { msg: msg.into(), source: Some(source) }
    }
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.source {
            // `{:#}`-style chain rendering, always on: "msg: cause".
            Some(s) => write!(f, "{}: {}", self.msg, s),
            None => write!(f, "{}", self.msg),
        }
    }
}

impl std::error::Error for ContextError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static))
    }
}

/// Construct an [`Error`] from a format string (shim for `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::from(
            $crate::util::error::ContextError::msg(format!($($arg)*)),
        )
    };
}

/// Early-return with a formatted [`Error`] (shim for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `.context(...)` / `.with_context(...)` for `Result` and `Option`.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, msg: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::from(ContextError::wrap(msg.to_string(), e.into())))
    }

    fn with_context(self, msg: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::from(ContextError::wrap(msg(), e.into())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::from(ContextError::msg(msg.to_string())))
    }

    fn with_context(self, msg: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::from(ContextError::msg(msg())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 7)
    }

    #[test]
    fn bail_and_context_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn io_errors_convert() {
        let r: std::io::Result<()> = Err(std::io::Error::other("boom"));
        let e = r.with_context(|| "reading".to_string()).unwrap_err();
        assert!(e.to_string().starts_with("reading: "));
    }
}

//! A small seeded property-testing harness (the vendored crate set has no
//! `proptest`), used for model-based testing of the concurrent structures:
//! generate a random operation sequence from a seed, run it against both the
//! system under test and a sequential model, and on failure report the seed
//! and a greedily shrunken prefix.

use super::rng::Xoshiro256;

/// Number of random cases per property (overridable via `EMR_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("EMR_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Run `prop(rng)` for `cases` different seeds derived from `seed`.
/// `prop` returns `Err(msg)` to signal a failure.
pub fn check<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Xoshiro256) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Xoshiro256::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed (seed={case_seed:#x}, case={case}): {msg}");
        }
    }
}

/// Generate a vector of `n` operations drawn by `gen`.
pub fn ops<T>(rng: &mut Xoshiro256, n: usize, mut gen: impl FnMut(&mut Xoshiro256) -> T) -> Vec<T> {
    (0..n).map(|_| gen(rng)).collect()
}

/// Run an op-sequence property with greedy prefix shrinking: on failure, find
/// the shortest failing prefix and include it in the panic message via
/// `describe`.
pub fn check_ops<Op: Clone, F>(
    name: &str,
    seed: u64,
    cases: usize,
    max_ops: usize,
    gen: impl Fn(&mut Xoshiro256) -> Op + Copy,
    run: F,
    describe: impl Fn(&[Op]) -> String,
) where
    F: Fn(&[Op]) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Xoshiro256::new(case_seed);
        let n = 1 + rng.below_usize(max_ops);
        let sequence = ops(&mut rng, n, gen);
        if let Err(msg) = run(&sequence) {
            // Greedy shrink: shortest failing prefix.
            let mut lo = 1;
            while lo < sequence.len() && run(&sequence[..lo]).is_ok() {
                lo += 1;
            }
            let prefix = &sequence[..lo];
            panic!(
                "property `{name}` failed (seed={case_seed:#x}, case={case}, \
                 shrunk {orig}→{short} ops): {msg}\nops: {}",
                describe(prefix),
                orig = sequence.len(),
                short = prefix.len(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 1, 16, |rng| {
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `falsum` failed")]
    fn failing_property_panics_with_seed() {
        check("falsum", 1, 4, |_| Err("always".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn shrinking_reports_short_prefix() {
        // Fails as soon as the sequence contains a 7; shrinker should trim.
        check_ops(
            "contains-seven",
            3,
            32,
            64,
            |rng| rng.below(10),
            |ops| {
                if ops.contains(&7) {
                    Err("saw 7".into())
                } else {
                    Ok(())
                }
            },
            |ops| format!("{ops:?}"),
        );
    }
}

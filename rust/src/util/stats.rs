//! Summary statistics for benchmark reporting: mean, stddev, percentiles and
//! trimmed means — the quantities the paper's plots are built from (the paper
//! reports per-trial average runtime per operation and smoothed conditional
//! means over repeated runs).

/// Aggregate of a sample set (nanoseconds, counts, ...).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Nearest-rank percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((pct / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Mean after dropping the lowest and highest `trim` fraction — the robust
/// per-op estimate the bench harness reports (resilient to scheduler noise,
/// important on oversubscribed cores).
pub fn trimmed_mean(samples: &[f64], trim: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cut = ((sorted.len() as f64) * trim) as usize;
    let kept = &sorted[cut..sorted.len() - cut.min(sorted.len() - cut - 1)];
    kept.iter().sum::<f64>() / kept.len() as f64
}

/// Simple moving average used to mimic the paper's "smoothed conditional
/// means" in the efficiency time-series plots.
pub fn smooth(series: &[f64], window: usize) -> Vec<f64> {
    if window <= 1 || series.len() <= 2 {
        return series.to_vec();
    }
    let w = window.min(series.len());
    let mut out = Vec::with_capacity(series.len());
    for i in 0..series.len() {
        let lo = i.saturating_sub(w / 2);
        let hi = (i + w / 2 + 1).min(series.len());
        out.push(series[lo..hi].iter().sum::<f64>() / (hi - lo) as f64);
    }
    out
}

/// Sub-bucket resolution bits of [`LogHistogram`]: 16 linear sub-buckets
/// per power of two → ≤ 1/16 (6.25%) relative error per recorded value.
const HIST_SUB_BITS: u32 = 4;
const HIST_SUB: usize = 1 << HIST_SUB_BITS;
/// Blocks 0..=60 of 16 buckets cover the full u64 range.
const HIST_BUCKETS: usize = (64 - HIST_SUB_BITS as usize + 1) * HIST_SUB;

/// Log-bucketed latency histogram (HdrHistogram-style bucketing): O(1)
/// record, mergeable, percentiles with ≤ 6.25% relative error, fixed
/// ~8 KiB footprint. This replaces the keep-every-sample + full-sort
/// percentile path in the latency reports — at 100k-client scale the
/// per-request vectors were the dominant reporting cost — and is what
/// the trace recorder folds submit→complete deltas into.
///
/// Bucketing: values below 16 get exact unit buckets; above, each
/// power-of-two octave splits into 16 linear sub-buckets.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Box<[u64; HIST_BUCKETS]>,
    count: u64,
    min: u64,
    max: u64,
    sum: u128,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self {
            counts: Box::new([0; HIST_BUCKETS]),
            count: 0,
            min: u64::MAX,
            max: 0,
            sum: 0,
        }
    }

    fn index(v: u64) -> usize {
        if v < HIST_SUB as u64 {
            v as usize
        } else {
            let top = 63 - v.leading_zeros();
            let sub = ((v >> (top - HIST_SUB_BITS)) & (HIST_SUB as u64 - 1)) as usize;
            (top - HIST_SUB_BITS + 1) as usize * HIST_SUB + sub
        }
    }

    /// Largest value that falls into bucket `i` (what percentiles report:
    /// an upper bound, never an underestimate beyond the bucket width).
    fn bucket_high(i: usize) -> u64 {
        let block = i / HIST_SUB;
        let sub = (i % HIST_SUB) as u64;
        if block == 0 {
            return sub;
        }
        let top = block as u32 + HIST_SUB_BITS - 1;
        let width = 1u64 << (top - HIST_SUB_BITS);
        (1u64 << top) + sub * width + (width - 1)
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.count += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.sum += v as u128;
    }

    /// Fold another histogram in (bucket-wise; lossless).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.sum += other.sum;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.max
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank percentile (upper bucket bound, clamped to the true
    /// observed max so p100 is exact).
    pub fn percentile(&self, pct: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((pct / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_high(i).min(self.max);
            }
        }
        self.max
    }
}

/// Human-friendly nanosecond formatting ("12.3 ns", "4.5 µs", ...).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Format a count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_samples() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-9);
        assert!((s.p50 - 3.0).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_of_empty_is_zeroed() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_extremes() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
        assert_eq!(percentile_sorted(&v, 1.0), 1.0);
    }

    #[test]
    fn trimmed_mean_drops_outliers() {
        let samples = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1000.0, 0.0];
        let tm = trimmed_mean(&samples, 0.1);
        assert!(tm < 2.0, "tm={tm}");
    }

    #[test]
    fn smooth_preserves_length_and_flattens() {
        let noisy = [0.0, 10.0, 0.0, 10.0, 0.0, 10.0];
        let s = smooth(&noisy, 3);
        assert_eq!(s.len(), noisy.len());
        let spread = |v: &[f64]| {
            v.iter().cloned().fold(f64::MIN, f64::max) - v.iter().cloned().fold(f64::MAX, f64::min)
        };
        assert!(spread(&s) < spread(&noisy));
    }

    #[test]
    fn histogram_small_values_are_exact() {
        let mut h = LogHistogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 16);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 15);
        // Unit buckets below 16: percentiles are exact.
        assert_eq!(h.percentile(50.0), 7);
        assert_eq!(h.percentile(100.0), 15);
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // index/bucket_high are inverse at every octave boundary and the
        // recorded value always falls within its bucket's bound.
        for v in [15u64, 16, 17, 31, 32, 33, 63, 64, 1000, 1023, 1024, u32::MAX as u64, 1 << 40]
        {
            let i = LogHistogram::index(v);
            let hi = LogHistogram::bucket_high(i);
            assert!(hi >= v, "bucket_high({i})={hi} < v={v}");
            // Relative error bound: bucket upper edge within 1/16 of v.
            assert!(hi as f64 <= v as f64 * (1.0 + 1.0 / 16.0), "v={v} hi={hi}");
            // The bound is itself a member of the bucket.
            assert_eq!(LogHistogram::index(hi), i, "v={v}");
        }
    }

    #[test]
    fn histogram_percentiles_within_error() {
        let mut h = LogHistogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.percentile(50.0) as f64;
        let p99 = h.percentile(99.0) as f64;
        let p999 = h.percentile(99.9) as f64;
        assert!((p50 - 5_000.0).abs() / 5_000.0 < 0.07, "p50={p50}");
        assert!((p99 - 9_900.0).abs() / 9_900.0 < 0.07, "p99={p99}");
        assert!((p999 - 9_990.0).abs() / 9_990.0 < 0.07, "p999={p999}");
        assert_eq!(h.percentile(100.0), 10_000);
    }

    #[test]
    fn histogram_merge_equals_union() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut all = LogHistogram::new();
        for v in [3u64, 17, 900, 4096, 70_000] {
            a.record(v);
            all.record(v);
        }
        for v in [1u64, 250, 8191, 1 << 20] {
            b.record(v);
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        for pct in [10.0, 50.0, 90.0, 99.0, 99.9] {
            assert_eq!(a.percentile(pct), all.percentile(pct));
        }
        assert!((a.mean() - all.mean()).abs() < 1e-9);
    }

    #[test]
    fn histogram_empty_is_zeroed() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(12.34), "12.3 ns");
        assert_eq!(fmt_ns(4_500.0), "4.50 µs");
        assert_eq!(fmt_count(1_234_567), "1,234,567");
    }
}

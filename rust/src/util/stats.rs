//! Summary statistics for benchmark reporting: mean, stddev, percentiles and
//! trimmed means — the quantities the paper's plots are built from (the paper
//! reports per-trial average runtime per operation and smoothed conditional
//! means over repeated runs).

/// Aggregate of a sample set (nanoseconds, counts, ...).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Nearest-rank percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((pct / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Mean after dropping the lowest and highest `trim` fraction — the robust
/// per-op estimate the bench harness reports (resilient to scheduler noise,
/// important on oversubscribed cores).
pub fn trimmed_mean(samples: &[f64], trim: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cut = ((sorted.len() as f64) * trim) as usize;
    let kept = &sorted[cut..sorted.len() - cut.min(sorted.len() - cut - 1)];
    kept.iter().sum::<f64>() / kept.len() as f64
}

/// Simple moving average used to mimic the paper's "smoothed conditional
/// means" in the efficiency time-series plots.
pub fn smooth(series: &[f64], window: usize) -> Vec<f64> {
    if window <= 1 || series.len() <= 2 {
        return series.to_vec();
    }
    let w = window.min(series.len());
    let mut out = Vec::with_capacity(series.len());
    for i in 0..series.len() {
        let lo = i.saturating_sub(w / 2);
        let hi = (i + w / 2 + 1).min(series.len());
        out.push(series[lo..hi].iter().sum::<f64>() / (hi - lo) as f64);
    }
    out
}

/// Human-friendly nanosecond formatting ("12.3 ns", "4.5 µs", ...).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Format a count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_samples() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-9);
        assert!((s.p50 - 3.0).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_of_empty_is_zeroed() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_extremes() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
        assert_eq!(percentile_sorted(&v, 1.0), 1.0);
    }

    #[test]
    fn trimmed_mean_drops_outliers() {
        let samples = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1000.0, 0.0];
        let tm = trimmed_mean(&samples, 0.1);
        assert!(tm < 2.0, "tm={tm}");
    }

    #[test]
    fn smooth_preserves_length_and_flattens() {
        let noisy = [0.0, 10.0, 0.0, 10.0, 0.0, 10.0];
        let s = smooth(&noisy, 3);
        assert_eq!(s.len(), noisy.len());
        let spread = |v: &[f64]| {
            v.iter().cloned().fold(f64::MIN, f64::max) - v.iter().cloned().fold(f64::MAX, f64::min)
        };
        assert!(spread(&s) < spread(&noisy));
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(12.34), "12.3 ns");
        assert_eq!(fmt_ns(4_500.0), "4.50 µs");
        assert_eq!(fmt_count(1_234_567), "1,234,567");
    }
}

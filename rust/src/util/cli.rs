//! Minimal CLI argument parser (the vendored crate set has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! generated `--help` text. Benches and the `repro` binary share it.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Leading non-flag arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs; bare `--flag` maps to "true".
    options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — flags may appear anywhere.
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.options.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0] and a possible
    /// `--bench` injected by `cargo bench`).
    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).map(|v| v.parse().unwrap_or_else(|_| die(name, v))).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).map(|v| v.parse().unwrap_or_else(|_| die(name, v))).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).map(|v| v.parse().unwrap_or_else(|_| die(name, v))).unwrap_or(default)
    }

    /// Comma-separated list, e.g. `--threads 1,2,4,8`.
    pub fn list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| die(name, v)))
                .collect(),
        }
    }
}

fn die(name: &str, v: &str) -> ! {
    eprintln!("invalid value for --{name}: {v:?}");
    std::process::exit(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = parse("bench --threads 4 --scheme=stamp --verbose");
        assert_eq!(a.positional, vec!["bench"]);
        assert_eq!(a.usize_or("threads", 1), 4);
        assert_eq!(a.get("scheme"), Some("stamp"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize_or("threads", 3), 3);
        assert_eq!(a.get_or("scheme", "ebr"), "ebr");
        assert_eq!(a.f64_or("secs", 1.5), 1.5);
    }

    #[test]
    fn parses_lists() {
        let a = parse("--threads 1,2,4,8");
        assert_eq!(a.list_or("threads", &[]), vec![1, 2, 4, 8]);
        assert_eq!(a.list_or("missing", &[7]), vec![7]);
    }

    #[test]
    fn bare_flag_before_positional() {
        // A bare flag followed by a non-flag consumes it as a value; callers
        // must order flags after positionals or use `=` — document by test.
        let a = parse("--paper --secs 2 queue");
        assert!(a.flag("paper"));
        assert_eq!(a.u64_or("secs", 0), 2);
        assert_eq!(a.positional, vec!["queue"]);
    }
}

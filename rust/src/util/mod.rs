//! Small self-contained utilities.
//!
//! The build is fully offline (vendored crates only), so facilities that
//! would normally come from `rand`, `clap`, `criterion` or `proptest` are
//! implemented here: a counter-based PRNG ([`rng`]), summary statistics
//! ([`stats`]), a tiny CLI parser ([`cli`]) and a seeded model-based
//! property-testing harness ([`prop`]).

pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;

/// Number of logical CPUs visible to this process.
pub fn num_cpus() -> usize {
    // SAFETY: plain libc query, no preconditions.
    let n = unsafe { libc::sysconf(libc::_SC_NPROCESSORS_ONLN) };
    if n < 1 {
        1
    } else {
        n as usize
    }
}

/// Monotonic nanosecond clock (CLOCK_MONOTONIC); the benchmark timebase.
pub fn monotonic_ns() -> u64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: ts is a valid out-pointer.
    unsafe { libc::clock_gettime(libc::CLOCK_MONOTONIC, &mut ts) };
    ts.tv_sec as u64 * 1_000_000_000 + ts.tv_nsec as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_cpus_is_positive() {
        assert!(num_cpus() >= 1);
    }

    #[test]
    fn monotonic_clock_advances() {
        let a = monotonic_ns();
        let b = monotonic_ns();
        assert!(b >= a);
    }
}

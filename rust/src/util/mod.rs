//! Small self-contained utilities.
//!
//! The build is fully offline (vendored crates only), so facilities that
//! would normally come from `rand`, `clap`, `criterion` or `proptest` are
//! implemented here: a counter-based PRNG ([`rng`]), summary statistics
//! ([`stats`]), a tiny CLI parser ([`cli`]) and a seeded model-based
//! property-testing harness ([`prop`]).

pub mod cache_pad;
pub mod cli;
pub mod error;
pub mod prop;
pub mod rng;
pub mod stats;

/// Number of logical CPUs visible to this process.
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Monotonic nanosecond clock; the benchmark timebase. Nanoseconds since
/// the first call (an arbitrary but fixed epoch — only differences are
/// meaningful).
pub fn monotonic_ns() -> u64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_cpus_is_positive() {
        assert!(num_cpus() >= 1);
    }

    #[test]
    fn monotonic_clock_advances() {
        let a = monotonic_ns();
        let b = monotonic_ns();
        assert!(b >= a);
    }
}

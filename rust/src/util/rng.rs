//! Deterministic PRNGs for workloads and property tests.
//!
//! SplitMix64 (Steele et al.) seeds Xoshiro256** (Blackman & Vigna); both are
//! tiny, fast, and reproducible across runs — benchmark trials are seeded per
//! (trial, thread) so paper-style repeated runs are comparable.

/// SplitMix64 — used for seeding and for cheap stateless hashing.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// One-shot SplitMix64 finalizer: a good 64→64 bit mixer.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — the workload generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift; bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Bernoulli with probability `pct` in percent.
    #[inline]
    pub fn percent(&mut self, pct: u32) -> bool {
        self.below(100) < pct as u64
    }

    /// One key from the serving benchmarks' skewed stream: `hot_pct`% of
    /// draws land on a hot set of 1% of the key space (min 16 keys), the
    /// rest are uniform. The E15/E16/E17 load shape, defined once — the
    /// coordinator figures stay comparable because they all draw from here.
    #[inline]
    pub fn skewed_key(&mut self, key_space: u64, hot_pct: u32) -> u32 {
        let key_space = key_space.max(1);
        // min(max(ks/100, 16), ks) without a max-min chain: the hot set is
        // 1% of the key space, at least 16 keys, never beyond the space.
        let hot_set = (key_space / 100).max(16.min(key_space));
        if self.percent(hot_pct) {
            self.below(hot_set) as u32
        } else {
            self.below(key_space) as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 0 from the public-domain implementation.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_is_deterministic() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn skewed_key_stays_in_range() {
        let mut r = Xoshiro256::new(7);
        for ks in [1u64, 4, 100, 30_000] {
            for _ in 0..1000 {
                assert!((r.skewed_key(ks, 80) as u64) < ks);
            }
        }
        // The skew is real: at 100% hot, every key lands in the hot set.
        let mut r = Xoshiro256::new(8);
        for _ in 0..1000 {
            assert!((r.skewed_key(30_000, 100) as u64) < 300);
        }
    }

    #[test]
    fn percent_rates_are_plausible() {
        let mut r = Xoshiro256::new(9);
        let hits = (0..100_000).filter(|_| r.percent(20)).count();
        assert!((15_000..25_000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Xoshiro256::new(3);
        let mut buckets = [0usize; 8];
        for _ in 0..80_000 {
            buckets[r.below_usize(8)] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "buckets={buckets:?}");
        }
    }
}

//! Lock-free, size-classed, **type-stable** slot pool.
//!
//! Design:
//!
//! * Size classes are powers of two from 64 B to 64 KiB. Every class owns a
//!   set of 2 MiB chunks, each aligned to 2 MiB so a slot pointer can be
//!   masked back to its chunk header (no per-slot bookkeeping).
//! * Free slots form a Treiber stack of **slot indices** with a 32-bit
//!   version tag packed next to the index in one `AtomicU64` head —
//!   the tag makes pop ABA-safe without double-word CAS (the same packing
//!   discipline the paper applies to its Stamp Pool links).
//! * The intrusive free-list link lives at byte offset 8 of a free slot.
//!   **Offset 0 is never written by the pool**: LFRC keeps its refcount
//!   word there, and Valois-style counting relies on that word staying
//!   readable (and marked RETIRED) while the slot sits in the free-list.
//! * Chunks are never unmapped — the type-stability guarantee.
//!
//! Fresh slots are handed out by a per-class bump cursor; the free-list is
//! only populated by frees, so the fast path after warm-up is pop/push.

use std::alloc::Layout;
use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

const CHUNK_BYTES: usize = 1 << 21; // 2 MiB, alignment == size
const SLOT_ALIGN: usize = 64;
const MIN_CLASS: usize = 64;
const MAX_CLASS: usize = 64 * 1024;
const NUM_CLASSES: usize = 11; // 64,128,...,65536
const MAX_CHUNKS: usize = 4096; // per class => 8 GiB per class, ample
const NIL: u32 = u32::MAX;

/// Per-chunk header, stored at the start of each aligned chunk.
#[repr(C)]
struct ChunkHeader {
    /// Global slot index of this chunk's first slot.
    start_index: u32,
    /// Slot size of the owning class (for debug assertions).
    slot_size: u32,
}

/// Header space reserved at the chunk start (keeps slots 64-aligned).
const HEADER_BYTES: usize = SLOT_ALIGN;

struct SizeClass {
    slot_size: usize,
    slots_per_chunk: usize,
    /// Packed Treiber head: `(tag << 32) | index`, `NIL` index = empty.
    head: AtomicU64,
    /// Next never-used global slot index.
    bump: AtomicU64,
    /// Number of published chunks; `capacity = count * slots_per_chunk`.
    count: AtomicU32,
    bases: Box<[AtomicPtr<u8>]>,
    grow: Mutex<()>,
}

impl SizeClass {
    fn new(slot_size: usize) -> Self {
        let slots_per_chunk = (CHUNK_BYTES - HEADER_BYTES) / slot_size;
        let bases = (0..MAX_CHUNKS).map(|_| AtomicPtr::new(ptr::null_mut())).collect();
        Self {
            slot_size,
            slots_per_chunk,
            head: AtomicU64::new(NIL as u64),
            bump: AtomicU64::new(0),
            count: AtomicU32::new(0),
            bases,
            grow: Mutex::new(()),
        }
    }

    #[inline]
    fn slot_ptr(&self, index: u32) -> *mut u8 {
        let chunk = index as usize / self.slots_per_chunk;
        let slot = index as usize % self.slots_per_chunk;
        let base = self.bases[chunk].load(Ordering::Acquire);
        debug_assert!(!base.is_null(), "slot index {index} in unpublished chunk");
        // SAFETY: base points at a live CHUNK_BYTES chunk and slot is in range.
        unsafe { base.add(HEADER_BYTES + slot * self.slot_size) }
    }

    /// The free-list link of a free slot (byte offset 8 — offset 0 is
    /// reserved for scheme headers, see module docs).
    #[inline]
    fn link(&self, slot: *mut u8) -> *mut u32 {
        // SAFETY: every slot is at least 64 bytes.
        unsafe { slot.add(8) as *mut u32 }
    }

    fn alloc(&self) -> *mut u8 {
        loop {
            // Fast path: pop from the tagged free-list.
            let head = self.head.load(Ordering::Acquire);
            let index = head as u32;
            if index != NIL {
                let slot = self.slot_ptr(index);
                // The link read may be stale if another thread popped and
                // reused the slot concurrently — the tagged CAS below
                // detects that and we retry.
                // SAFETY: slot memory is never unmapped (type-stable).
                let next = unsafe { self.link(slot).read_volatile() };
                let new = ((head >> 32).wrapping_add(1) << 32) | next as u64;
                if self
                    .head
                    .compare_exchange_weak(head, new, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    return slot;
                }
                continue;
            }
            // Slow path: bump-allocate a fresh slot, growing if needed.
            let i = self.bump.fetch_add(1, Ordering::Relaxed);
            assert!(i < (MAX_CHUNKS * self.slots_per_chunk) as u64, "pool class exhausted");
            let i = i as u32;
            while (self.count.load(Ordering::Acquire) as u64 * self.slots_per_chunk as u64)
                <= i as u64
            {
                self.grow_to(i);
            }
            return self.slot_ptr(i);
        }
    }

    #[cold]
    fn grow_to(&self, index: u32) {
        let _g = self.grow.lock().unwrap();
        while (self.count.load(Ordering::Acquire) as u64 * self.slots_per_chunk as u64)
            <= index as u64
        {
            let chunk_idx = self.count.load(Ordering::Acquire) as usize;
            assert!(chunk_idx < MAX_CHUNKS, "pool class exhausted");
            let layout = Layout::from_size_align(CHUNK_BYTES, CHUNK_BYTES).unwrap();
            // SAFETY: non-zero, power-of-two layout.
            let base = unsafe { std::alloc::alloc_zeroed(layout) };
            assert!(!base.is_null(), "chunk allocation failed");
            // SAFETY: fresh chunk, header fits in HEADER_BYTES.
            unsafe {
                (base as *mut ChunkHeader).write(ChunkHeader {
                    start_index: (chunk_idx * self.slots_per_chunk) as u32,
                    slot_size: self.slot_size as u32,
                });
            }
            self.bases[chunk_idx].store(base, Ordering::Release);
            self.count.store(chunk_idx as u32 + 1, Ordering::Release);
        }
    }

    fn free(&self, slot: *mut u8) {
        let index = self.index_of(slot);
        loop {
            let head = self.head.load(Ordering::Acquire);
            // SAFETY: slot belongs to this class (checked by index_of).
            unsafe { self.link(slot).write_volatile(head as u32) };
            let new = ((head >> 32).wrapping_add(1) << 32) | index as u64;
            if self
                .head
                .compare_exchange_weak(head, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
        }
    }

    fn index_of(&self, slot: *mut u8) -> u32 {
        let base = (slot as usize & !(CHUNK_BYTES - 1)) as *mut u8;
        // SAFETY: slot came from this pool, so the masked base is a chunk
        // header that is never unmapped.
        let header = unsafe { &*(base as *const ChunkHeader) };
        debug_assert_eq!(header.slot_size as usize, self.slot_size);
        let offset = slot as usize - base as usize - HEADER_BYTES;
        debug_assert_eq!(offset % self.slot_size, 0);
        header.start_index + (offset / self.slot_size) as u32
    }
}

fn classes() -> &'static [SizeClass; NUM_CLASSES] {
    use std::sync::OnceLock;
    static CLASSES: OnceLock<Box<[SizeClass; NUM_CLASSES]>> = OnceLock::new();
    CLASSES.get_or_init(|| {
        let v: Vec<SizeClass> =
            (0..NUM_CLASSES).map(|i| SizeClass::new(MIN_CLASS << i)).collect();
        let boxed: Box<[SizeClass; NUM_CLASSES]> =
            v.into_boxed_slice().try_into().map_err(|_| ()).unwrap();
        boxed
    })
}

fn class_index(size: usize) -> usize {
    let size = size.max(MIN_CLASS);
    assert!(size <= MAX_CLASS, "pool allocation of {size} B exceeds the {MAX_CLASS} B max class");
    (usize::BITS - (size - 1).leading_zeros()) as usize - MIN_CLASS.trailing_zeros() as usize
}

/// Allocate a slot large enough for `layout`. Aborts on OOM.
pub fn alloc(layout: Layout) -> *mut u8 {
    assert!(layout.align() <= SLOT_ALIGN, "pool supports alignment up to {SLOT_ALIGN}");
    classes()[class_index(layout.size())].alloc()
}

/// Return a slot to its size class.
///
/// # Safety
/// `ptr` must come from [`alloc`] with a layout of the same size class and
/// must not be used afterwards. Byte offset 0 of the slot is preserved
/// (LFRC's refcount word); offsets 8..12 are overwritten by the free-list
/// link.
pub unsafe fn free(ptr: *mut u8, layout: Layout) {
    classes()[class_index(layout.size())].free(ptr);
}

/// Number of bytes currently held by the pool (for diagnostics).
pub fn footprint_bytes() -> usize {
    classes().iter().map(|c| c.count.load(Ordering::Relaxed) as usize * CHUNK_BYTES).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn class_index_boundaries() {
        assert_eq!(class_index(1), 0);
        assert_eq!(class_index(64), 0);
        assert_eq!(class_index(65), 1);
        assert_eq!(class_index(128), 1);
        assert_eq!(class_index(129), 2);
        assert_eq!(class_index(MAX_CLASS), NUM_CLASSES - 1);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn oversized_allocation_panics() {
        class_index(MAX_CLASS + 1);
    }

    #[test]
    fn alloc_free_recycles_slots() {
        // Size class chosen to be unused by other (parallel) tests so the
        // LIFO assertion is not raced.
        let layout = Layout::from_size_align(3000, 8).unwrap();
        let a = alloc(layout);
        unsafe { free(a, layout) };
        let b = alloc(layout);
        // LIFO free-list: the same slot comes back.
        assert_eq!(a, b);
        unsafe { free(b, layout) };
    }

    #[test]
    fn distinct_live_allocations_do_not_alias() {
        let layout = Layout::from_size_align(64, 8).unwrap();
        let ptrs: Vec<_> = (0..1000).map(|_| alloc(layout)).collect();
        let set: HashSet<_> = ptrs.iter().collect();
        assert_eq!(set.len(), ptrs.len());
        for p in ptrs {
            unsafe { free(p, layout) };
        }
    }

    #[test]
    fn word0_is_preserved_across_free() {
        // Class 32768 — unused elsewhere, keeps the LIFO assertion race-free.
        let layout = Layout::from_size_align(20_000, 8).unwrap();
        let p = alloc(layout);
        unsafe {
            (p as *mut u64).write(0xDEAD_BEEF_CAFE_F00D);
            free(p, layout);
            // Slot is free but word 0 must be intact (LFRC contract).
            assert_eq!((p as *mut u64).read(), 0xDEAD_BEEF_CAFE_F00D);
        }
        let q = alloc(layout);
        assert_eq!(p, q);
        unsafe { free(q, layout) };
    }

    #[test]
    fn concurrent_alloc_free_stress() {
        let layout = Layout::from_size_align(96, 8).unwrap();
        let threads: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut held = Vec::new();
                    for i in 0..2000 {
                        held.push(alloc(layout));
                        if i % 3 == 0 {
                            if let Some(p) = held.pop() {
                                unsafe { free(p, layout) };
                            }
                        }
                        if i % 64 == 0 {
                            std::thread::yield_now();
                        }
                    }
                    // Write to every held slot to catch aliasing between
                    // concurrently-live allocations.
                    for (i, &p) in held.iter().enumerate() {
                        unsafe { (p as *mut u64).write(i as u64) };
                    }
                    for (i, &p) in held.iter().enumerate() {
                        unsafe { assert_eq!((p as *mut u64).read(), i as u64) };
                    }
                    for p in held {
                        unsafe { free(p, layout) };
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }
}

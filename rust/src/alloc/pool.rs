//! Lock-free, size-classed, **type-stable** slot pool.
//!
//! Design:
//!
//! * Size classes are powers of two from 64 B to 64 KiB. Every class owns a
//!   set of 2 MiB chunks, each aligned to 2 MiB so a slot pointer can be
//!   masked back to its chunk header (no per-slot bookkeeping).
//! * Free slots form a Treiber stack of **slot indices** with a 32-bit
//!   version tag packed next to the index in one `AtomicU64` head —
//!   the tag makes pop ABA-safe without double-word CAS (the same packing
//!   discipline the paper applies to its Stamp Pool links).
//! * The intrusive free-list link lives at byte offset 8 of a free slot and
//!   the depot chain-of-chains link at byte offset 12 (see below).
//!   **Offset 0 is never written by the pool**: LFRC keeps its refcount
//!   word there, and Valois-style counting relies on that word staying
//!   readable (and marked RETIRED) while the slot sits in the free-list.
//!   Offsets 8..16 of a *free* slot are pool-owned scratch; everything else
//!   is untouched.
//! * Chunks are never unmapped — the type-stability guarantee.
//! * A per-thread **magazine** layer ([`super::magazine`]) fronts the
//!   Treiber head: [`alloc`]/[`free`] first try the calling thread's
//!   magazine rack, and whole magazines are exchanged with the per-class
//!   **depot** — a second tagged stack whose elements are *chains* of up to
//!   a magazine's worth of slots linked through offset 8, so one CAS moves
//!   ~64 slots instead of one.
//!
//! Fresh slots are handed out by a per-class bump cursor; the free-list is
//! only populated by frees, so the fast path after warm-up is pop/push —
//! and with magazines enabled, a non-atomic `Vec` pop/push.

use std::alloc::Layout;
use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

const CHUNK_BYTES: usize = 1 << 21; // 2 MiB, alignment == size
const SLOT_ALIGN: usize = 64;
const MIN_CLASS: usize = 64;
const MAX_CLASS: usize = 64 * 1024;
pub(crate) const NUM_CLASSES: usize = 11; // 64,128,...,65536
const MAX_CHUNKS: usize = 4096; // per class => 8 GiB per class, ample
const NIL: u32 = u32::MAX;

/// Per-chunk header, stored at the start of each aligned chunk.
#[repr(C)]
struct ChunkHeader {
    /// Global slot index of this chunk's first slot.
    start_index: u32,
    /// Slot size of the owning class (for debug assertions).
    slot_size: u32,
}

/// Header space reserved at the chunk start (keeps slots 64-aligned).
const HEADER_BYTES: usize = SLOT_ALIGN;

/// One size class. The global pool holds a `'static` array of these; tests
/// may construct private instances (class-level alloc/free sit *below* the
/// magazine layer, so a private instance is magazine-free by construction
/// and its LIFO behaviour is exact and unraced).
pub(crate) struct SizeClass {
    slot_size: usize,
    slots_per_chunk: usize,
    /// Packed Treiber head: `(tag << 32) | index`, `NIL` index = empty.
    head: AtomicU64,
    /// Depot of slot *chains* (magazine-granularity exchange): packed
    /// `(tag << 32) | index` of the top chain's head slot. Chain-internal
    /// links are the ordinary offset-8 links; the link from one chain's
    /// head slot to the next chain's head lives at offset 12.
    depot: AtomicU64,
    /// Next never-used global slot index.
    bump: AtomicU64,
    /// Number of published chunks; `capacity = count * slots_per_chunk`.
    count: AtomicU32,
    bases: Box<[AtomicPtr<u8>]>,
    grow: Mutex<()>,
}

impl SizeClass {
    pub(crate) fn new(slot_size: usize) -> Self {
        let slots_per_chunk = (CHUNK_BYTES - HEADER_BYTES) / slot_size;
        let bases = (0..MAX_CHUNKS).map(|_| AtomicPtr::new(ptr::null_mut())).collect();
        Self {
            slot_size,
            slots_per_chunk,
            head: AtomicU64::new(NIL as u64),
            depot: AtomicU64::new(NIL as u64),
            bump: AtomicU64::new(0),
            count: AtomicU32::new(0),
            bases,
            grow: Mutex::new(()),
        }
    }

    #[inline]
    fn slot_ptr(&self, index: u32) -> *mut u8 {
        let chunk = index as usize / self.slots_per_chunk;
        let slot = index as usize % self.slots_per_chunk;
        let base = self.bases[chunk].load(Ordering::Acquire);
        debug_assert!(!base.is_null(), "slot index {index} in unpublished chunk");
        // SAFETY: base points at a live CHUNK_BYTES chunk and slot is in range.
        unsafe { base.add(HEADER_BYTES + slot * self.slot_size) }
    }

    /// The free-list / chain-internal link of a free slot (byte offset 8 —
    /// offset 0 is reserved for scheme headers, see module docs).
    #[inline]
    fn link(&self, slot: *mut u8) -> *mut u32 {
        // SAFETY: every slot is at least 64 bytes.
        unsafe { slot.add(8) as *mut u32 }
    }

    /// The chain-of-chains link of a depot chain's head slot (byte offset
    /// 12; only meaningful while the chain sits in the depot).
    #[inline]
    fn chain_link(&self, slot: *mut u8) -> *mut u32 {
        // SAFETY: every slot is at least 64 bytes.
        unsafe { slot.add(12) as *mut u32 }
    }

    pub(crate) fn alloc(&self) -> *mut u8 {
        loop {
            // Fast path: pop from the tagged free-list.
            let head = self.head.load(Ordering::Acquire);
            let index = head as u32;
            if index != NIL {
                let slot = self.slot_ptr(index);
                // The link read may be stale if another thread popped and
                // reused the slot concurrently — the tagged CAS below
                // detects that and we retry.
                // SAFETY: slot memory is never unmapped (type-stable).
                let next = unsafe { self.link(slot).read_volatile() };
                let new = ((head >> 32).wrapping_add(1) << 32) | next as u64;
                if self
                    .head
                    .compare_exchange_weak(head, new, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    return slot;
                }
                continue;
            }
            // Free-list empty: salvage one slot from a cached depot chain
            // before bumping fresh memory. This keeps depot slots live when
            // magazines are disabled mid-run (`--magazines off` after a
            // warm-up) — no slot is ever stranded in the depot.
            if let Some(slot) = self.pop_depot_chain() {
                // SAFETY: the chain was popped, so this thread owns it
                // exclusively; the remainder stays well-formed.
                unsafe {
                    if let Some(rest) = self.chain_next(slot) {
                        self.push_depot_chain_raw(rest);
                    }
                }
                return slot;
            }
            // Slow path: bump-allocate a fresh slot, growing if needed.
            let i = self.bump.fetch_add(1, Ordering::Relaxed);
            assert!(i < (MAX_CHUNKS * self.slots_per_chunk) as u64, "pool class exhausted");
            let i = i as u32;
            while (self.count.load(Ordering::Acquire) as u64 * self.slots_per_chunk as u64)
                <= i as u64
            {
                self.grow_to(i);
            }
            return self.slot_ptr(i);
        }
    }

    #[cold]
    fn grow_to(&self, index: u32) {
        let _g = self.grow.lock().unwrap();
        while (self.count.load(Ordering::Acquire) as u64 * self.slots_per_chunk as u64)
            <= index as u64
        {
            let chunk_idx = self.count.load(Ordering::Acquire) as usize;
            assert!(chunk_idx < MAX_CHUNKS, "pool class exhausted");
            let layout = Layout::from_size_align(CHUNK_BYTES, CHUNK_BYTES).unwrap();
            // SAFETY: non-zero, power-of-two layout.
            let base = unsafe { std::alloc::alloc_zeroed(layout) };
            assert!(!base.is_null(), "chunk allocation failed");
            // SAFETY: fresh chunk, header fits in HEADER_BYTES.
            unsafe {
                (base as *mut ChunkHeader).write(ChunkHeader {
                    start_index: (chunk_idx * self.slots_per_chunk) as u32,
                    slot_size: self.slot_size as u32,
                });
            }
            self.bases[chunk_idx].store(base, Ordering::Release);
            self.count.store(chunk_idx as u32 + 1, Ordering::Release);
        }
    }

    pub(crate) fn free(&self, slot: *mut u8) {
        let index = self.index_of(slot);
        loop {
            let head = self.head.load(Ordering::Acquire);
            // SAFETY: slot belongs to this class (checked by index_of).
            unsafe { self.link(slot).write_volatile(head as u32) };
            let new = ((head >> 32).wrapping_add(1) << 32) | index as u64;
            if self
                .head
                .compare_exchange_weak(head, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
        }
    }

    /// Link `slots` into a chain (offset-8 links, `NIL`-terminated) and
    /// push the whole chain onto the depot with one tagged CAS — the
    /// magazine-granularity exchange: ~cap slots per CAS instead of one.
    ///
    /// # Safety
    /// Every pointer must be a free slot of this class owned exclusively by
    /// the caller and must not be used afterwards.
    pub(crate) unsafe fn push_depot_chain(&self, slots: &[*mut u8]) {
        if slots.is_empty() {
            return;
        }
        for w in slots.windows(2) {
            self.link(w[0]).write_volatile(self.index_of(w[1]));
        }
        self.link(slots[slots.len() - 1]).write_volatile(NIL);
        self.push_depot_chain_raw(slots[0]);
    }

    /// Push an already-linked chain (offset-8 links terminated by `NIL`)
    /// onto the depot.
    ///
    /// # Safety
    /// `head` must start a well-formed free chain of this class owned
    /// exclusively by the caller.
    pub(crate) unsafe fn push_depot_chain_raw(&self, head: *mut u8) {
        let head_idx = self.index_of(head);
        loop {
            let cur = self.depot.load(Ordering::Acquire);
            self.chain_link(head).write_volatile(cur as u32);
            let new = ((cur >> 32).wrapping_add(1) << 32) | head_idx as u64;
            if self
                .depot
                .compare_exchange_weak(cur, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
        }
    }

    /// Pop one whole chain off the depot (one tagged CAS); returns the
    /// chain's head slot, or `None` when the depot is empty. The caller
    /// owns the entire chain afterwards and walks it with [`chain_next`].
    ///
    /// [`chain_next`]: SizeClass::chain_next
    pub(crate) fn pop_depot_chain(&self) -> Option<*mut u8> {
        loop {
            let cur = self.depot.load(Ordering::Acquire);
            let idx = cur as u32;
            if idx == NIL {
                return None;
            }
            let slot = self.slot_ptr(idx);
            // Possibly stale if another thread pops concurrently — the
            // tagged CAS detects that, same discipline as the free-list.
            // SAFETY: slot memory is never unmapped (type-stable).
            let next = unsafe { self.chain_link(slot).read_volatile() };
            let new = ((cur >> 32).wrapping_add(1) << 32) | next as u64;
            if self
                .depot
                .compare_exchange_weak(cur, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return Some(slot);
            }
        }
    }

    /// Next slot of a privately-owned chain (`None` at the chain's end).
    ///
    /// # Safety
    /// `slot` must belong to a chain this thread owns exclusively (popped
    /// from the depot or built locally but not yet pushed).
    pub(crate) unsafe fn chain_next(&self, slot: *mut u8) -> Option<*mut u8> {
        let next = self.link(slot).read_volatile();
        (next != NIL).then(|| self.slot_ptr(next))
    }

    fn index_of(&self, slot: *mut u8) -> u32 {
        let base = (slot as usize & !(CHUNK_BYTES - 1)) as *mut u8;
        // SAFETY: slot came from this pool, so the masked base is a chunk
        // header that is never unmapped.
        let header = unsafe { &*(base as *const ChunkHeader) };
        debug_assert_eq!(header.slot_size as usize, self.slot_size);
        let offset = slot as usize - base as usize - HEADER_BYTES;
        debug_assert_eq!(offset % self.slot_size, 0);
        header.start_index + (offset / self.slot_size) as u32
    }
}

impl Drop for SizeClass {
    fn drop(&mut self) {
        // Only private (test) instances are ever dropped — the global
        // classes live in a `'static` OnceLock, preserving type stability.
        // Dropping is sound only when no slot pointer outlives the instance.
        let layout = Layout::from_size_align(CHUNK_BYTES, CHUNK_BYTES).unwrap();
        for base in self.bases.iter() {
            let p = base.load(Ordering::Acquire);
            if !p.is_null() {
                // SAFETY: p was returned by alloc_zeroed with this layout.
                unsafe { std::alloc::dealloc(p, layout) };
            }
        }
    }
}

fn classes() -> &'static [SizeClass; NUM_CLASSES] {
    use std::sync::OnceLock;
    static CLASSES: OnceLock<Box<[SizeClass; NUM_CLASSES]>> = OnceLock::new();
    CLASSES.get_or_init(|| {
        let v: Vec<SizeClass> =
            (0..NUM_CLASSES).map(|i| SizeClass::new(MIN_CLASS << i)).collect();
        let boxed: Box<[SizeClass; NUM_CLASSES]> =
            v.into_boxed_slice().try_into().map_err(|_| ()).unwrap();
        boxed
    })
}

/// The global size class at index `ci` (magazine layer / diagnostics).
pub(crate) fn class(ci: usize) -> &'static SizeClass {
    &classes()[ci]
}

pub(crate) fn class_index(size: usize) -> usize {
    let size = size.max(MIN_CLASS);
    assert!(size <= MAX_CLASS, "pool allocation of {size} B exceeds the {MAX_CLASS} B max class");
    (usize::BITS - (size - 1).leading_zeros()) as usize - MIN_CLASS.trailing_zeros() as usize
}

/// Allocate a slot large enough for `layout`. Aborts on OOM.
///
/// Tries the calling thread's magazine first (non-atomic pop); falls back
/// to the class free-list / bump cursor when magazines are disabled or
/// empty and the depot has no cached chain.
pub fn alloc(layout: Layout) -> *mut u8 {
    assert!(layout.align() <= SLOT_ALIGN, "pool supports alignment up to {SLOT_ALIGN}");
    let ci = class_index(layout.size());
    match super::magazine::mag_alloc(ci) {
        Some(p) => p,
        None => classes()[ci].alloc(),
    }
}

/// Return a slot to its size class — into the calling thread's magazine
/// when enabled (non-atomic push), else onto the global free-list.
///
/// # Safety
/// `ptr` must come from [`alloc`] with a layout of the same size class and
/// must not be used afterwards. Byte offset 0 of the slot is preserved
/// (LFRC's refcount word); offsets 8..16 may be overwritten by free-list
/// and depot chain links.
pub unsafe fn free(ptr: *mut u8, layout: Layout) {
    let ci = class_index(layout.size());
    if !super::magazine::mag_free(ci, ptr) {
        classes()[ci].free(ptr);
    }
}

/// Number of bytes currently held by the pool (for diagnostics).
pub fn footprint_bytes() -> usize {
    classes().iter().map(|c| c.count.load(Ordering::Relaxed) as usize * CHUNK_BYTES).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn class_index_boundaries() {
        assert_eq!(class_index(1), 0);
        assert_eq!(class_index(64), 0);
        assert_eq!(class_index(65), 1);
        assert_eq!(class_index(128), 1);
        assert_eq!(class_index(129), 2);
        assert_eq!(class_index(MAX_CLASS), NUM_CLASSES - 1);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn oversized_allocation_panics() {
        class_index(MAX_CLASS + 1);
    }

    #[test]
    fn alloc_free_recycles_slots() {
        // Private instance: the LIFO assertion is exact — no other test
        // shares the class, and class-level alloc/free sit below the
        // magazine layer so no rack interposes.
        let c = SizeClass::new(4096);
        let a = c.alloc();
        c.free(a);
        let b = c.alloc();
        // LIFO free-list: the same slot comes back.
        assert_eq!(a, b);
        c.free(b);
    }

    #[test]
    fn distinct_live_allocations_do_not_alias() {
        // Global pool on purpose: with magazines on, this also checks the
        // rack never hands the same slot out twice.
        let layout = Layout::from_size_align(64, 8).unwrap();
        let ptrs: Vec<_> = (0..1000).map(|_| alloc(layout)).collect();
        let set: HashSet<_> = ptrs.iter().collect();
        assert_eq!(set.len(), ptrs.len());
        for p in ptrs {
            unsafe { free(p, layout) };
        }
    }

    #[test]
    fn word0_is_preserved_across_free() {
        let c = SizeClass::new(32768);
        let p = c.alloc();
        unsafe {
            (p as *mut u64).write(0xDEAD_BEEF_CAFE_F00D);
            c.free(p);
            // Slot is free but word 0 must be intact (LFRC contract).
            assert_eq!((p as *mut u64).read(), 0xDEAD_BEEF_CAFE_F00D);
        }
        let q = c.alloc();
        assert_eq!(p, q);
        c.free(q);
    }

    #[test]
    fn depot_chains_round_trip() {
        let c = SizeClass::new(64);
        let slots: Vec<_> = (0..5).map(|_| c.alloc()).collect();
        // SAFETY: freshly allocated, exclusively ours.
        unsafe { c.push_depot_chain(&slots) };
        let head = c.pop_depot_chain().expect("depot has the chain");
        assert_eq!(head, slots[0]);
        let mut got = vec![head];
        let mut cur = head;
        while let Some(n) = unsafe { c.chain_next(cur) } {
            got.push(n);
            cur = n;
        }
        assert_eq!(got, slots, "chain preserves order and membership");
        assert!(c.pop_depot_chain().is_none(), "depot drained");
        for p in got {
            c.free(p);
        }
    }

    #[test]
    fn depot_chains_preserve_word0() {
        let c = SizeClass::new(128);
        let slots: Vec<_> = (0..3).map(|_| c.alloc()).collect();
        for (i, &p) in slots.iter().enumerate() {
            unsafe { (p as *mut u64).write(0xA110C_000 + i as u64) };
        }
        // SAFETY: freshly allocated, exclusively ours.
        unsafe { c.push_depot_chain(&slots) };
        for (i, &p) in slots.iter().enumerate() {
            // Chain links live at offsets 8..16; word 0 is untouched.
            unsafe { assert_eq!((p as *mut u64).read(), 0xA110C_000 + i as u64) };
        }
        while let Some(head) = c.pop_depot_chain() {
            let mut cur = Some(head);
            while let Some(p) = cur {
                cur = unsafe { c.chain_next(p) };
                c.free(p);
            }
        }
    }

    #[test]
    fn legacy_alloc_salvages_depot_chains() {
        let c = SizeClass::new(256);
        let slots: Vec<_> = (0..3).map(|_| c.alloc()).collect();
        // SAFETY: freshly allocated, exclusively ours.
        unsafe { c.push_depot_chain(&slots) };
        let bump = c.bump.load(Ordering::Relaxed);
        // Free-list is empty, so alloc must split the depot chain (take
        // its head, re-push the remainder) instead of bumping fresh memory.
        assert_eq!(c.alloc(), slots[0]);
        assert_eq!(c.alloc(), slots[1]);
        assert_eq!(c.alloc(), slots[2]);
        assert_eq!(c.bump.load(Ordering::Relaxed), bump, "no fresh memory while depot non-empty");
        for p in slots {
            c.free(p);
        }
    }

    #[test]
    fn concurrent_alloc_free_stress() {
        let layout = Layout::from_size_align(96, 8).unwrap();
        let threads: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut held = Vec::new();
                    for i in 0..2000 {
                        held.push(alloc(layout));
                        if i % 3 == 0 {
                            if let Some(p) = held.pop() {
                                unsafe { free(p, layout) };
                            }
                        }
                        if i % 64 == 0 {
                            std::thread::yield_now();
                        }
                    }
                    // Write to every held slot to catch aliasing between
                    // concurrently-live allocations.
                    for (i, &p) in held.iter().enumerate() {
                        unsafe { (p as *mut u64).write(i as u64) };
                    }
                    for (i, &p) in held.iter().enumerate() {
                        unsafe { assert_eq!((p as *mut u64).read(), i as u64) };
                    }
                    for p in held {
                        unsafe { free(p, layout) };
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn concurrent_depot_exchange_stress() {
        // Producer/consumer chains racing on one private depot: every slot
        // pushed must come back exactly once.
        let c = std::sync::Arc::new(SizeClass::new(64));
        let total = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let producers: Vec<_> = (0..2)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        let slots: Vec<_> = (0..8).map(|_| c.alloc()).collect();
                        // SAFETY: freshly allocated, exclusively ours.
                        unsafe { c.push_depot_chain(&slots) };
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let c = c.clone();
                let total = total.clone();
                std::thread::spawn(move || {
                    let mut seen = HashSet::new();
                    let mut idle = 0;
                    while idle < 1000 {
                        match c.pop_depot_chain() {
                            Some(head) => {
                                idle = 0;
                                let mut cur = Some(head);
                                while let Some(p) = cur {
                                    // SAFETY: popped chain is exclusively ours.
                                    cur = unsafe { c.chain_next(p) };
                                    assert!(seen.insert(p as usize), "slot delivered twice");
                                }
                            }
                            None => {
                                idle += 1;
                                std::thread::yield_now();
                            }
                        }
                    }
                    total.fetch_add(seen.len(), std::sync::atomic::Ordering::Relaxed);
                })
            })
            .collect();
        for t in producers {
            t.join().unwrap();
        }
        for t in consumers {
            t.join().unwrap();
        }
        // Drain whatever the consumers' idle cutoff left behind.
        let mut rest = 0;
        while let Some(head) = c.pop_depot_chain() {
            let mut cur = Some(head);
            while let Some(p) = cur {
                cur = unsafe { c.chain_next(p) };
                rest += 1;
            }
        }
        assert_eq!(
            total.load(std::sync::atomic::Ordering::Relaxed) + rest,
            2 * 100 * 8,
            "every pushed slot came back exactly once"
        );
    }
}

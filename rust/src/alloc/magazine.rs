//! Per-thread **magazine** (tcache) layer over the slot pool — ROADMAP
//! item 4: close the reclamation→allocation loop so the paper's "reclaims
//! earlier" property becomes an allocation-throughput win instead of a
//! pile-up on one global Treiber head per size class.
//!
//! Layering (see also `DESIGN.md` §7):
//!
//! ```text
//!   Owned::new / reclaim_one
//!        │  alloc_raw / free_raw      (policy + efficiency counters)
//!        ▼
//!   pool::alloc / pool::free          (size-class routing)
//!        │
//!        ├─► magazine rack (this module) — non-atomic Vec push/pop in TLS
//!        │        │  full/empty exchange: one tagged CAS per ~cap slots
//!        │        ▼
//!        │   per-class depot — Treiber stack of slot *chains*
//!        ▼
//!   SizeClass free-list / bump        (slot-granularity fallback)
//! ```
//!
//! Each thread keeps a **rack**: one loaded/previous magazine pair per size
//! class (Bonwick's two-magazine scheme — swapping instead of spilling makes
//! the hot path immune to alloc/free phase flapping at a magazine boundary).
//! The steady-state retire→reuse cycle — `reclaim_one` frees a node and the
//! next `Owned::new` takes it straight back — touches no shared cache line:
//! both ends are a plain `Vec` push/pop on the calling thread's rack.
//!
//! Cross-thread flow (one thread reclaims what another allocates, the E16
//! coordinator shape) moves at magazine granularity: a full magazine is
//! linked into one chain and pushed to the class depot with a single tagged
//! CAS; a refill pops one chain the same way — 1/cap of the CAS traffic the
//! raw free-list would see.
//!
//! **Type-stability / LFRC contract**: a cached slot's word 0 is never
//! written (rack magazines store slot pointers in side `Vec`s; depot chain
//! links live at slot offsets 8..16, the same scratch region as the global
//! free-list link), so a stale Valois-style reader can still inspect the
//! refcount word of a slot parked in any magazine or depot chain.
//!
//! **Placement**: magazines are *thread*-local rather than owned by a
//! reclamation `LocalHandle`. `Owned::new` is deliberately
//! domain-independent and slots are type-stable process-wide, so
//! cross-domain reuse is sound — domains matter at retire time, not at
//! allocation. Handle teardown still participates: dropping or evicting a
//! `LocalHandle` calls [`flush_magazines`] so a thread that stops using a
//! domain strands no slots (thread exit flushes too, via the rack's `Drop`).
//!
//! `Policy::System` never reaches this module (the policy check happens in
//! `alloc_raw`/`free_raw` above the pool), and LFRC's force-pool traffic is
//! served like any other pool traffic. A capacity of 0 disables the layer
//! (`--magazines off`), leaving only one relaxed atomic load on each path.

use super::pool::{self, NUM_CLASSES};
use crate::util::cache_pad::CachePadded;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Default magazine capacity (slots per magazine, per class): one depot CAS
/// amortizes ~64 slot hand-offs, the batch size the tentpole targets.
pub const DEFAULT_MAGAZINE_CAP: usize = 64;

/// Global capacity knob (0 = magazines off). Benchmarks toggle this per
/// trial (`--magazines on|off|<cap>`); racks lazily re-shape on next use.
static CAP: AtomicUsize = AtomicUsize::new(DEFAULT_MAGAZINE_CAP);

/// Set the per-class magazine capacity; `0` disables the layer. Takes
/// effect on each thread's next pool operation (existing rack contents are
/// flushed to the depot on the re-shape, so no slot is stranded).
pub fn set_magazine_cap(cap: usize) {
    CAP.store(cap, Ordering::Relaxed);
}

/// Current magazine capacity (0 = disabled).
pub fn magazine_cap() -> usize {
    CAP.load(Ordering::Relaxed)
}

// Process-wide, monotonic event counters (relaxed; diagnostics only).
static ALLOC_HITS: CachePadded<AtomicU64> = CachePadded::new(AtomicU64::new(0));
static ALLOC_MISSES: CachePadded<AtomicU64> = CachePadded::new(AtomicU64::new(0));
static FREE_HITS: CachePadded<AtomicU64> = CachePadded::new(AtomicU64::new(0));
static DEPOT_FLUSHES: CachePadded<AtomicU64> = CachePadded::new(AtomicU64::new(0));
static DEPOT_REFILLS: CachePadded<AtomicU64> = CachePadded::new(AtomicU64::new(0));

/// Snapshot of the magazine event counters (monotonic since process start).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct MagazineStats {
    /// Pool allocs served from the calling thread's rack (incl. after a
    /// loaded↔prev swap or a depot refill) — the non-atomic fast path.
    pub alloc_hits: u64,
    /// Pool allocs that fell through to the class free-list / bump cursor.
    pub alloc_misses: u64,
    /// Pool frees absorbed by the calling thread's rack.
    pub free_hits: u64,
    /// Full magazines pushed to a depot as one chain (one CAS per ~cap
    /// slots; includes handle-drop / thread-exit flushes).
    pub depot_flushes: u64,
    /// Chains popped from a depot to refill an empty rack.
    pub depot_refills: u64,
}

impl MagazineStats {
    /// Fraction of magazine-eligible allocs served without touching a
    /// shared cache line.
    pub fn hit_rate(&self) -> f64 {
        let total = self.alloc_hits + self.alloc_misses;
        if total == 0 {
            0.0
        } else {
            self.alloc_hits as f64 / total as f64
        }
    }
}

/// Read the magazine counters.
pub fn magazine_stats() -> MagazineStats {
    MagazineStats {
        alloc_hits: ALLOC_HITS.load(Ordering::Relaxed),
        alloc_misses: ALLOC_MISSES.load(Ordering::Relaxed),
        free_hits: FREE_HITS.load(Ordering::Relaxed),
        depot_flushes: DEPOT_FLUSHES.load(Ordering::Relaxed),
        depot_refills: DEPOT_REFILLS.load(Ordering::Relaxed),
    }
}

/// One size class's magazine pair (Bonwick: `loaded` serves the hot path,
/// `prev` buffers one phase change before any depot traffic).
struct ClassMags {
    loaded: Vec<*mut u8>,
    prev: Vec<*mut u8>,
}

/// A thread's full set of magazines, one pair per size class.
struct Rack {
    cap: usize,
    mags: [ClassMags; NUM_CLASSES],
}

impl Rack {
    fn new(cap: usize) -> Self {
        Rack {
            cap,
            mags: std::array::from_fn(|_| ClassMags {
                loaded: Vec::with_capacity(cap),
                prev: Vec::with_capacity(cap),
            }),
        }
    }

    fn alloc(&mut self, ci: usize) -> Option<*mut u8> {
        let m = &mut self.mags[ci];
        if let Some(p) = m.loaded.pop() {
            ALLOC_HITS.fetch_add(1, Ordering::Relaxed);
            crate::trace::event!("mag.hit", ci);
            return Some(p);
        }
        if !m.prev.is_empty() {
            std::mem::swap(&mut m.loaded, &mut m.prev);
            ALLOC_HITS.fetch_add(1, Ordering::Relaxed);
            crate::trace::event!("mag.hit", ci);
            return m.loaded.pop();
        }
        // Rack empty: refill one whole chain from the class depot.
        let class = pool::class(ci);
        if let Some(head) = class.pop_depot_chain() {
            DEPOT_REFILLS.fetch_add(1, Ordering::Relaxed);
            let mut cur = Some(head);
            while let Some(p) = cur {
                if m.loaded.len() == self.cap {
                    // Chain longer than the current cap (cap was lowered
                    // mid-run): park the remainder back in the depot —
                    // links from p onward are still intact.
                    // SAFETY: popped chain is exclusively ours.
                    unsafe { class.push_depot_chain_raw(p) };
                    break;
                }
                // SAFETY: popped chain is exclusively ours.
                let next = unsafe { class.chain_next(p) };
                m.loaded.push(p);
                cur = next;
            }
            ALLOC_HITS.fetch_add(1, Ordering::Relaxed);
            crate::trace::event!("mag.hit", ci);
            return m.loaded.pop();
        }
        ALLOC_MISSES.fetch_add(1, Ordering::Relaxed);
        crate::trace::event!("mag.miss", ci);
        None
    }

    fn free(&mut self, ci: usize, p: *mut u8) {
        let m = &mut self.mags[ci];
        if m.loaded.len() < self.cap {
            m.loaded.push(p);
        } else if m.prev.is_empty() {
            std::mem::swap(&mut m.loaded, &mut m.prev);
            m.loaded.push(p);
        } else {
            // Both magazines full: return `prev` to the depot as one chain
            // (a single tagged CAS for cap slots), rotate, keep going.
            let class = pool::class(ci);
            // SAFETY: rack slots are free and exclusively this thread's.
            unsafe { class.push_depot_chain(&m.prev) };
            DEPOT_FLUSHES.fetch_add(1, Ordering::Relaxed);
            m.prev.clear();
            std::mem::swap(&mut m.loaded, &mut m.prev);
            m.loaded.push(p);
        }
        FREE_HITS.fetch_add(1, Ordering::Relaxed);
    }

    /// Push every cached slot to the depots and empty the rack.
    fn flush_all(&mut self) {
        for (ci, m) in self.mags.iter_mut().enumerate() {
            let class = pool::class(ci);
            for v in [&mut m.loaded, &mut m.prev] {
                if !v.is_empty() {
                    // SAFETY: rack slots are free and exclusively ours.
                    unsafe { class.push_depot_chain(v) };
                    DEPOT_FLUSHES.fetch_add(1, Ordering::Relaxed);
                    v.clear();
                }
            }
        }
    }

    fn cached(&self) -> usize {
        self.mags.iter().map(|m| m.loaded.len() + m.prev.len()).sum()
    }
}

impl Drop for Rack {
    // Thread exit: hand every cached slot back via the depots.
    fn drop(&mut self) {
        self.flush_all();
    }
}

thread_local! {
    static RACK: RefCell<Option<Rack>> = const { RefCell::new(None) };
}

/// Get-or-reshape the rack for the current capacity. A cap change flushes
/// the old rack first so no slot is stranded across the re-shape.
fn ensure(slot: &mut Option<Rack>, cap: usize) -> &mut Rack {
    if slot.as_ref().map_or(true, |r| r.cap != cap) {
        if let Some(r) = slot.as_mut() {
            r.flush_all();
        }
        *slot = Some(Rack::new(cap));
    }
    slot.as_mut().unwrap()
}

/// Magazine-path allocation for class `ci`; `None` falls through to the
/// class free-list (magazines disabled, TLS tearing down, or rack + depot
/// both empty).
#[inline]
pub(super) fn mag_alloc(ci: usize) -> Option<*mut u8> {
    let cap = magazine_cap();
    if cap == 0 {
        return None;
    }
    RACK.try_with(|cell| {
        // try_borrow guards against re-entrancy through TLS destructors
        // (a handle cached in TLS may reclaim nodes while the rack is
        // being dropped); the legacy path is always a correct fallback.
        let mut r = cell.try_borrow_mut().ok()?;
        ensure(&mut *r, cap).alloc(ci)
    })
    .ok()
    .flatten()
}

/// Magazine-path free for class `ci`; `false` means the caller must use
/// the class free-list.
#[inline]
pub(super) fn mag_free(ci: usize, p: *mut u8) -> bool {
    let cap = magazine_cap();
    if cap == 0 {
        return false;
    }
    RACK.try_with(|cell| {
        let Ok(mut r) = cell.try_borrow_mut() else { return false };
        ensure(&mut *r, cap).free(ci, p);
        true
    })
    .unwrap_or(false)
}

/// Flush the calling thread's rack to the depots. Called on reclamation
/// handle drop/eviction (and implicitly at thread exit); also the test
/// hook for the "no stranded slots" invariant.
pub fn flush_magazines() {
    let _ = RACK.try_with(|cell| {
        if let Ok(mut r) = cell.try_borrow_mut() {
            if let Some(rack) = r.as_mut() {
                rack.flush_all();
            }
        }
    });
}

/// Number of slots currently cached in *this thread's* rack (diagnostics /
/// tests; other threads' racks are invisible by design).
pub fn thread_cached_slots() -> usize {
    RACK.try_with(|cell| cell.try_borrow().map_or(0, |r| r.as_ref().map_or(0, Rack::cached)))
        .unwrap_or(0)
}

/// Serialize lib tests that toggle the process-global capacity knob (the
/// magazine unit tests below and the `micro_alloc` figure smoke test).
#[cfg(test)]
pub(crate) fn test_cap_lock() -> std::sync::MutexGuard<'static, ()> {
    static CAP_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    CAP_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::alloc::Layout;

    // Unit tests share the process-global CAP with the rest of the lib test
    // binary, so: (a) each test uses a size class no other lib test touches,
    // (b) assertions on the global counters are `>=` deltas, and (c) tests
    // that change CAP restore the default and serialize on a lock.
    fn with_cap<T>(cap: usize, f: impl FnOnce() -> T) -> T {
        let _g = test_cap_lock();
        set_magazine_cap(cap);
        let out = f();
        flush_magazines();
        set_magazine_cap(DEFAULT_MAGAZINE_CAP);
        out
    }

    #[test]
    fn rack_round_trip_is_lifo_and_counted() {
        with_cap(8, || {
            let layout = Layout::from_size_align(5000, 8).unwrap(); // class 8192
            let before = magazine_stats();
            let a = pool::alloc(layout);
            unsafe { pool::free(a, layout) };
            let b = pool::alloc(layout);
            assert_eq!(a, b, "retire→reuse loop closes within the rack");
            unsafe { pool::free(b, layout) };
            let after = magazine_stats();
            assert!(after.free_hits >= before.free_hits + 2);
            assert!(after.alloc_hits >= before.alloc_hits + 1);
        });
    }

    #[test]
    fn cap_zero_bypasses_rack() {
        with_cap(0, || {
            let layout = Layout::from_size_align(40_000, 8).unwrap(); // class 65536
            let before = thread_cached_slots();
            let a = pool::alloc(layout);
            unsafe { pool::free(a, layout) };
            assert_eq!(thread_cached_slots(), before, "disabled layer caches nothing");
            // Legacy LIFO still applies (global free-list).
            let b = pool::alloc(layout);
            assert_eq!(a, b);
            unsafe { pool::free(b, layout) };
        });
    }

    #[test]
    fn flush_leaves_zero_cached_and_refill_recovers() {
        with_cap(4, || {
            let layout = Layout::from_size_align(12_000, 8).unwrap(); // class 16384
            let ptrs: Vec<_> = (0..8).map(|_| pool::alloc(layout)).collect();
            for &p in &ptrs {
                unsafe { pool::free(p, layout) };
            }
            assert!(thread_cached_slots() > 0);
            let before = magazine_stats();
            flush_magazines();
            assert_eq!(thread_cached_slots(), 0, "flush strands nothing");
            // Refill pulls the flushed chains back out of the depot.
            let again: Vec<_> = (0..8).map(|_| pool::alloc(layout)).collect();
            let after = magazine_stats();
            assert!(after.depot_flushes > before.depot_flushes);
            assert!(after.depot_refills > before.depot_refills);
            let set: std::collections::HashSet<_> = ptrs.iter().collect();
            assert!(again.iter().all(|p| set.contains(p)), "same slots return via depot");
            for p in again {
                unsafe { pool::free(p, layout) };
            }
        });
    }

    #[test]
    fn cap_change_reshapes_without_stranding() {
        with_cap(4, || {
            let layout = Layout::from_size_align(2100, 8).unwrap(); // class 4096
            let a = pool::alloc(layout);
            unsafe { pool::free(a, layout) };
            assert!(thread_cached_slots() >= 1);
            // Lower the cap: next op flushes + rebuilds the rack.
            set_magazine_cap(2);
            let b = pool::alloc(layout);
            // The slot survived the re-shape (via the depot or the rack).
            assert_eq!(a, b);
            unsafe { pool::free(b, layout) };
        });
    }
}

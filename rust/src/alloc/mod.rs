//! Node allocation with global allocation/reclamation counters and a
//! runtime-selectable policy, reproducing the paper's allocator axis
//! (jemalloc vs libc, Appendix A.3) without rebuilding the binary:
//!
//! * [`Policy::Pool`] — a lock-free, size-classed, **type-stable** pool
//!   ([`pool`]): memory is never returned to the OS, free slots are recycled
//!   through tagged free-lists. This mimics jemalloc's thread-cached
//!   behaviour and, crucially, provides the type-stable memory that LFRC
//!   (Valois-style reference counting) *requires* — a stale reader may touch
//!   the refcount word of a recycled slot, which is only sound if the slot
//!   is never unmapped and every slot keeps a refcount-compatible first word.
//! * [`Policy::System`] — plain `std::alloc` (libc malloc).
//!
//! LFRC ignores the policy and always uses the pool (the paper makes the
//! same point: LFRC "is not a general reclamation scheme, since the
//! reclaimed nodes cannot be returned to the memory manager, but are stored
//! in a global free-list").
//!
//! The pool itself is fronted by a per-thread **magazine** layer
//! ([`magazine`]) that closes the retire→reuse loop without touching the
//! global free-list in steady state; `Policy::System` bypasses it entirely
//! (the policy check happens here, above the pool), and LFRC's forced pool
//! traffic flows through it like any other pool traffic.
//!
//! The counters are the measurement substrate for the paper's *reclamation
//! efficiency* analysis (§4.4): `unreclaimed() = allocated − reclaimed` is
//! exactly the quantity plotted in Figures 6 and 8–11.

pub mod magazine;
pub mod pool;

pub use magazine::{
    flush_magazines, magazine_cap, magazine_stats, set_magazine_cap, thread_cached_slots,
    MagazineStats, DEFAULT_MAGAZINE_CAP,
};

use crate::util::cache_pad::CachePadded;
use std::alloc::Layout;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

/// Allocation policy for reclaimable nodes.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Size-classed, type-stable, lock-free pool (jemalloc-like; default).
    Pool,
    /// `std::alloc` (libc malloc) — the paper's Appendix A.3 configuration.
    System,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "pool" | "jemalloc" => Some(Policy::Pool),
            "system" | "libc" => Some(Policy::System),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Pool => "pool",
            Policy::System => "system",
        }
    }
}

static POLICY: AtomicU8 = AtomicU8::new(0); // 0 = Pool, 1 = System

/// Select the global allocation policy (benchmark harness, trial setup).
pub fn set_policy(p: Policy) {
    POLICY.store(p as u8, Ordering::Relaxed);
}

/// Current global allocation policy.
pub fn policy() -> Policy {
    if POLICY.load(Ordering::Relaxed) == 0 {
        Policy::Pool
    } else {
        Policy::System
    }
}

static ALLOCATED: CachePadded<AtomicU64> = CachePadded::new(AtomicU64::new(0));
static RECLAIMED: CachePadded<AtomicU64> = CachePadded::new(AtomicU64::new(0));

/// Total nodes ever allocated (monotonic).
pub fn allocated() -> u64 {
    ALLOCATED.load(Ordering::Relaxed)
}

/// Total nodes ever reclaimed (monotonic).
pub fn reclaimed() -> u64 {
    RECLAIMED.load(Ordering::Relaxed)
}

/// Currently unreclaimed nodes — the paper's reclamation-efficiency metric.
pub fn unreclaimed() -> u64 {
    allocated().saturating_sub(reclaimed())
}

/// Snapshot of the counters, for per-trial deltas.
#[derive(Copy, Clone, Debug, Default)]
pub struct CounterSnapshot {
    pub allocated: u64,
    pub reclaimed: u64,
}

/// Take a counter snapshot.
pub fn snapshot() -> CounterSnapshot {
    CounterSnapshot { allocated: allocated(), reclaimed: reclaimed() }
}

/// Allocate one node of `layout` under the given policy. Never returns
/// null. Returns the pointer **and the provenance actually used**: the
/// policy is sampled exactly once, so a concurrent [`set_policy`] toggle
/// (the benchmark ablation knob) can never make a node's recorded pool
/// flag disagree with where its memory really came from — the caller must
/// tag the node with the returned provenance, not re-sample the policy.
///
/// `force_pool` is set by LFRC (type-stable memory requirement).
pub fn alloc_raw(layout: Layout, force_pool: bool) -> (*mut u8, bool) {
    ALLOCATED.fetch_add(1, Ordering::Relaxed);
    let pooled = force_pool || policy() == Policy::Pool;
    let p = if pooled {
        pool::alloc(layout)
    } else {
        // SAFETY: layout has non-zero size (nodes always carry a header).
        let p = unsafe { std::alloc::alloc(layout) };
        assert!(!p.is_null(), "system allocator returned null");
        p
    };
    (p, pooled)
}

/// Return a node's memory.
///
/// # Safety
/// `ptr` must come from [`alloc_raw`] with the same `layout` and
/// `from_pool` flag, and must not be used afterwards.
pub unsafe fn free_raw(ptr: *mut u8, layout: Layout, from_pool: bool) {
    RECLAIMED.fetch_add(1, Ordering::Relaxed);
    if from_pool {
        pool::free(ptr, layout);
    } else {
        std::alloc::dealloc(ptr, layout);
    }
}

/// Whether an allocation made *now* would come from the pool. Diagnostics
/// only — allocation sites must use the provenance [`alloc_raw`] returns
/// (sampling the policy twice is the TOCTOU this API shape prevents).
pub fn currently_pooled(force_pool: bool) -> bool {
    force_pool || policy() == Policy::Pool
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_move() {
        let before = snapshot();
        let layout = Layout::from_size_align(64, 8).unwrap();
        // Free with the provenance alloc_raw returned — never a second
        // policy sample (the TOCTOU the returned flag exists to prevent).
        let (p, pooled) = alloc_raw(layout, false);
        unsafe { free_raw(p, layout, pooled) };
        let after = snapshot();
        assert!(after.allocated >= before.allocated + 1);
        assert!(after.reclaimed >= before.reclaimed + 1);
    }

    #[test]
    fn policy_roundtrip() {
        assert_eq!(Policy::parse("pool"), Some(Policy::Pool));
        assert_eq!(Policy::parse("libc"), Some(Policy::System));
        assert_eq!(Policy::parse("bogus"), None);
        assert_eq!(Policy::Pool.name(), "pool");
    }
}

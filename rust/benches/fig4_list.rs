//! Figure 4 (13): List benchmark, 10 elements, 20% updates, thread sweep.
//! The paper omits LFRC here ("performs exceedingly poor"); pass
//! --schemes all to include it anyway.
use emr::bench_fw::figures::{fig_throughput, Workload};
use emr::bench_fw::BenchParams;
use emr::reclaim::SchemeId;
use emr::util::cli::Args;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    if args.get("schemes").is_none() {
        p.schemes.retain(|s| *s != SchemeId::Lfrc); // paper's Fig. 4 set
    }
    fig_throughput(&p, Workload::List);
}

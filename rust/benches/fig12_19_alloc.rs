//! Figures 12-19 (Appendix A.3): the same benchmarks under the system
//! (libc) allocator instead of the jemalloc-like pool. The paper's finding
//! — "the impact of the memory manager is equally big/small for all
//! schemes" — shows as both sweeps preserving the scheme ordering.
use emr::alloc::Policy;
use emr::bench_fw::figures::{fig_efficiency, fig_throughput, Workload};
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    let mut p = BenchParams::from_args(&Args::parse());
    for alloc in [Policy::Pool, Policy::System] {
        p.alloc = alloc;
        fig_throughput(&p, Workload::Queue);    // Fig 3 vs 12
        fig_throughput(&p, Workload::List);     // Fig 4 vs 13
        fig_efficiency(&p, Workload::Queue);    // Fig 8 vs 16
    }
}

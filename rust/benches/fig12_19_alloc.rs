//! Figures 12-19 (Appendix A.3): the same benchmarks under the system
//! (libc) allocator instead of the jemalloc-like pool. The paper's finding
//! — "the impact of the memory manager is equally big/small for all
//! schemes" — shows as both sweeps preserving the scheme ordering.
//!
//! Since E20 the pool pass is itself an ablation over the magazine layer
//! (`--magazines on|off|<cap>` picks the "on" capacity; both arms always
//! run), so one invocation yields three allocator configurations per
//! workload: pool+magazines, pool bare, and system. Results are printed
//! as tables *and* written as a machine-readable record to
//! `BENCH_fig12_19_alloc.json` (override with `--json PATH`) for the CI
//! artifact trail.
use emr::alloc::Policy;
use emr::bench_fw::figures::{efficiency_table, throughput_table, Workload};
use emr::bench_fw::report::{SeriesTable, SweepTable};
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;
use std::fmt::Write as _;

/// One (workload, alloc-config) throughput sweep flattened to JSON cells.
fn push_throughput_cells(
    out: &mut String,
    first: &mut bool,
    workload: &str,
    alloc: &str,
    magazines: usize,
    table: &SweepTable,
) {
    for (scheme, row) in &table.rows {
        for (&threads, &ns_per_op) in table.threads.iter().zip(row) {
            if !*first {
                out.push_str(",\n");
            }
            *first = false;
            let _ = write!(
                out,
                "    {{\"kind\": \"throughput\", \"workload\": \"{workload}\", \
                 \"alloc\": \"{alloc}\", \"magazines\": {magazines}, \
                 \"scheme\": \"{scheme}\", \"threads\": {threads}, \
                 \"ns_per_op\": {ns_per_op:.3}}}"
            );
        }
    }
}

/// One efficiency series summarised to (peak, end) unreclaimed nodes.
fn push_efficiency_cells(
    out: &mut String,
    first: &mut bool,
    workload: &str,
    alloc: &str,
    magazines: usize,
    table: &SeriesTable,
) {
    for (scheme, series) in &table.rows {
        let peak = series.iter().map(|&(_, v)| v).fold(0.0f64, f64::max);
        let end = series.last().map_or(0.0, |&(_, v)| v);
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        let _ = write!(
            out,
            "    {{\"kind\": \"efficiency\", \"workload\": \"{workload}\", \
             \"alloc\": \"{alloc}\", \"magazines\": {magazines}, \
             \"scheme\": \"{scheme}\", \"peak_unreclaimed\": {peak:.1}, \
             \"end_unreclaimed\": {end:.1}}}"
        );
    }
}

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    let on_cap = if p.magazine_cap == 0 {
        emr::alloc::DEFAULT_MAGAZINE_CAP
    } else {
        p.magazine_cap
    };
    // (policy, magazine cap) configurations: the pool arm is the magazine
    // ablation; System bypasses the pool entirely, so the cap is moot there.
    let configs = [
        (Policy::Pool, on_cap),
        (Policy::Pool, 0usize),
        (Policy::System, 0usize),
    ];

    let mut cells = String::new();
    let mut first = true;
    for (alloc, cap) in configs {
        p.alloc = alloc;
        p.magazine_cap = cap;
        let label = alloc.name();
        let queue = throughput_table(&p, Workload::Queue); // Fig 3 vs 12
        queue.print();
        push_throughput_cells(&mut cells, &mut first, "queue", label, cap, &queue);
        let list = throughput_table(&p, Workload::List); // Fig 4 vs 13
        list.print();
        push_throughput_cells(&mut cells, &mut first, "list", label, cap, &list);
        let eff = efficiency_table(&p, Workload::Queue); // Fig 8 vs 16
        eff.print();
        push_efficiency_cells(&mut cells, &mut first, "queue", label, cap, &eff);
    }
    // Restore the process default so nothing after us runs capless.
    emr::alloc::set_magazine_cap(emr::alloc::DEFAULT_MAGAZINE_CAP);

    let json = format!(
        "{{\n  \"bench\": \"fig12_19_alloc\",\n  \"magazine_cap\": {on_cap},\n  \
         \"cells\": [\n{cells}\n  ]\n}}\n"
    );
    let path = args.get_or("json", "BENCH_fig12_19_alloc.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

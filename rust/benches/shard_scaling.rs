//! E16: shard-scaling — Router throughput and reclamation robustness vs
//! shard count (1/2/4/8), domain-per-shard vs one-shared-domain, on the
//! coordinator's HashMap serving path with a skewed key stream — and vs
//! **engine-group count** (`--groups`, default 1,2,4): each group runs its
//! own batcher/engine thread, so this axis is the miss-compute parallelism
//! the single-batcher fleet never had. Runs on the synthetic backend, so
//! no PJRT artifacts are needed.
//!
//! Besides the printed tables (and `--csv PATH`), the sweep is written as
//! a machine-readable record to `BENCH_fig_shard_scaling.json` (override
//! with `--json PATH`) for the CI artifact trail.
//!
//! `--gate-groups RATIO` turns the run into the CI groups gate: at the
//! largest swept shard count, the highest group count must reach at least
//! RATIO × the `groups=1` throughput for every (scheme, domain-mode) pair,
//! or the process exits 1.
//!
//! ```bash
//! cargo bench --bench shard_scaling -- --schemes stamp,ebr,hp --secs 1
//! cargo bench --bench shard_scaling -- --shards 8 --groups 1,4 --gate-groups 1.5
//! ```
use emr::bench_fw::figures::{fig_shard_scaling, ShardCell};
use emr::bench_fw::BenchParams;
use emr::reclaim::SchemeId;
use emr::util::cli::Args;
use std::fmt::Write as _;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    if args.get("schemes").is_none() {
        // Default to the three families the sharding story contrasts:
        // stamp (the paper), one epoch scheme, hazard pointers.
        p.schemes = vec![SchemeId::Stamp, SchemeId::Ebr, SchemeId::Hp];
    }
    if args.get("groups").is_none() {
        // Default groups sweep: the old single-batcher fleet against the
        // grouped ones (combos with groups > shards are skipped).
        p.groups = vec![1, 2, 4];
    }
    let cells = fig_shard_scaling(&p);

    let mut body = String::new();
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            body.push_str(",\n");
        }
        let _ = write!(
            body,
            "    {{\"scheme\": \"{}\", \"mode\": \"{}\", \"shards\": {}, \
             \"groups\": {}, \"req_per_s\": {:.1}, \"hit_rate\": {:.4}, \
             \"batches\": {}, \"unreclaimed\": {}, \
             \"trace_p50_ns\": {}, \"trace_p99_ns\": {}, \"trace_p999_ns\": {}, \
             \"trace_pairs\": {}, \"per_group_batches\": {:?}}}",
            c.scheme,
            c.mode,
            c.shards,
            c.groups,
            c.ops_per_sec,
            c.hit_rate,
            c.batches,
            c.unreclaimed,
            c.trace_p50_ns,
            c.trace_p99_ns,
            c.trace_p999_ns,
            c.trace_pairs,
            c.group_batches,
        );
    }
    let json = format!(
        "{{\n  \"bench\": \"shard_scaling\",\n  \"cells\": [\n{body}\n  ]\n}}\n"
    );
    let path = args.get_or("json", "BENCH_fig_shard_scaling.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    if let Some(ratio) = args.get("gate-groups") {
        let ratio: f64 = ratio.parse().unwrap_or_else(|_| {
            eprintln!("--gate-groups wants a ratio, got {ratio:?}");
            std::process::exit(2);
        });
        if !groups_gate(&cells, ratio) {
            std::process::exit(1);
        }
    }
}

/// The groups-axis CI gate: at the largest swept shard count, the highest
/// group count must reach `ratio` × the single-batcher (`groups=1`)
/// throughput for every (scheme, domain-mode) pair seen in `cells`.
fn groups_gate(cells: &[ShardCell], ratio: f64) -> bool {
    let Some(max_shards) = cells.iter().map(|c| c.shards).max() else {
        eprintln!("groups gate: no cells measured");
        return false;
    };
    let at_max: Vec<&ShardCell> = cells.iter().filter(|c| c.shards == max_shards).collect();
    let mut ok = true;
    let mut compared = 0usize;
    for base in at_max.iter().filter(|c| c.groups == 1) {
        let Some(best) = at_max
            .iter()
            .filter(|c| c.scheme == base.scheme && c.mode == base.mode)
            .max_by_key(|c| c.groups)
        else {
            continue;
        };
        if best.groups == 1 {
            continue; // nothing to compare — sweep had no grouped cell
        }
        compared += 1;
        let speedup = best.ops_per_sec / base.ops_per_sec;
        let verdict = if speedup >= ratio { "ok" } else { "FAIL" };
        println!(
            "groups gate [{verdict}]: {} {} shards={max_shards}: \
             groups={} {:.0} req/s vs groups=1 {:.0} req/s — {speedup:.2}x \
             (need {ratio:.2}x)",
            base.scheme, base.mode, best.groups, best.ops_per_sec, base.ops_per_sec,
        );
        if speedup < ratio {
            ok = false;
        }
    }
    if compared == 0 {
        eprintln!(
            "groups gate: sweep had no groups>1 cell at shards={max_shards} \
             (pass --groups 1,4 and --shards up to at least 4)"
        );
        return false;
    }
    ok
}

//! E16: shard-scaling — Router throughput and reclamation robustness vs
//! shard count (1/2/4/8), domain-per-shard vs one-shared-domain, on the
//! coordinator's HashMap serving path with a skewed key stream. Runs on
//! the synthetic backend, so no PJRT artifacts are needed.
//!
//! ```bash
//! cargo bench --bench shard_scaling -- --schemes stamp,ebr,hp --secs 1
//! ```
use emr::bench_fw::figures::fig_shard_scaling;
use emr::bench_fw::BenchParams;
use emr::reclaim::SchemeId;
use emr::util::cli::Args;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    if args.get("schemes").is_none() {
        // Default to the three families the sharding story contrasts:
        // stamp (the paper), one epoch scheme, hazard pointers.
        p.schemes = vec![SchemeId::Stamp, SchemeId::Ebr, SchemeId::Hp];
    }
    fig_shard_scaling(&p);
}

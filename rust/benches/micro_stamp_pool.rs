//! E14: Stamp Pool push+remove cycle cost vs thread count (the paper's
//! "expected average runtime of the operations is constant" claim).
use emr::bench_fw::figures::micro_stamp_pool;
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    micro_stamp_pool(&BenchParams::from_args(&Args::parse()));
}

//! Figures 6 and 8-11 (16-19): reclamation efficiency — unreclaimed nodes
//! over time for Queue, List (20% and 80%) and HashMap.
use emr::bench_fw::figures::{fig_efficiency, Workload};
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    fig_efficiency(&p, Workload::Queue);        // Fig 8
    p.workload_pct = 20;
    fig_efficiency(&p, Workload::List);         // Fig 9
    p.workload_pct = 80;
    fig_efficiency(&p, Workload::List);         // Fig 10
    fig_efficiency(&p, Workload::HashMap);      // Figs 6 & 11
}

//! Figure 5 (14): HashMap benchmark, thread sweep. The paper excludes QSR
//! from this plot ("scales very poorly"); pass --schemes all to include it.
use emr::bench_fw::figures::{fig_throughput, Workload};
use emr::bench_fw::BenchParams;
use emr::reclaim::SchemeId;
use emr::util::cli::Args;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    if args.get("schemes").is_none() {
        p.schemes.retain(|s| *s != SchemeId::Qsr); // paper's Fig. 5 set
    }
    fig_throughput(&p, Workload::HashMap);
}

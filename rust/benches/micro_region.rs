//! E13: cost of a critical-region enter+exit cycle per scheme — the
//! operations Propositions 2/3 claim are (amortized) constant-time.
use emr::bench_fw::figures::micro_region;
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    micro_region(&BenchParams::from_args(&Args::parse()));
}

//! E13: cost of a critical-region enter+exit cycle per scheme — the
//! operations Propositions 2/3 claim are (amortized) constant-time.
//!
//! Plain run prints the thread sweep (the figure). Two extra modes drive
//! the CI regression gate (EXPERIMENTS.md §E13):
//!
//! ```bash
//! # gate against the recorded baseline (exit 1 on >20% regression or
//! # measurable facade-over-raw guard overhead):
//! cargo bench --bench micro_region -- --gate ci/micro_region_baseline.csv
//! # (re)record the baseline on this machine:
//! cargo bench --bench micro_region -- --record ci/micro_region_baseline.csv
//! # flight-recorder overhead gate (exit 1 when trace-on exceeds 1.05x
//! # trace-off on the region-cycle hot path):
//! cargo bench --bench micro_region -- --trace-gate
//! ```
use emr::bench_fw::figures::{micro_region, micro_region_gate, trace_overhead_gate};
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    let args = Args::parse();
    let params = BenchParams::from_args(&args);
    if args.flag("trace-gate") {
        if !trace_overhead_gate(&params) {
            std::process::exit(1);
        }
        return;
    }
    match (args.get("record"), args.get("gate")) {
        (Some(path), _) => {
            if !micro_region_gate(&params, None, Some(path)) {
                std::process::exit(1);
            }
        }
        (None, Some(path)) => {
            if !micro_region_gate(&params, Some(path), None) {
                std::process::exit(1);
            }
        }
        (None, None) => micro_region(&params),
    }
}

//! Figure 3 (and 12 with --alloc system): Queue benchmark, thread sweep.
use emr::bench_fw::figures::{fig_throughput, Workload};
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    let p = BenchParams::from_args(&Args::parse());
    fig_throughput(&p, Workload::Queue);
}

//! E19: stall robustness — `Domain::unreclaimed()` growth per scheme while
//! an injected executor task holds a guard across a never-woken future
//! (ROADMAP item 3's async adversary). Each scheme runs a baseline cell
//! (no adversary) and a stalled cell; the guard-across-await lint must
//! fire in every stalled cell. Expected shapes: epoch schemes strand
//! ~everything retired, Stamp-it everything younger than the stalled
//! stamp, HP a bounded hazard set, Hyaline only batches born before the
//! stalled announce.
//!
//! Besides the printed table (and `--csv PATH`), the sweep is written to
//! `BENCH_fig_stall_robustness.json` (override with `--json PATH`).
//! `--gate-hyaline-peak N` exits non-zero unless Hyaline's stalled-mode
//! peak stays under `N` and the lint fired — the CI `stall-robustness`
//! gate.
//!
//! ```bash
//! cargo bench --bench stall_robustness -- --secs 0.5
//! cargo bench --bench stall_robustness -- --secs 0.2 --gate-hyaline-peak 10000
//! ```
use emr::bench_fw::figures::{fig_stall_robustness, stall_gate};
use emr::bench_fw::BenchParams;
use emr::reclaim::SchemeId;
use emr::util::cli::Args;
use std::fmt::Write as _;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    if args.get("schemes").is_none() {
        // The robustness comparison set: the new robust scheme against the
        // paper's scheme, one epoch representative and hazard pointers.
        p.schemes = vec![SchemeId::Hyaline, SchemeId::Stamp, SchemeId::Ebr, SchemeId::Hp];
    }
    let cells = fig_stall_robustness(&p);

    let mut body = String::new();
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            body.push_str(",\n");
        }
        let series =
            c.samples.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ");
        let _ = write!(
            body,
            "    {{\"scheme\": \"{}\", \"mode\": \"{}\", \"churn_threads\": {}, \
             \"retired\": {}, \"peak_unreclaimed\": {}, \"end_unreclaimed\": {}, \
             \"lint_violations\": {}, \"series\": [{series}]}}",
            c.scheme,
            c.mode,
            c.churn_threads,
            c.retired,
            c.peak_unreclaimed,
            c.end_unreclaimed,
            c.lint_violations,
        );
    }
    let json = format!(
        "{{\n  \"bench\": \"stall_robustness\",\n  \"secs\": {:.3},\n  \
         \"cells\": [\n{body}\n  ]\n}}\n",
        p.secs
    );
    let path = args.get_or("json", "BENCH_fig_stall_robustness.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    if let Some(bound) = args.get("gate-hyaline-peak") {
        let bound: u64 = bound.parse().unwrap_or_else(|_| {
            eprintln!("--gate-hyaline-peak expects an integer, got {bound:?}");
            std::process::exit(2);
        });
        if !stall_gate(&cells, bound) {
            std::process::exit(1);
        }
        println!("stall-robustness gate passed (Hyaline peak ≤ {bound}, lint fired)");
    }
}

//! E20: cost of a retire→reuse node cycle (Owned::new + retire_owned)
//! with the magazine layer on vs off — the allocation-side win the
//! magazines exist for.
//!
//! Plain run prints the per-scheme on/off thread sweep (the figure). Two
//! extra modes drive the CI regression gate (EXPERIMENTS.md §E20):
//!
//! ```bash
//! # gate: magazines-on must beat magazines-off under churn, and the
//! # recorded per-scheme baseline must hold (exit 1 on regression):
//! cargo bench --bench micro_alloc -- --gate ci/micro_alloc_baseline.csv
//! # (re)record the baseline on this machine:
//! cargo bench --bench micro_alloc -- --record ci/micro_alloc_baseline.csv
//! ```
use emr::bench_fw::figures::{micro_alloc, micro_alloc_gate};
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    let args = Args::parse();
    let params = BenchParams::from_args(&args);
    match (args.get("record"), args.get("gate")) {
        (Some(path), _) => {
            if !micro_alloc_gate(&params, None, Some(path)) {
                std::process::exit(1);
            }
        }
        (None, Some(path)) => {
            if !micro_alloc_gate(&params, Some(path), None) {
                std::process::exit(1);
            }
        }
        (None, None) => micro_alloc(&params),
    }
}

//! A1-A3: design-choice ablations — Stamp-it's global-retire threshold
//! (paper: 20), HPR's scan-threshold base (paper: 100), and the epoch
//! advance / DEBRA check periods (paper: 100 / 20).
use emr::bench_fw::figures::{abl_epoch_period, abl_hp_threshold, abl_threshold};
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    let p = BenchParams::from_args(&Args::parse());
    abl_threshold(&p);
    abl_hp_threshold(&p);
    abl_epoch_period(&p);
}

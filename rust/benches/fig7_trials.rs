//! Figure 7 (15): development of HashMap runtime over trials (warm-up).
use emr::bench_fw::figures::fig7_trials;
use emr::bench_fw::BenchParams;
use emr::util::cli::Args;

fn main() {
    fig7_trials(&BenchParams::from_args(&Args::parse()));
}

//! E18: net scaling — the TCP front (`coordinator::frontend::net`) under
//! a loopback connection storm as concurrency grows (100/1k by default;
//! add 10k with `--conns 100,1000,10000` or `--paper`). `--groups N[,M]`
//! sweeps the engine-group count of the 4-shard fleet, lifting the old
//! single-batcher asymptote the serving curve plateaued at. Measures aggregate
//! throughput, p50/p99 round-trip latency, client errors, server-side
//! protocol errors, end-of-run unreclaimed nodes and the peak
//! active-connection / in-flight gauges, per scheme. Runs on the synthetic
//! backend, so no PJRT artifacts are needed.
//!
//! Besides the printed tables (and `--csv PATH`), the sweep is written as
//! a machine-readable record to `BENCH_fig_net_scaling.json` (override
//! with `--json PATH`) for the CI artifact trail.
//!
//! ```bash
//! cargo bench --bench net_scaling -- --conns 100,1000,10000 --exec-threads 8
//! ```
use emr::bench_fw::figures::fig_net_scaling;
use emr::bench_fw::BenchParams;
use emr::reclaim::SchemeId;
use emr::util::cli::Args;
use std::fmt::Write as _;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    if args.get("schemes").is_none() {
        // The ISSUE's comparison set: the paper's scheme, one epoch
        // scheme, hazard pointers.
        p.schemes = vec![SchemeId::Stamp, SchemeId::Ebr, SchemeId::Hp];
    }
    let cells = fig_net_scaling(&p);

    let mut body = String::new();
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            body.push_str(",\n");
        }
        let _ = write!(
            body,
            "    {{\"scheme\": \"{}\", \"conns\": {}, \"groups\": {}, \
             \"req_per_s\": {:.1}, \
             \"p50_ns\": {:.0}, \"p99_ns\": {:.0}, \
             \"trace_p50_ns\": {}, \"trace_p99_ns\": {}, \"trace_p999_ns\": {}, \
             \"trace_pairs\": {}, \"errors\": {}, \
             \"protocol_errors\": {}, \"bytes_in\": {}, \"bytes_out\": {}, \
             \"unreclaimed\": {}, \"peak_active\": {}, \"peak_in_flight\": {}}}",
            c.scheme,
            c.conns,
            c.groups,
            c.req_per_s,
            c.p50_ns,
            c.p99_ns,
            c.trace_p50_ns,
            c.trace_p99_ns,
            c.trace_p999_ns,
            c.trace_pairs,
            c.errors,
            c.protocol_errors,
            c.bytes_in,
            c.bytes_out,
            c.unreclaimed,
            c.peak_active,
            c.peak_in_flight,
        );
    }
    let json = format!(
        "{{\n  \"bench\": \"net_scaling\",\n  \"exec_threads\": {},\n  \
         \"cells\": [\n{body}\n  ]\n}}\n",
        p.exec_threads
    );
    let path = args.get_or("json", "BENCH_fig_net_scaling.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

//! E17: async scaling — thread-per-request vs the async multiplexed
//! front-end (`coordinator::frontend`) as logical-client concurrency grows
//! (1k/10k by default; add 100k with `--clients 1000,10000,100000` or
//! `--paper`). `--groups N[,M]` sweeps the engine-group count of the
//! 4-shard fleet. Measures throughput, client-observed p50/p99 latency,
//! flight-recorder-derived p50/p99/p999 (`--trace on|off|<cap>` toggles
//! the recorder), end-of-run unreclaimed nodes and the peak queue-depth /
//! in-flight gauges, per scheme. Runs on the synthetic backend, so no
//! PJRT artifacts are needed.
//!
//! Besides the printed tables (and `--csv PATH`), the sweep is written as
//! a machine-readable record to `BENCH_fig_async_scaling.json` (override
//! with `--json PATH`) for the CI artifact trail.
//!
//! ```bash
//! cargo bench --bench async_scaling -- --clients 1000,10000 --exec-threads 8
//! ```
use emr::bench_fw::figures::fig_async_scaling;
use emr::bench_fw::BenchParams;
use emr::reclaim::SchemeId;
use emr::util::cli::Args;
use std::fmt::Write as _;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    if args.get("schemes").is_none() {
        // The ISSUE's comparison set: the paper's scheme, one epoch
        // scheme, hazard pointers.
        p.schemes = vec![SchemeId::Stamp, SchemeId::Ebr, SchemeId::Hp];
    }
    let cells = fig_async_scaling(&p);

    let mut body = String::new();
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            body.push_str(",\n");
        }
        let _ = write!(
            body,
            "    {{\"scheme\": \"{}\", \"mode\": \"{}\", \"clients\": {}, \
             \"groups\": {}, \"os_threads\": {}, \"req_per_s\": {:.1}, \
             \"p50_ns\": {:.0}, \"p99_ns\": {:.0}, \
             \"trace_p50_ns\": {}, \"trace_p99_ns\": {}, \"trace_p999_ns\": {}, \
             \"trace_pairs\": {}, \"errors\": {}, \"unreclaimed\": {}, \
             \"peak_queue_depth\": {}, \"peak_in_flight\": {}}}",
            c.scheme,
            c.mode,
            c.clients,
            c.groups,
            c.threads_used,
            c.req_per_s,
            c.p50_ns,
            c.p99_ns,
            c.trace_p50_ns,
            c.trace_p99_ns,
            c.trace_p999_ns,
            c.trace_pairs,
            c.errors,
            c.unreclaimed,
            c.peak_queue_depth,
            c.peak_in_flight,
        );
    }
    let json = format!(
        "{{\n  \"bench\": \"async_scaling\",\n  \"exec_threads\": {},\n  \
         \"cells\": [\n{body}\n  ]\n}}\n",
        p.exec_threads
    );
    let path = args.get_or("json", "BENCH_fig_async_scaling.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

//! E17: async scaling — thread-per-request vs the async multiplexed
//! front-end (`coordinator::frontend`) as logical-client concurrency grows
//! (1k/10k by default; add 100k with `--clients 1000,10000,100000` or
//! `--paper`). `--groups N[,M]` sweeps the engine-group count of the
//! 4-shard fleet. Measures throughput, p50/p99 latency, end-of-run
//! unreclaimed nodes and the peak queue-depth / in-flight gauges, per
//! scheme. Runs on the synthetic backend, so no PJRT artifacts are needed.
//!
//! ```bash
//! cargo bench --bench async_scaling -- --clients 1000,10000 --exec-threads 8
//! ```
use emr::bench_fw::figures::fig_async_scaling;
use emr::bench_fw::BenchParams;
use emr::reclaim::SchemeId;
use emr::util::cli::Args;

fn main() {
    let args = Args::parse();
    let mut p = BenchParams::from_args(&args);
    if args.get("schemes").is_none() {
        // The ISSUE's comparison set: the paper's scheme, one epoch
        // scheme, hazard pointers.
        p.schemes = vec![SchemeId::Stamp, SchemeId::Ebr, SchemeId::Hp];
    }
    fig_async_scaling(&p);
}

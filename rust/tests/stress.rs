//! Heavy concurrent stress: value conservation, use-after-reclaim
//! detection (poisoned payloads), and capacity bounds under every scheme,
//! with all three structures churning simultaneously. Each stress case
//! runs in its own reclamation domain.

use emr::ds::hashmap::FifoCache;
use emr::ds::list::List;
use emr::ds::queue::Queue;
use emr::reclaim::tests_common::{flush_until, Payload};
use emr::reclaim::{Cached, DomainRef, Reclaimer};
use emr::util::rng::Xoshiro256;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// MPMC conservation: every enqueued value dequeued exactly once, payload
/// drops exactly match allocations.
fn queue_conservation<R: Reclaimer>(threads: usize, per_thread: usize) {
    let domain = DomainRef::<R>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));
    let q: Queue<Payload, R> = Queue::new_in(domain.clone());
    let dequeued_sum = AtomicU64::new(0);
    let dequeued_count = AtomicUsize::new(0);
    let expected_sum: u64 = (0..(threads * per_thread) as u64).sum();

    std::thread::scope(|s| {
        for t in 0..threads {
            let q = &q;
            let drops = &drops;
            s.spawn(move || {
                let h = q.domain().register();
                for i in 0..per_thread {
                    let v = (t * per_thread + i) as u64;
                    q.enqueue(&h, Payload::new(v, drops));
                    if i % 97 == 0 {
                        std::thread::yield_now();
                    }
                }
            });
        }
        for _ in 0..threads {
            let q = &q;
            let dequeued_sum = &dequeued_sum;
            let dequeued_count = &dequeued_count;
            let total = threads * per_thread;
            s.spawn(move || {
                let h = q.domain().register();
                loop {
                    if dequeued_count.load(Ordering::Relaxed) >= total {
                        break;
                    }
                    match q.dequeue(&h) {
                        Some(p) => {
                            dequeued_sum.fetch_add(p.read(), Ordering::Relaxed);
                            dequeued_count.fetch_add(1, Ordering::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
            });
        }
    });

    assert_eq!(dequeued_count.load(Ordering::Relaxed), threads * per_thread);
    assert_eq!(
        dequeued_sum.load(Ordering::Relaxed),
        expected_sum,
        "{}: values lost/duplicated",
        R::NAME
    );
    drop(q);
    let h = domain.register();
    flush_until(&h, || drops.load(Ordering::Relaxed) == threads * per_thread);
    assert_eq!(
        drops.load(Ordering::Relaxed),
        threads * per_thread,
        "{}: payload drop count",
        R::NAME
    );
}

/// Random mixed list workload with poisoned-payload reads; afterwards every
/// allocation is accounted for.
fn list_poison_detection<R: Reclaimer>(threads: usize, iters: usize) {
    let domain = DomainRef::<R>::new_owned();
    let drops = Arc::new(AtomicUsize::new(0));
    let allocs = Arc::new(AtomicUsize::new(0));
    let list: List<u64, Payload, R> = List::new_in(domain.clone());

    std::thread::scope(|s| {
        for t in 0..threads {
            let list = &list;
            let drops = &drops;
            let allocs = &allocs;
            s.spawn(move || {
                let h = list.domain().register();
                let mut rng = Xoshiro256::new(0x715 + t as u64);
                for i in 0..iters {
                    let k = rng.below(40);
                    match rng.below(10) {
                        0..=3 => {
                            // Every constructed payload is eventually
                            // dropped — either via reclamation or, for a
                            // rejected duplicate, immediately by insert.
                            allocs.fetch_add(1, Ordering::Relaxed);
                            list.insert(&h, k, Payload::new(k, drops));
                        }
                        4..=6 => {
                            list.remove(&h, &k);
                        }
                        _ => {
                            // read() panics on poisoned (reclaimed) payloads.
                            list.get(&h, &k, |p| assert_eq!(p.read(), k));
                        }
                    }
                    if i % 128 == 0 {
                        std::thread::yield_now();
                    }
                }
            });
        }
    });

    let live = list.len(Cached);
    drop(list);
    let h = domain.register();
    flush_until(&h, || drops.load(Ordering::Relaxed) == allocs.load(Ordering::Relaxed));
    assert_eq!(
        drops.load(Ordering::Relaxed),
        allocs.load(Ordering::Relaxed),
        "{}: {} live at drop",
        R::NAME,
        live
    );
}

/// The HashMap-benchmark shape under stress: payload integrity + bounded
/// capacity while evictions retire 1 KiB nodes.
fn cache_bounded_integrity<R: Reclaimer>(threads: usize, iters: usize) {
    let cache: FifoCache<u64, [u64; 128], R> =
        FifoCache::new_in(DomainRef::new_owned(), 64, 200);
    std::thread::scope(|s| {
        for t in 0..threads {
            let cache = &cache;
            s.spawn(move || {
                let h = cache.domain().register();
                let mut rng = Xoshiro256::new(0xCAC4E + t as u64);
                for i in 0..iters {
                    let k = rng.below(2_000);
                    match cache.get(&h, &k, |v| {
                        // Payload self-describes its key: catches
                        // cross-node corruption from bad reclamation.
                        assert_eq!(v[0], k);
                        assert_eq!(v[127], k ^ 0xFFFF);
                    }) {
                        Some(()) => {}
                        None => {
                            let mut payload = [0u64; 128];
                            payload[0] = k;
                            payload[127] = k ^ 0xFFFF;
                            cache.insert(&h, k, payload);
                        }
                    }
                    if i % 256 == 0 {
                        std::thread::yield_now();
                    }
                }
            });
        }
    });
    assert!(
        cache.len() <= 200 + threads,
        "{}: capacity {} exceeded",
        R::NAME,
        cache.len()
    );
}

macro_rules! stress {
    ($mod_name:ident, $scheme:ty) => {
        mod $mod_name {
            use super::*;

            #[test]
            fn queue_conserves_values() {
                queue_conservation::<$scheme>(4, 3_000);
            }

            #[test]
            fn list_detects_no_poison() {
                list_poison_detection::<$scheme>(4, 4_000);
            }

            #[test]
            fn cache_bounded_and_intact() {
                cache_bounded_integrity::<$scheme>(4, 4_000);
            }
        }
    };
}

stress!(lfrc, emr::reclaim::lfrc::Lfrc);
stress!(hp, emr::reclaim::hp::Hp);
stress!(ebr, emr::reclaim::ebr::Ebr);
stress!(nebr, emr::reclaim::nebr::Nebr);
stress!(qsr, emr::reclaim::qsr::Qsr);
stress!(debra, emr::reclaim::debra::Debra);
stress!(stamp, emr::reclaim::stamp::StampIt);
stress!(hyaline, emr::reclaim::hyaline::Hyaline);

//! Hyaline robustness end-to-end (the E19 mechanism at test scale): a
//! stalled executor task that leaked a guard across a never-woken future
//! must (a) trip the guard-across-await lint and (b) strand only batches
//! born before its announce — fresh churn keeps reclaiming to zero while
//! the task stays parked. Plus the lint's public knob surface and the
//! `smr.stall` watermark event.
//!
//! Lint and trace state are process-global, so every test here serializes
//! on [`LOCK`] (same pattern as `tests/trace.rs`).

use emr::reclaim::facade::lint;
use emr::reclaim::hyaline::Hyaline;
use emr::reclaim::tests_common::{flush_until, Payload};
use emr::reclaim::{Atomic, DomainRef, Owned};
use emr::runtime::exec::Executor;
use emr::trace;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Serializes tests that flip process-global lint/trace state.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The E19 adversary through the public API only: a task polled once on a
/// real executor protects a node and leaks the guard before returning
/// `Pending` forever. The lint must record the violation at that poll, the
/// `smr.stall` watermark must fire once churn crosses it, and — Hyaline's
/// whole point — churn retired *after* the stall began must still reclaim
/// completely, leaving `unreclaimed()` at zero with the task still parked.
#[test]
fn stalled_task_is_linted_and_strands_nothing_fresh() {
    let _g = lock();
    trace::set_enabled(true);
    lint::set_enabled(true);
    let mut drainer = trace::Drainer::from_now();

    let domain = DomainRef::<Hyaline>::new_owned();
    // Low watermark: the first churn burst crosses it deterministically
    // (Hyaline holds at least HY_BATCH_MIN retires before its first seal).
    domain.domain().set_stall_watermark(4);

    let violations_before = lint::violations();
    let armed = Arc::new(AtomicBool::new(false));
    let exec = Executor::new(1);
    {
        let domain = domain.clone();
        let armed = armed.clone();
        let mut first = true;
        exec.spawn(std::future::poll_fn(move |_cx| {
            if first {
                first = false;
                // Leak cell, handle and guard: protection outlives the poll
                // (and even the task, if the lint's debug assertion downs
                // it) — exactly the bug the lint exists to catch.
                let cell = Box::leak(Box::new(Atomic::<u64, Hyaline>::new(Owned::new(0xE19))));
                let h = Box::leak(Box::new(domain.register()));
                let mut g = h.guard();
                assert!(g.protect(cell).is_some());
                std::mem::forget(g);
                armed.store(true, Ordering::Release);
            }
            std::task::Poll::<()>::Pending
        }));
    }
    while !armed.load(Ordering::Acquire) {
        std::thread::yield_now();
    }
    // `armed` flips inside the poll; the lint check runs after the poll
    // returns Pending on the worker thread — give it a moment.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while lint::violations() == violations_before && std::time::Instant::now() < deadline {
        std::thread::yield_now();
    }
    assert!(
        lint::violations() > violations_before,
        "leaking a guard across a Pending poll must record a lint violation"
    );

    // Advance the birth-era clock well past the stalled announce (dropping
    // unpublished Owneds frees directly — nothing is retired, so no orphan
    // can drag a later batch's min_birth below the stalled era).
    for _ in 0..256 {
        drop(Owned::<u64, Hyaline>::new(0));
    }

    // Churn on the stalled domain: every batch is born after the stall, so
    // the era gate must skip the parked task's slot and reclaim everything.
    let drops = Arc::new(AtomicUsize::new(0));
    let h = domain.register();
    const CHURN: usize = 64;
    for i in 0..CHURN as u64 {
        h.retire_owned(Owned::<Payload, Hyaline>::new(Payload::new(i, &drops)));
    }
    let ok = flush_until(&h, || drops.load(Ordering::Relaxed) == CHURN);
    assert!(
        ok,
        "stalled task stranded fresh batches: {} of {CHURN} reclaimed",
        drops.load(Ordering::Relaxed)
    );
    assert_eq!(
        domain.domain().unreclaimed(),
        0,
        "every post-stall retire must reclaim with the task still parked"
    );

    // The watermark crossing left its mark in the flight recorder.
    let d = drainer.drain();
    assert!(
        d.events.iter().any(|e| trace::label_name(e.label) == Some("smr.stall")),
        "crossing the stall watermark must emit smr.stall"
    );
    drop(exec); // cancels the parked task (its protection was leaked anyway)
}

/// The opt-out knob and the counting surface: guards count per thread,
/// `check_after_poll` records violations only while enabled.
#[test]
fn lint_knob_and_counters_roundtrip() {
    let _g = lock();
    lint::set_enabled(true);

    // Knob strings mirror the trace/magazine knobs.
    assert!(lint::apply_knob("off"));
    assert!(!lint::enabled());
    assert!(lint::apply_knob("on"));
    assert!(lint::enabled());
    assert!(!lint::apply_knob("sideways"));

    let domain = DomainRef::<Hyaline>::new_owned();
    let h = domain.register();
    let cell: Atomic<u64, Hyaline> = Atomic::new(Owned::new(7));

    let base = lint::live_guards();
    let mut g = h.guard();
    assert!(g.protect(&cell).is_some());
    assert_eq!(lint::live_guards(), base + 1, "guard creation must count");

    // A guard born during a poll and still live at Pending: violation —
    // wrapped in catch_unwind because debug builds also assert.
    let before_v = lint::violations();
    let caught = std::panic::catch_unwind(|| lint::check_after_poll(base));
    assert_eq!(lint::violations(), before_v + 1);
    if let Ok(flagged) = caught {
        assert!(flagged, "check_after_poll must report the violation");
    }

    // Disabled: the same situation records nothing.
    lint::set_enabled(false);
    assert!(!lint::check_after_poll(base));
    assert_eq!(lint::violations(), before_v + 1);
    lint::set_enabled(true);

    drop(g);
    assert_eq!(lint::live_guards(), base, "guard drop must uncount");
    // Balanced tasks never trip the check.
    assert!(!lint::check_after_poll(base));

    // Cleanup the published node.
    let node = cell.load(Ordering::Acquire);
    cell.store(emr::reclaim::MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked above; retired exactly once.
    unsafe { h.retire(node.get()) };
    h.flush();
}

/// A task that drops its guard before parking is clean: polling it to
/// `Pending` on a real executor must not move the violation counter.
#[test]
fn balanced_task_does_not_trip_lint() {
    let _g = lock();
    lint::set_enabled(true);

    let domain = DomainRef::<Hyaline>::new_owned();
    let cell = Arc::new(Atomic::<u64, Hyaline>::new(Owned::new(41)));
    let before = lint::violations();
    let polled = Arc::new(AtomicBool::new(false));
    let exec = Executor::new(1);
    let task = {
        let domain = domain.clone();
        let cell = cell.clone();
        let polled = polled.clone();
        let mut parked_once = false;
        exec.spawn(std::future::poll_fn(move |cx| {
            if !parked_once {
                parked_once = true;
                let h = domain.register();
                let mut g = h.guard();
                assert_eq!(g.protect(&cell).expect("non-null").read(), 41);
                drop(g); // balanced: nothing live across the await point
                polled.store(true, Ordering::Release);
                cx.waker().wake_by_ref();
                return std::task::Poll::Pending;
            }
            std::task::Poll::Ready(())
        }))
    };
    assert_eq!(task.join(), Some(()));
    assert!(polled.load(Ordering::Acquire));
    assert_eq!(lint::violations(), before, "a balanced task must not be flagged");

    // Cleanup.
    let h = domain.register();
    let node = cell.load(Ordering::Acquire);
    cell.store(emr::reclaim::MarkedPtr::null(), Ordering::Release);
    // SAFETY: unlinked above; retired exactly once.
    unsafe { h.retire(node.get()) };
    h.flush();
}

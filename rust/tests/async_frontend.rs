//! End-to-end tests of the async submission front-end (DESIGN.md §6):
//! `Router::submit_async` + completion slots + the std-only executor +
//! the connection mux — all on the synthetic backend, artifact-free.
//!
//! The cancellation-churn suite is the satellite the ISSUE calls out:
//! dropping a `SubmitFuture` mid-flight must neither leak its completion
//! slot nor wedge the shard worker, under Stamp-it, HP and EBR alike. The
//! `in_flight` gauge is the leak detector — every abandoned request must
//! still be answered (and its RAII token dropped) by the fleet.

use emr::bench_fw::workload::compute_payload;
use emr::coordinator::frontend::mux::{self, MuxConfig};
use emr::coordinator::{Backend, Router, ServerConfig};
use emr::reclaim::ebr::Ebr;
use emr::reclaim::hp::Hp;
use emr::reclaim::stamp::StampIt;
use emr::reclaim::Reclaimer;
use emr::runtime::exec::{block_on, block_on_deadline, Executor};
use std::time::{Duration, Instant};

fn synthetic_cfg() -> ServerConfig {
    ServerConfig {
        workers: 2,
        capacity: 128,
        buckets: 32,
        ..ServerConfig::default()
    }
    .with_backend(Backend::synthetic())
}

/// Wait (bounded) for the fleet-wide `in_flight` gauge to drain to zero.
fn wait_in_flight_zero<R: Reclaimer>(server: &Router<R>, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if server.metrics().in_flight == 0 {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    server.metrics().in_flight == 0
}

#[test]
fn async_roundtrip_matches_synthetic_compute() {
    let server = Router::<StampIt>::start(synthetic_cfg()).unwrap();
    // Miss, then hit — both through the async path.
    let r1 = block_on(server.submit_async(7)).expect("first submit");
    assert!(!r1.hit);
    assert_eq!(r1.data[..], compute_payload(7)[..]);
    let r2 = block_on(server.submit_async(7)).expect("second submit");
    assert!(r2.hit, "second request must be served from cache");
    assert_eq!(r2.data[..], compute_payload(7)[..]);
    server.shutdown();
}

#[test]
fn blocking_submit_is_a_wrapper_over_async() {
    // The blocking API must behave exactly like submit_async + block-on:
    // same payloads, same metrics accounting.
    let server = Router::<Ebr>::start(synthetic_cfg()).unwrap();
    let blocking = server.submit(11).recv().expect("blocking submit");
    let asynced = block_on(server.submit_async(11)).expect("async submit");
    assert_eq!(blocking.data[..], asynced.data[..]);
    let m = server.metrics();
    assert_eq!(m.requests, 2);
    assert_eq!(m.hits + m.misses, 2);
    server.shutdown();
    // Stopped router: both paths reject immediately (no timeout wait).
    let t0 = Instant::now();
    assert!(server.submit(12).recv().is_err());
    assert!(block_on(server.submit_async(13)).is_err());
    assert!(t0.elapsed() < Duration::from_secs(5));
}

#[test]
fn mux_drives_thousands_of_logical_clients() {
    // 2000 logical clients on 4 executor threads — far beyond
    // thread-per-request territory for a test — must all be served.
    let server = Router::<StampIt>::start(synthetic_cfg().with_shards(4)).unwrap();
    let exec = Executor::new(4);
    let cfg = MuxConfig {
        clients: 2000,
        requests_per_client: 5,
        key_space: 2_000,
        hot_pct: 80,
        shard_in_flight: 64,
        seed: 0xA57,
    };
    let report = mux::drive(&exec, server.clone(), &cfg);
    assert_eq!(report.errors, 0, "no request may be dropped");
    assert_eq!(report.served(), 2000 * 5);
    let m = server.metrics();
    assert_eq!(m.requests, 2000 * 5);
    assert_eq!(m.hits + m.misses, 2000 * 5);
    assert!(
        wait_in_flight_zero(&server, Duration::from_secs(10)),
        "in_flight must drain once every client is answered: {}",
        server.metrics().in_flight
    );
    server.shutdown();
    assert_eq!(server.metrics().queue_depth, 0, "shutdown must drain the queues");
}

#[test]
fn mux_back_pressure_bounds_open_slots() {
    // The per-shard budget is the invariant: a client only submits while
    // holding a budget permit, and the in-flight token's lifetime sits
    // inside the permit's — so the fleet-wide gauge can never exceed
    // shards × budget, at any sampled instant.
    let server = Router::<Ebr>::start(synthetic_cfg().with_shards(2)).unwrap();
    let exec = Executor::new(4);
    let cfg = MuxConfig {
        clients: 400,
        requests_per_client: 3,
        key_space: 1_000,
        hot_pct: 80,
        shard_in_flight: 8,
        seed: 0xBB,
    };
    let bound = 2 * 8;
    let done = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let sampler = {
        let server = server.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let mut peak = 0u64;
            while !done.load(std::sync::atomic::Ordering::Acquire) {
                peak = peak.max(server.metrics().in_flight);
                std::thread::sleep(Duration::from_micros(200));
            }
            peak
        })
    };
    let report = mux::drive(&exec, server.clone(), &cfg);
    done.store(true, std::sync::atomic::Ordering::Release);
    let peak = sampler.join().unwrap();
    assert_eq!(report.errors, 0);
    assert!(
        peak <= bound as u64,
        "in_flight gauge ({peak}) exceeded the back-pressure bound ({bound})"
    );
    server.shutdown();
}

/// The churn satellite: spawn and drop 10k `SubmitFuture`s mid-flight —
/// half never polled, half cancelled after their first poll (waker
/// registered) — then verify nothing leaked and nothing wedged.
fn cancellation_churn<R: Reclaimer>() {
    let server = Router::<R>::start(
        ServerConfig {
            workers: 2,
            capacity: 64, // tiny: constant eviction churn under the load
            buckets: 16,
            ..ServerConfig::default()
        }
        .with_backend(Backend::synthetic())
        .with_shards(2),
    )
    .unwrap();
    const N: u32 = 10_000;
    for key in 0..N {
        let fut = server.submit_async(key % 512);
        if key % 2 == 0 {
            // Dropped unpolled: no waker was ever registered.
            drop(fut);
        } else {
            // Polled once (waker registered), then cancelled: the shard
            // fulfils a slot nobody reads.
            let _ = block_on_deadline(fut, Instant::now());
        }
    }
    // Every abandoned request must still be answered: the in-flight gauge
    // (RAII tokens riding the requests) drains to exactly zero.
    assert!(
        wait_in_flight_zero(&server, Duration::from_secs(30)),
        "{}: abandoned requests leaked in_flight slots: {}",
        R::NAME,
        server.metrics().in_flight
    );
    let m = server.metrics();
    assert_eq!(m.requests, N as u64, "{}: every submit must be counted", R::NAME);
    // And the workers are not wedged: a fresh request round-trips.
    let r = block_on(server.submit_async(3)).expect("post-churn request");
    assert_eq!(r.data[..], compute_payload(3)[..]);
    server.shutdown();
    assert_eq!(server.metrics().queue_depth, 0);
}

#[test]
fn cancellation_churn_stamp() {
    cancellation_churn::<StampIt>();
}

#[test]
fn cancellation_churn_hp() {
    cancellation_churn::<Hp>();
}

#[test]
fn cancellation_churn_ebr() {
    cancellation_churn::<Ebr>();
}

#[test]
fn submit_handle_timeout_is_bounded_not_eternal() {
    // Satellite regression: the old API returned a bare mpsc::Receiver a
    // caller could block on forever. SubmitHandle::recv_timeout bounds it.
    let server = Router::<StampIt>::start(synthetic_cfg()).unwrap();
    // A healthy request completes well inside the timeout.
    let ok = server.submit(1).recv_timeout(Duration::from_secs(10));
    assert!(ok.is_ok());
    server.shutdown();
}
